"""Quickstart: the paper's framework in ~60 lines.

Builds a 3-stage dataflow (source → dedup → durable log), attaches two
independent consumers, shows backpressure + provenance, then feeds a few
training batches to a tiny LM.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import tempfile
from pathlib import Path

import jax

from repro.core import (ConsumerGroup, DetectDuplicate, FlowGraph,
                        PartitionedLog, PublishToLog, Source, make_flowfile)
from repro.core.sources import FirehoseSource
from repro.data import StreamingDataLoader
from repro.models import Model
from repro import configs


def main() -> None:
    root = Path(tempfile.mkdtemp(prefix="quickstart_"))

    # 1. durable pub-sub log (the Kafka analogue)
    log = PartitionedLog(root / "log")
    log.create_topic("tweets", partitions=4)

    # 2. dataflow: firehose → dedup → publish
    flow = FlowGraph("quickstart")
    src = flow.add(Source("firehose", FirehoseSource(count=3000, seed=7)))
    dedup = flow.add(DetectDuplicate(
        mode="exact",   # retweets share text but differ in id → key on text
        key_fn=lambda ff: ff.json().get("text", "").encode()))
    pub = flow.add(PublishToLog("to-log", log, "tweets"))
    flow.connect(src, "success", dedup)
    flow.connect(dedup, "unique", pub)
    flow.run_to_completion(timeout=120)
    print("pipeline status:", {k: v for k, v in
                               flow.status()["provenance_counts"].items() if v})
    print(f"published {pub.published} unique records "
          f"(dropped {3000 - pub.published} duplicates/noise)")

    # 3. two consumers, added WITHOUT touching the pipeline (paper §III.C)
    analytics = ConsumerGroup(log, "tweets", "analytics").add_member("a0")
    trainer_grp = ConsumerGroup(log, "tweets", "trainer")
    consumer = trainer_grp.add_member("t0")
    print("analytics consumer sees", len(analytics.poll(100)), "records")

    # 4. stream → tokenized training batches → tiny LM step
    loader = StreamingDataLoader(
        consumer, batch_size=4, seq_len=128,
        text_fn=lambda ff: ff.json().get("text", ""))
    model = Model(configs.get_reduced("tinyllama-1.1b"))
    params = model.init(jax.random.PRNGKey(0))
    for i in range(3):
        batch = loader.next_batch()
        loss, _ = model.loss_fn(params, {"tokens": jax.numpy.asarray(batch)})
        print(f"batch {i}: tokens={batch.shape}, loss={float(loss):.3f}")
    # exactly-once: positions travel with your checkpoint
    print("loader state (goes into the checkpoint):",
          {k: v for k, v in loader.state().items() if k != "pending_rows"})
    log.close()


if __name__ == "__main__":
    main()
