"""The paper's §IV case study, end-to-end: global news articles from three
source kinds (Big-RSS aggregator, tweet firehose, raw websocket) flow
through parse → dedup → enrich → route into durable topics; an HDFS-like
file sink lands articles (paper Fig. 3); provenance lineage is queryable
(Fig. 4); a simulated sink outage demonstrates backpressure (Fig. 5); a
second, fault-injected run demonstrates the robustness half of the paper's
claim — supervised restarts, poison-record quarantine, zero record loss;
and a third run feeds the topology from *live* simulated endpoints through
the acquisition runtime — reconnecting poll loops, checkpointed cursors,
event-time watermarks, and watermark-driven window closes — while the
connectors flap. (The same topology goes wire-real with
``build_news_pipeline(live="socket")`` against the localhost HTTP/WebSocket
feed servers in ``tests/net_fixtures.py`` — see
``benchmarks/bench_socket_acquisition.py``.)

Run:  PYTHONPATH=src python examples/news_ingestion.py
"""
import tempfile
import time
import urllib.request
from pathlib import Path

from repro.core import (ConsumerGroup, DeadLetterQueue, FileSink, FlowFile,
                        FlowGraph, RestartPolicy, Source)
from repro.core.faults import INJECTOR
from repro.data.pipeline import (arm_news_chaos, build_news_pipeline,
                                 expected_fabric_doc_ids,
                                 landed_doc_ids_by_shard)


def fault_tolerance_demo() -> None:
    """Re-run the topology with chaos armed: the enrich stage raises every
    ~500 records AND chokes on poison articles; the supervised / retrying
    graph finishes anyway, quarantining the poison to a dead-letter topic."""
    root = Path(tempfile.mkdtemp(prefix="news_ft_"))
    flow, log = build_news_pipeline(
        root, n_rss=5000, n_firehose=0, n_ws=0, partitions=4,
        restart_policy=RestartPolicy(max_restarts=40, backoff_base_sec=0.002,
                                     backoff_cap_sec=0.05),
        max_retries=3, dead_letter_topic="dead-letters", poison_rate=0.01)
    arm_news_chaos(crash_every=500)
    t0 = time.monotonic()
    try:
        flow.run_to_completion(timeout=300)
    finally:
        INJECTOR.reset()
    dt = time.monotonic() - t0
    st = flow.status()
    enrich = st["processors"]["enrich"]
    restarts = sum(p["restarts"] for p in st["processors"].values())
    dlq = flow.nodes["dead-letter"].processor
    landed = sum(log.end_offsets("articles"))
    print(f"fault-injected run: {landed} articles landed in {dt:.2f}s "
          f"despite injected faults (restarts={restarts}, "
          f"retries={enrich['retries']}, "
          f"quarantined={dlq.quarantined}, failed={st['failed']})")
    sample = next(DeadLetterQueue.replay(log, "dead-letters"))
    print("  quarantined sample:",
          {k: sample.attributes[k]
           for k in ("kind", "retry.count", "dead.letter.source",
                     "dead.letter.reason")})
    log.close()


def live_acquisition_demo() -> None:
    """The same topology fed by *live* endpoints: three simulated network
    sources behind reconnecting poll loops, flapped by the ``acquire.*``
    fault sites — records keep landing (duplicates bounded by the reconnect
    redelivery window, loss never), watermarks advance monotonically, and
    per-connector lag / reconnects / watermark gauges surface in
    ``flow.status()["acquisition"]``. ``window_sec`` adds the
    watermark-driven aggregation stage: tumbling event-time windows close
    only when the fabric-wide low watermark passes them, landing in topic
    ``windows``."""
    root = Path(tempfile.mkdtemp(prefix="news_live_"))
    flow, log = build_news_pipeline(root, n_rss=3000, n_firehose=2000,
                                    n_ws=500, partitions=4, live=True,
                                    window_sec=64.0)
    INJECTOR.arm("acquire.poll", "raise", nth=2, every=6)    # flap everyone
    t0 = time.monotonic()
    try:
        flow.acquisition.run_with_flow(timeout=300)
    finally:
        INJECTOR.reset()
    dt = time.monotonic() - t0
    acq = flow.status()["acquisition"]
    landed = sum(log.end_offsets("articles"))
    late = sum(log.end_offsets("late"))
    windows = sum(log.end_offsets("windows"))
    print(f"live run: {landed} articles landed in {dt:.2f}s from 3 flapping "
          f"connectors (late-routed={late}, windowed bundles={windows}, "
          f"low watermark={acq['low_watermark']:.0f})")
    for name, c in sorted(acq["connectors"].items()):
        print(f"  {name:10s} state={c['state']} acquired={c['in_records']} "
              f"reconnects={c['reconnects']} duplicates={c['duplicates']} "
              f"watermark={c['watermark']:.0f}")
    log.close()


def fabric_demo() -> None:
    """The same case study sharded over worker *processes*: each worker owns
    a slice of the sources and a disjoint subset of the landing topics'
    partitions, publishing through the socket-transported log
    (``workers=N`` — the multi-process fabric of ``core/fabric.py``). One
    worker is ``kill -9``-ed mid-ingest; the coordinator's failure detector
    fences its lease epoch and reassigns its shard groups, and the WAL +
    checkpoint replay finishes the run with zero acked-record loss."""
    root = Path(tempfile.mkdtemp(prefix="news_fabric_"))
    fabric, store = build_news_pipeline(root, n_rss=8000, n_firehose=8000,
                                        n_ws=1000, partitions=4,
                                        durable=True, workers=2)
    fabric.start()
    srv = fabric.serve_metrics()        # Prometheus-style text exposition
    t0 = time.monotonic()
    while (sum(store.end_offsets("articles")) < 1000
           and time.monotonic() - t0 < 60.0):
        time.sleep(0.05)
    fabric.kill_worker("w0")
    # scrape mid-run (wait() shuts the endpoint down with the workers):
    # merged per-worker histograms are already visible over heartbeats
    body = urllib.request.urlopen(srv.url, timeout=10).read().decode()
    st = fabric.wait(timeout=300.0)
    dt = time.monotonic() - t0
    exp = expected_fabric_doc_ids(list(fabric.shards.values()))
    ids, counts = landed_doc_ids_by_shard(store)
    missing = sum(len(exp[g] - ids.get(g, set())) for g in exp)
    dupes = sum(counts[g] - len(ids[g]) for g in counts)
    moves = ", ".join(f"{g}:{old}→{new}@e{e}"
                      for g, old, new, e in st["reassignments"])
    print(f"fabric run: 2 workers, one killed mid-ingest; "
          f"{sum(counts.values())} articles landed in {dt:.2f}s "
          f"(lost={missing}, duplicates={dupes}, takeovers=[{moves}], "
          f"low watermark={st['low_watermark']:.0f})")
    # fabric-wide telemetry: per-worker histograms merged over heartbeats
    # + group-done finals, scraped as Prometheus-style text
    e2e = [v for k, v in st["telemetry"].items()
           if k.startswith("ingest_to_land_seconds")]
    print(f"  merged ingest→land e2e across workers: "
          f"n={sum(v['count'] for v in e2e)}, "
          f"worst p99={max((v['p99_ms'] for v in e2e), default=0.0):.1f}ms")
    sample = [ln for ln in body.splitlines()
              if ln.startswith(("repro_fabric_", "repro_ingest_to_land"))][:6]
    print(f"  scraped {srv.url} mid-run "
          f"({len(body.splitlines())} lines); sample:")
    for ln in sample:
        print(f"    {ln}")
    store.close()


def main() -> None:
    root = Path(tempfile.mkdtemp(prefix="news_"))
    t0 = time.monotonic()
    flow, log = build_news_pipeline(root, n_rss=5000, n_firehose=5000,
                                    n_ws=1000, partitions=8)
    flow.run_to_completion(timeout=300)
    dt = time.monotonic() - t0
    st = flow.status()

    total = sum(st["processors"][s]["in_records"]
                for s in ("big-rss", "twitter", "websocket"))
    landed = sum(log.end_offsets("articles"))
    print(f"ingested {total} records in {dt:.2f}s "
          f"({total/dt:,.0f} rec/s) → {landed} clean articles landed")
    print("per-processor:", {n: s["in_records"]
                             for n, s in st["processors"].items()})

    # per-stage latency histograms (ISSUE 9): process time per processor
    # and the end-to-end ingest→land distribution at the terminal sinks
    tel = st["telemetry"]
    print("per-stage latency (p50/p99 ms):")
    for key in sorted(k for k in tel if k.startswith("process_seconds")):
        s = tel[key]
        print(f"  {key:45s} n={s['count']:6d} "
              f"p50={s['p50_ms']:.3f} p99={s['p99_ms']:.3f}")
    e2e = flow.telemetry.merged("ingest_to_land_seconds").summary()
    print(f"ingest→land e2e: n={e2e['count']} "
          f"p50={e2e['p50_ms']:.1f}ms p99={e2e['p99_ms']:.1f}ms")

    # provenance lineage (paper Fig. 4): walk one record's path
    ev = flow.provenance.events(event_type="CREATE")[0]
    print("lineage of one record:",
          " → ".join(flow.provenance.lineage_chain(ev.lineage_id)))

    # HDFS-like landing zone (paper Fig. 3): one uuid-named file per article
    grp = ConsumerGroup(log, "articles", "hdfs-sink")
    consumer = grp.add_member("h0")
    sink_dir = root / "hdfs"
    sink = FileSink("hdfs", sink_dir)
    n = 0
    while n < 200:
        recs = consumer.poll(64)
        if not recs:
            break
        for r in recs:
            list(sink.process(FlowFile.from_record(r.key, r.value)))
        n += len(recs)
    files = sorted(sink_dir.iterdir())[:5]
    print(f"landed {sink.written} files; sample listing:")
    for f in files:
        print(f"  {f.name}  {f.stat().st_size/1024:.1f} kB")

    # consumers scale elastically; committed offsets survive rebalance
    c2 = grp.add_member("h1")
    print(f"scaled sink group to 2 members: "
          f"{len(consumer.assignment)} + {len(c2.assignment)} partitions")
    log.close()

    # robustness (the other half of the paper's title): same topology under
    # injected faults — supervised restarts + retry + dead-letter quarantine
    fault_tolerance_demo()

    # live acquisition: the same topology fed by reconnecting poll loops
    # over flapping simulated endpoints, with event-time watermarks
    live_acquisition_demo()

    # scale-out: the topology sharded across worker processes over the
    # socket log, surviving a kill -9 via lease takeover (paper title:
    # "scalable AND robust")
    fabric_demo()


if __name__ == "__main__":
    main()
