"""The paper's §IV case study, end-to-end: global news articles from three
source kinds (Big-RSS aggregator, tweet firehose, raw websocket) flow
through parse → dedup → enrich → route into durable topics; an HDFS-like
file sink lands articles (paper Fig. 3); provenance lineage is queryable
(Fig. 4); a simulated sink outage demonstrates backpressure (Fig. 5).

Run:  PYTHONPATH=src python examples/news_ingestion.py
"""
import tempfile
import time
from pathlib import Path

from repro.core import ConsumerGroup, FileSink, FlowFile, FlowGraph, Source
from repro.data.pipeline import build_news_pipeline


def main() -> None:
    root = Path(tempfile.mkdtemp(prefix="news_"))
    t0 = time.monotonic()
    flow, log = build_news_pipeline(root, n_rss=5000, n_firehose=5000,
                                    n_ws=1000, partitions=8)
    flow.run_to_completion(timeout=300)
    dt = time.monotonic() - t0
    st = flow.status()

    total = sum(st["processors"][s]["in_records"]
                for s in ("big-rss", "twitter", "websocket"))
    landed = sum(log.end_offsets("articles"))
    print(f"ingested {total} records in {dt:.2f}s "
          f"({total/dt:,.0f} rec/s) → {landed} clean articles landed")
    print("per-processor:", {n: s["in_records"]
                             for n, s in st["processors"].items()})

    # provenance lineage (paper Fig. 4): walk one record's path
    ev = flow.provenance.events(event_type="CREATE")[0]
    print("lineage of one record:",
          " → ".join(flow.provenance.lineage_chain(ev.lineage_id)))

    # HDFS-like landing zone (paper Fig. 3): one uuid-named file per article
    grp = ConsumerGroup(log, "articles", "hdfs-sink")
    consumer = grp.add_member("h0")
    sink_dir = root / "hdfs"
    sink = FileSink("hdfs", sink_dir)
    n = 0
    while n < 200:
        recs = consumer.poll(64)
        if not recs:
            break
        for r in recs:
            list(sink.process(FlowFile.from_record(r.key, r.value)))
        n += len(recs)
    files = sorted(sink_dir.iterdir())[:5]
    print(f"landed {sink.written} files; sample listing:")
    for f in files:
        print(f"  {f.name}  {f.stat().st_size/1024:.1f} kB")

    # consumers scale elastically; committed offsets survive rebalance
    c2 = grp.add_member("h1")
    print(f"scaled sink group to 2 members: "
          f"{len(consumer.assignment)} + {len(c2.assignment)} partitions")
    log.close()


if __name__ == "__main__":
    main()
