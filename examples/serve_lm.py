"""Streaming inference: requests arrive on a 'requests' topic, a Server
consumer batches prefill+decode, completions land on a 'completions' topic —
the paper's add/remove-consumers property applied to serving (scale servers
= add group members).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import json
import tempfile
from pathlib import Path

import jax

from repro import configs
from repro.core import ConsumerGroup, PartitionedLog
from repro.models import Model
from repro.runtime import ServeConfig, Server


def main() -> None:
    root = Path(tempfile.mkdtemp(prefix="serve_"))
    log = PartitionedLog(root / "log")
    log.create_topic("requests", partitions=4)
    log.create_topic("completions", partitions=4)

    # any producer can enqueue requests (REST bridge, upstream pipeline...)
    prompts = ["the market rally", "storm warning for", "election results",
               "satellite launch at", "quarter earnings beat", "trade summit"]
    for i, p in enumerate(prompts):
        log.append("requests", str(i).encode(),
                   json.dumps({"id": i, "prompt": p}).encode())

    cfg = configs.get_reduced("tinyllama-1.1b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    grp = ConsumerGroup(log, "requests", "servers")
    server = Server(model, params, grp.add_member("srv0"), log,
                    ServeConfig(batch_size=3, prompt_len=32,
                                max_new_tokens=16))
    while server.serve_once():
        pass
    print(f"served {server.served} requests")
    out = log.read("completions", 0, 0, 100)
    for p in range(log.num_partitions("completions")):
        for r in log.read("completions", p, 0, 100):
            doc = json.loads(r.value)
            print(f"  req {doc['id']}: {doc['completion_ids'][:8]}…")
    log.close()


if __name__ == "__main__":
    main()
