"""End-to-end driver: train an LM on a live ingestion stream with
checkpoint/restart fault tolerance — the paper's framework feeding the
training consumer.

Default scale finishes in ~2 minutes on this CPU container (10M-param
llama-family model, 200 steps); --scale full trains a ~100M model.

Run:  PYTHONPATH=src python examples/train_stream_lm.py [--steps 200]
      PYTHONPATH=src python examples/train_stream_lm.py --scale full
"""
import argparse
import dataclasses
import tempfile
from pathlib import Path

from repro import configs
from repro.core import PartitionedLog, make_flowfile
from repro.core.sources import corpus_documents
from repro.data.pipeline import attach_training_loader
from repro.models import Model
from repro.optim import OptConfig
from repro.runtime import Trainer, TrainerConfig


def build_corpus(root: Path, n_docs: int) -> PartitionedLog:
    """In production this topic is filled by the news pipeline
    (examples/news_ingestion.py); here we fill it directly."""
    log = PartitionedLog(root / "log")
    log.create_topic("articles", partitions=8)
    records = [make_flowfile(doc, text=doc).to_record()
               for doc in corpus_documents(n_docs)]
    for p in range(8):                    # batched append: one write per chunk
        log.append_batch("articles", records[p::8], partition=p)
    log.flush(fsync=False)
    return log


def model_config(scale: str):
    base = configs.get_reduced("tinyllama-1.1b")
    if scale == "small":        # ~4M params (finishes in ~2 min)
        return dataclasses.replace(base, num_layers=4, d_model=256,
                                   n_heads=8, n_kv_heads=4, d_head=32,
                                   d_ff=1024, vocab_size=512)
    # 'full': ~100M params (slow on 1 CPU core — budget ~1h for 200 steps)
    return dataclasses.replace(base, num_layers=8, d_model=768, n_heads=12,
                               n_kv_heads=4, d_head=64, d_ff=2304,
                               vocab_size=512)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--scale", choices=("small", "full"), default="small")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--workdir", default=None)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    root = Path(args.workdir or tempfile.mkdtemp(prefix="stream_train_"))
    print(f"workdir: {root}")
    log = build_corpus(root, n_docs=60_000)
    grp, loader = attach_training_loader(log, batch_size=args.batch,
                                         seq_len=args.seq)
    cfg = model_config(args.scale)
    model = Model(cfg)
    n_params = cfg.param_count()
    print(f"model: {cfg.name} ({n_params/1e6:.1f}M params), "
          f"{args.batch}×{args.seq} tokens/step")

    trainer = Trainer(
        model, loader,
        OptConfig(peak_lr=3e-3, warmup_steps=20, total_steps=args.steps),
        TrainerConfig(steps=args.steps, ckpt_every=50, log_every=10,
                      ckpt_dir=str(root / "ckpt")))
    if args.resume and trainer.resume():
        print(f"resumed at step {trainer.step_idx}")
    out = trainer.run()
    for h in trainer.history:
        print(f"step {h['step']:>4}  loss {h['loss']:.4f}  "
              f"lr {h['lr']:.2e}  gnorm {h['grad_norm']:.2f}")
    tps = out["steps"] * args.batch * args.seq / out["wall_sec"]
    print(f"\ntrained {out['steps']} steps in {out['wall_sec']:.1f}s "
          f"({tps:,.0f} tokens/s); final loss {out['final_loss']:.4f}")
    print(f"checkpoints: {trainer.ckpt.steps()} (resume with --resume "
          f"--workdir {root})")
    log.close()


if __name__ == "__main__":
    main()
