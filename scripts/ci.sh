#!/usr/bin/env bash
# One-command verify (documented in pyproject.toml + ROADMAP):
#   scripts/ci.sh            tier-1 pytest + CI-sized bench smoke pass
#   scripts/ci.sh -m 'not slow'   ... forwarding extra pytest args
#
# The bench smoke (`benchmarks/run.py --quick`) runs the same ingest /
# backpressure / recovery / loader scenarios as the full run at ~10x
# smaller inputs and does NOT rewrite BENCH_ingest.json.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 pytest =="
python -m pytest -q "$@"

echo "== bench smoke (--quick) =="
python benchmarks/run.py --quick

echo "== ci.sh: OK =="
