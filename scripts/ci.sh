#!/usr/bin/env bash
# One-command verify (documented in pyproject.toml + ROADMAP):
#   scripts/ci.sh            tier-1 pytest + CI-sized bench smoke pass
#   scripts/ci.sh -m 'not slow'   ... forwarding extra pytest args
#
# The bench smoke (`benchmarks/run.py --quick`) runs the same ingest /
# backpressure / recovery / acquisition / socket-acquisition / loader
# scenarios as the full run at ~10x smaller inputs and does NOT rewrite
# BENCH_ingest.json. It FAILS (non-zero exit) when a quick ingest variant
# regresses below 0.8x an A/B baseline (the same quick pass run from a git
# worktree of HEAD — or HEAD~1 on a clean checkout — in the same host-load
# phase; snapshot + calibration fallback without git)
# on BOTH wall-clock and cpu-time rates (one re-measure absorbs residual
# noise), or when an acceptance flag breaks in the recovery /
# flapping-connector acquisition scenarios — simulated AND wire-real
# localhost HTTP/WebSocket (record loss, watermark regression, unbounded
# duplicates, window closes outrunning the low watermark, missing
# per-stage latency telemetry). The quick pass also A/B-guards the
# telemetry hot path: instrumented ingest must stay within 2% of a
# telemetry=off run measured back to back (either wall or cpu rate).
# The tier-1 pass includes the `net` marker's localhost-socket tests.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== invariant analysis (repro.analysis --check) =="
# AST pass over src/: fails on any unbaselined finding, any stale baseline
# entry (drift in either direction), or any unused suppression pragma.
# Rules + the committed baseline: src/repro/analysis/, analysis-baseline.json.
python -m repro.analysis --check src/

echo "== tier-1 pytest =="
python -m pytest -q "$@"

echo "== lock-order detector over the fast concurrency subset =="
# Re-run the `lockorder`-marked modules with threading.Lock/RLock wrapped
# (opt-in via REPRO_LOCK_ORDER=1; zero patching otherwise). Exit 3 if the
# recorded acquisition graph contains a held-across cycle — a deadlock
# waiting for the right interleaving, even when every test passed.
REPRO_LOCK_ORDER=1 python -m pytest -q -m lockorder

echo "== bench smoke + acquisition/ingest guards (--quick) =="
python benchmarks/run.py --quick

echo "== ci.sh: OK =="
