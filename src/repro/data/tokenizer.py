"""Byte-level tokenizer.

Deterministic, dependency-free, and valid for every assigned architecture:
ids 0..255 are raw bytes, followed by the special tokens. All assigned model
vocabularies (32,000 .. 256,000) are strict supersets of this id range, so
the same encoded stream drives any of them; in production the tokenizer is a
pluggable interface (``Tokenizer`` protocol) and this is the reference
implementation.
"""
from __future__ import annotations

import numpy as np

BYTE_VOCAB = 256


class ByteTokenizer:
    PAD = 256
    BOS = 257
    EOS = 258
    vocab_size = 259

    def encode(self, text: str, add_bos: bool = True,
               add_eos: bool = True) -> list[int]:
        ids = list(text.encode("utf-8"))
        if add_bos:
            ids.insert(0, self.BOS)
        if add_eos:
            ids.append(self.EOS)
        return ids

    def decode(self, ids) -> str:
        return bytes(i for i in ids if 0 <= i < BYTE_VOCAB).decode(
            "utf-8", errors="replace")

    def encode_np(self, text: str, **kw) -> np.ndarray:
        return np.asarray(self.encode(text, **kw), dtype=np.int32)
