"""Byte-level tokenizer.

Deterministic, dependency-free, and valid for every assigned architecture:
ids 0..255 are raw bytes, followed by the special tokens. All assigned model
vocabularies (32,000 .. 256,000) are strict supersets of this id range, so
the same encoded stream drives any of them; in production the tokenizer is a
pluggable interface (``Tokenizer`` protocol) and this is the reference
implementation.
"""
from __future__ import annotations

import numpy as np

BYTE_VOCAB = 256


class ByteTokenizer:
    PAD = 256
    BOS = 257
    EOS = 258
    vocab_size = 259

    def encode(self, text: str, add_bos: bool = True,
               add_eos: bool = True) -> list[int]:
        ids = list(text.encode("utf-8"))
        if add_bos:
            ids.insert(0, self.BOS)
        if add_eos:
            ids.append(self.EOS)
        return ids

    def decode(self, ids) -> str:
        return bytes(i for i in ids if 0 <= i < BYTE_VOCAB).decode(
            "utf-8", errors="replace")

    def encode_np(self, text: str, **kw) -> np.ndarray:
        return np.asarray(self.encode(text, **kw), dtype=np.int32)

    def encode_batch(self, texts, add_bos: bool = True,
                     add_eos: bool = True) -> np.ndarray:
        """Vectorized multi-document encode: one concatenated int32 array of
        all documents' ids in order (each wrapped in BOS/EOS like ``encode``).
        Bytes are widened with ``np.frombuffer`` instead of a per-byte Python
        loop — the streaming loader's hot path."""
        payloads = [t.encode("utf-8") for t in texts]
        extra = int(add_bos) + int(add_eos)
        out = np.empty(sum(len(b) for b in payloads) + extra * len(payloads),
                       dtype=np.int32)
        pos = 0
        for b in payloads:
            if add_bos:
                out[pos] = self.BOS
                pos += 1
            end = pos + len(b)
            out[pos:end] = np.frombuffer(b, dtype=np.uint8)
            pos = end
            if add_eos:
                out[pos] = self.EOS
                pos += 1
        return out
