"""StreamingDataLoader — the device-facing edge of the ingestion fabric.

Pulls FlowFile documents from a topic of the durable log (as a consumer-group
member), tokenizes, packs, and assembles fixed-shape global batches, with:

  * bounded host→device prefetch (reuses ``core.Connection`` backpressure —
    the paper's object-threshold semantics extended to the accelerator hop);
  * multiple reader threads with work-stealing over assigned partitions
    (straggler mitigation: a slow partition/disk never stalls the batch
    assembly as long as any partition has data);
  * exactly-once state: (consumer positions, packer carry, row buffer) are
    checkpointable and restored byte-identically (poll is deterministic);
  * elasticity: the loader is one member of a consumer group — adding
    training jobs (or data-parallel reader hosts) rebalances partitions
    without touching the ingestion pipeline (paper's headline property).

In a multi-host deployment each host runs one loader member producing the
host-local rows of the global batch, and the runtime assembles them with
``jax.make_array_from_process_local_data``; in this single-process container
the loader produces the full global batch and the runtime shards it by
``jax.device_put`` with a NamedSharding.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Callable

import numpy as np

from ..core.connection import Connection
from ..core.delivery import Consumer
from ..core.flowfile import FlowFile
from .packing import SequencePacker
from .tokenizer import ByteTokenizer


class StreamingDataLoader:
    def __init__(self, consumer: Consumer, *, batch_size: int, seq_len: int,
                 tokenizer: ByteTokenizer | None = None,
                 text_fn: Callable[[FlowFile], str] | None = None,
                 prefetch_batches: int = 4,
                 prefetch_chunk: int | None = None,
                 prefetch_linger_sec: float = 0.05,
                 reader_threads: int = 2,
                 poll_records: int = 64) -> None:
        self.consumer = consumer
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.tokenizer = tokenizer or ByteTokenizer()
        self.text_fn = text_fn or (lambda ff: ff.text())
        self.packer = SequencePacker(seq_len, self.tokenizer.PAD)
        self._rows: list[np.ndarray] = []
        self._batches_emitted = 0
        self.poll_records = poll_records
        # host→device prefetch queue with backpressure. The assembler ships
        # *chunks* of up to ``prefetch_chunk`` batches per queue envelope:
        # the CPU-bound assembler thread only yields the GIL every switch
        # interval, so each queue handoff costs the consumer a scheduling
        # quantum — amortize it over many batches. ``prefetch_batches`` still
        # bounds the number of *batches* buffered: the queue's object
        # threshold counts envelopes, sized so envelopes × chunk ≈
        # prefetch_batches. ``prefetch_linger_sec`` bounds the latency a
        # partial chunk may wait.
        prefetch_batches = max(1, prefetch_batches)
        self._chunk_batches = (min(prefetch_batches, 8) if prefetch_chunk
                               is None else max(1, prefetch_chunk))
        depth = -(-prefetch_batches // self._chunk_batches)  # ceil div
        self._prefetch = Connection("loader-prefetch", object_threshold=depth)
        self._chunk_linger = prefetch_linger_sec
        self._drained: deque[np.ndarray] = deque()
        self._reader_threads = reader_threads
        self._stop = threading.Event()
        self._threads: list[threading.Thread] = []
        self._state_lock = threading.Lock()
        self._starved_polls = 0

    # ------------------------------------------------------------------
    # Synchronous path (used by tests, dry runs, and the exactly-once
    # restore story — deterministic single-threaded batch assembly).
    # ------------------------------------------------------------------
    def _ingest_records(self, records) -> None:
        """Tokenize + pack a whole poll batch at once: one ``encode_batch``
        over all documents and one reshape in the packer, instead of
        per-document Python token loops. Falls back to the per-document path
        for pluggable tokenizers without ``encode_batch``. Row output is
        byte-identical to the sequential path (same concatenation order)."""
        if not records:
            return
        texts = [self.text_fn(FlowFile.from_record(rec.key, rec.value))
                 for rec in records]
        encode_batch = getattr(self.tokenizer, "encode_batch", None)
        if encode_batch is None:
            for text in texts:
                self._rows.extend(
                    self.packer.add_document(self.tokenizer.encode(text)))
            return
        rows = self.packer.add_tokens(encode_batch(texts))
        if len(rows):
            self._rows.extend(rows)

    def next_batch(self, timeout_polls: int = 10_000) -> np.ndarray | None:
        """Assemble one (batch_size, seq_len+1) batch synchronously.
        Returns None when the stream is exhausted before a full batch."""
        with self._state_lock:
            polls = 0
            while len(self._rows) < self.batch_size:
                recs = self.consumer.poll(self.poll_records)
                if not recs:
                    polls += 1
                    self._starved_polls += 1
                    if polls >= timeout_polls:
                        return None
                    continue
                self._ingest_records(recs)
            batch = np.stack(self._rows[:self.batch_size])
            del self._rows[:self.batch_size]
            self._batches_emitted += 1
            return batch

    # ------------------------------------------------------------------
    # Asynchronous path: background readers + bounded prefetch queue.
    # ------------------------------------------------------------------
    def start(self) -> None:
        if self._threads:
            return
        self._stop.clear()
        t = threading.Thread(target=self._assembler, name="loader-assembler",
                             daemon=True)
        self._threads.append(t)
        t.start()

    def _assembler(self) -> None:
        chunk: list[np.ndarray] = []
        chunk_t0 = 0.0
        while not self._stop.is_set():
            batch = self.next_batch(timeout_polls=50)
            now = time.monotonic()
            if batch is not None:
                if not chunk:
                    chunk_t0 = now
                chunk.append(batch)
            if chunk and (batch is None
                          or len(chunk) >= self._chunk_batches
                          or now - chunk_t0 >= self._chunk_linger):
                self._prefetch.offer(_BatchEnvelope(chunk), block=True)
                chunk = []

    def get_prefetched(self, timeout: float = 30.0) -> np.ndarray | None:
        """Pop the next ready batch, unpacking whole prefetched chunks into a
        caller-local buffer — one queue round-trip amortized over up to
        ``prefetch_chunk`` batches."""
        if not self._drained:
            for env in self._prefetch.poll_batch(
                    self._prefetch.object_threshold, timeout=timeout):
                self._drained.extend(env.batches)
        return self._drained.popleft() if self._drained else None

    def stop(self) -> None:
        self._stop.set()
        for t in self._threads:
            t.join(timeout=5)
        self._threads.clear()

    # ------------------------------------------------------------------
    # Exactly-once checkpoint state
    # ------------------------------------------------------------------
    def state(self) -> dict:
        with self._state_lock:
            return {
                "positions": {str(k): int(v)
                              for k, v in self.consumer.positions().items()},
                "packer": self.packer.state(),
                "pending_rows": [r.tolist() for r in self._rows],
                "batches_emitted": self._batches_emitted,
            }

    def restore(self, state: dict) -> None:
        with self._state_lock:
            self.consumer.restore({int(k): int(v)
                                   for k, v in state["positions"].items()})
            self.packer.restore(state["packer"])
            self._rows = [np.asarray(r, dtype=np.int32)
                          for r in state.get("pending_rows", [])]
            self._batches_emitted = int(state.get("batches_emitted", 0))

    def commit(self) -> None:
        """At-least-once boundary for non-checkpoint consumers."""
        self.consumer.commit()

    @property
    def batches_emitted(self) -> int:
        return self._batches_emitted

    @property
    def starved_polls(self) -> int:
        """Times the loader polled an empty stream — the 'ingestion is the
        bottleneck' signal surfaced to the trainer's metrics."""
        return self._starved_polls


class _BatchEnvelope:
    """Duck-typed FlowFile stand-in so a chunk of assembled batches rides the
    backpressured Connection without serialization (zero-copy)."""

    __slots__ = ("batches",)

    def __init__(self, batches: list[np.ndarray]) -> None:
        self.batches = batches

    @property
    def size(self) -> int:
        return sum(int(b.nbytes) for b in self.batches)
