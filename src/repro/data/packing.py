"""Sequence packing: variable-length documents → fixed (seq_len+1) rows.

Documents are concatenated (each already carries BOS/EOS from the tokenizer)
and sliced into rows of ``seq_len + 1`` tokens; the training step uses
``row[:-1]`` as inputs and ``row[1:]`` as labels. A carry buffer holds the
tail tokens between calls, and is part of the packer's checkpointable state —
together with the consumer offsets this makes the stream→batch mapping
exactly reproducible after restart.
"""
from __future__ import annotations

import numpy as np


class SequencePacker:
    def __init__(self, seq_len: int, pad_id: int) -> None:
        self.seq_len = seq_len
        self.pad_id = pad_id
        self._carry: list[int] = []

    @property
    def row_len(self) -> int:
        return self.seq_len + 1

    def add_document(self, ids) -> list[np.ndarray]:
        """Feed one tokenized document; return zero or more full rows."""
        self._carry.extend(int(i) for i in ids)
        rows = []
        while len(self._carry) >= self.row_len:
            rows.append(np.asarray(self._carry[:self.row_len], dtype=np.int32))
            del self._carry[:self.row_len]
        return rows

    def add_tokens(self, ids: np.ndarray) -> np.ndarray:
        """Vectorized path: feed the concatenated ids of *many* documents at
        once (``ByteTokenizer.encode_batch`` output) and slice all full rows
        with one reshape instead of per-token list churn. Produces exactly
        the rows the per-document ``add_document`` loop would, in order."""
        ids = np.asarray(ids, dtype=np.int32)
        if self._carry:
            ids = np.concatenate(
                [np.asarray(self._carry, dtype=np.int32), ids])
        n_rows = len(ids) // self.row_len
        rows = ids[:n_rows * self.row_len].reshape(n_rows, self.row_len)
        self._carry = ids[n_rows * self.row_len:].tolist()
        return rows

    def flush(self) -> np.ndarray | None:
        """Pad-and-emit the carry (end of stream / eval only — training keeps
        packing so no pad tokens ever enter a training row)."""
        if not self._carry:
            return None
        row = np.full(self.row_len, self.pad_id, dtype=np.int32)
        row[:len(self._carry)] = self._carry
        self._carry.clear()
        return row

    # -- checkpointable state -------------------------------------------------
    def state(self) -> dict:
        return {"carry": list(self._carry)}

    def restore(self, state: dict) -> None:
        self._carry = [int(x) for x in state.get("carry", [])]
