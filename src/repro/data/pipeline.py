"""End-to-end pipeline builder — the paper's Fig. 2 topology as one call.

sources (RSS + firehose + websocket) → parse/filter → dedup → enrich →
route → PublishToLog(topic) ; consumers (training loaders / file sinks)
attach to the topic as consumer groups.

``build_news_fabric`` shards the same topology over N worker *processes*
(``core/fabric.py``): each shard group runs a vertical slice — its own
seeded sources, parser, dedup, enrich, route — and publishes into a
disjoint partition subset of the shared topics through the socket-
transported log. ``build_fabric_news_worker`` is the factory the worker
processes resolve by dotted path to rebuild their slice.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path

from ..core import (ConsumerGroup, DeadLetterQueue, DetectDuplicate,
                    ExecuteScript, FlowGraph, LogStore, LookupEnrich,
                    PartitionedLog, PublishToLog, ReplicatedLog,
                    RestartPolicy, RouteOnAttribute,
                    RssAggregatorSource, FirehoseSource, Source,
                    WebSocketSource, WindowedAggregate)
from ..core.acquisition import (AcquisitionRuntime, ConnectorPolicy,
                                SimulatedEndpoint, SourceConnector)
from ..core.fabric import IngestionFabric
from ..core.flowfile import FlowFile
from ..core.net_connectors import HttpPollConnector, WebSocketConnector
from ..core.delivery import Consumer
from .loader import StreamingDataLoader

SOURCE_REGIONS = {
    "reuters": {"region": "uk"}, "ap": {"region": "us"},
    "afp": {"region": "fr"}, "bbc": {"region": "uk"},
    "cbc": {"region": "ca"}, "nhk": {"region": "jp"},
    "dw": {"region": "de"}, "abc": {"region": "au"},
}


def build_news_pipeline(root: str | Path, *, n_rss: int = 2000,
                        n_firehose: int = 2000, n_ws: int = 500,
                        partitions: int = 8, dedup_mode: str = "exact",
                        seed: int = 0,
                        route_sample: int = 1,
                        restart_policy: RestartPolicy | None = None,
                        max_retries: int = 0,
                        dead_letter_topic: str | None = None,
                        durable: bool = False,
                        poison_rate: float = 0.0,
                        replicas: int = 1,
                        acks: str = "all",
                        live: bool | str = False,
                        live_policy: ConnectorPolicy | None = None,
                        congestion_mode: str | None = None,
                        priorities: dict[str, int] | None = None,
                        elastic_workers: dict[str, tuple[int, int]] | None = None,
                        ooo_window: int = 4,
                        redelivery: int = 4,
                        socket_endpoints: dict[str, tuple] | None = None,
                        window_sec: float | None = None,
                        workers: int = 1,
                        telemetry: bool = True,
                        trace_sample_rate: float = 0.0
                        ) -> tuple[FlowGraph, LogStore]:
    """The paper §IV case study: returns (flow, log) with topic ``articles``
    (clean, deduped, enriched news) and topic ``events`` (websocket feed).

    Fault-tolerance knobs (all off by default — the seed topology):
    ``restart_policy`` supervises every non-source processor;
    ``max_retries`` arms record retry on every interior connection;
    ``dead_letter_topic`` wires a ``DeadLetterQueue`` quarantine;
    ``durable`` makes the interior connections WAL-backed through ``log``;
    ``poison_rate`` makes the RSS source emit records the enrich stage can be
    made to choke on (see ``faults.raise_on``);
    ``replicas``/``acks`` land everything (topics, WAL, quarantine) in an
    N-replica ``ReplicatedLog`` instead of the single-host store, so the
    landed stream survives replica loss (``replicas=1`` keeps the
    single-store hot path).

    ``live=True`` replaces the synchronous in-process ``Source`` processors
    with an :class:`AcquisitionRuntime` (``flow.acquisition``) driving three
    :class:`SimulatedEndpoint` connectors — RSS and firehose into the
    parser, websocket into the events sink — with reconnect-with-backoff,
    cursor checkpoints in the log (topic ``__acq__.news``; rebuilding over
    the same ``root`` resumes), bounded out-of-order delivery
    (``ooo_window``), reconnect redelivery (``redelivery``), and per-
    connector watermarks; late records land in topic ``late`` via a
    dedicated sink. Run a live flow with
    ``flow.acquisition.run_with_flow(timeout)`` instead of
    ``flow.run_to_completion``.

    ``live="socket"`` goes wire-real: the same topology is fed by the
    first-class network connectors (``core/net_connectors.py``) — an
    HTTP/RSS cursor-feed long-poller for the article sources and an RFC
    6455 WebSocket client for the event feed — against the endpoints named
    in ``socket_endpoints`` (``{"big-rss": ("http", host, port),
    "twitter": ("http", host, port), "websocket": ("ws", host, port)}``;
    the in-repo servers live in ``tests/net_fixtures.py``). Everything
    else — runtime, reconnect backoff, checkpoints, watermarks, WAL —
    is byte-for-byte the machinery the simulated endpoints run on. Note
    the stream *content* then comes from the remote servers: the size
    knobs (``n_rss``/``n_firehose``/``n_ws``) and ``ooo_window``/
    ``redelivery`` only shape the in-process generators and simulated
    endpoints, so in socket mode they serve ground-truth bookkeeping
    (``expected_clean_doc_ids``) and must match the parameters the feed
    servers were built with (see ``bench_socket_acquisition._build``).

    Overload knobs (all live modes): ``congestion_mode`` overrides the
    connectors' congestion response (``block``/``throttle``/``shed``/
    ``spill``, see :class:`~repro.core.acquisition.ConnectorPolicy`);
    ``priorities`` maps connector names to admission priority classes
    (``{"big-rss": 2, "twitter": 1}`` — higher delivered first, shed
    last); ``elastic_workers`` maps interior stage names to ``(min, max)``
    elastic worker-pool bounds (``{"enrich": (1, 4)}`` — incompatible with
    ``durable=True``, which makes every interior input FIFO-prefix-acked).

    Telemetry (on by default, within the 2%-overhead budget):
    ``telemetry=False`` strips every per-stage latency histogram from the
    hot path (the overhead guard's A/B baseline); ``trace_sample_rate=r``
    stamps roughly every ``1/r``-th admitted record with a ``trace.id``
    attribute and records per-stage span events into provenance —
    ``flow.trace_spans(trace_id)`` rebuilds the timed span tree.

    ``window_sec`` (any live mode; defaults to 64 event-time seconds when
    ``live="socket"``) adds the watermark-driven aggregation stage: a
    :class:`~repro.core.windows.WindowedAggregate` fans out from the
    enrich stage, closes tumbling event-time windows only when the
    fabric-wide low watermark passes them, lands them in topic
    ``windows`` and routes stragglers to the existing ``late`` topic.

    ``workers=N`` (N > 1) switches to the multi-process fabric: the return
    value is ``(fabric, fabric.store)`` where ``fabric`` is an unstarted
    :class:`~repro.core.fabric.IngestionFabric` — drive it with
    ``fabric.start()`` / ``fabric.wait()`` (see :func:`build_news_fabric`
    for the knobs that matter there; options specific to the in-process
    topology — ``live``/``replicas``/``window_sec``/… — do not apply)."""
    root = Path(root)
    if workers > 1:
        fabric = build_news_fabric(
            root, workers=workers, n_rss=n_rss, n_firehose=n_firehose,
            n_ws=n_ws, partitions=partitions, dedup_mode=dedup_mode,
            seed=seed, poison_rate=poison_rate, durable=durable,
            max_retries=max_retries, ooo_window=ooo_window,
            redelivery=redelivery)
        return fabric, fabric.store
    if window_sec and not live:
        raise ValueError(
            "window_sec requires a live acquisition mode (live=True or "
            "live='socket'): the window stage closes off the event-time "
            "clock the acquisition runtime maintains")
    log: LogStore
    if replicas > 1:
        log = ReplicatedLog(root / "log", replicas=replicas, acks=acks)
    else:
        log = PartitionedLog(root / "log")
    log.create_topic("articles", partitions=partitions)
    log.create_topic("events", partitions=max(1, partitions // 4))

    from ..core import ProvenanceRepository
    g = FlowGraph("news-pipeline",
                  provenance=ProvenanceRepository(route_sample=route_sample),
                  telemetry=telemetry, trace_sample_rate=trace_sample_rate)
    conn_kw = {"max_retries": max_retries} if max_retries else {}
    if durable:
        conn_kw["durable"] = log
    add_kw = {"restart_policy": restart_policy} if restart_policy else {}

    def pool_kw(stage: str) -> dict:
        if not elastic_workers or stage not in elastic_workers:
            return {}
        lo, hi = elastic_workers[stage]
        return {"min_workers": lo, "max_workers": hi}
    rss_gen = RssAggregatorSource(n_rss, seed=seed, poison_rate=poison_rate)
    fire_gen = FirehoseSource(n_firehose, seed=seed + 1)
    ws_gen = WebSocketSource(n_ws, seed=seed + 2)
    if not live:
        rss = g.add(Source("big-rss", rss_gen), **add_kw)
        fire = g.add(Source("twitter", fire_gen), **add_kw)
        ws = g.add(Source("websocket", ws_gen), **add_kw)

    def parse(ff):
        try:
            doc = ff.json()
        except (ValueError, UnicodeDecodeError):
            return None                                  # junk → DROP
        text = doc.get("title", "")
        body = doc.get("body") or doc.get("text") or ""
        if not body:
            return None
        return ff.with_attributes(
            doc_id=str(doc.get("id", "")),
            lang=str(doc.get("lang", "")),
            text=(text + " " + body).strip())
    parser = g.add(ExecuteScript("parse", parse), **add_kw,
                   **pool_kw("parse"))

    dedup = g.add(DetectDuplicate(
        "dedup", mode=dedup_mode,
        key_fn=lambda ff: ff.attributes.get("text", "").encode()), **add_kw)

    enrich = g.add(LookupEnrich(
        "enrich", SOURCE_REGIONS,
        key_fn=lambda ff: ff.attributes.get("origin", "")), **add_kw,
        **pool_kw("enrich"))

    route = g.add(RouteOnAttribute("route", {
        "en": lambda ff: ff.attributes.get("lang") == "en",
        "other": lambda ff: True,
    }), **add_kw, **pool_kw("route"))

    pub_articles = g.add(PublishToLog("pub-articles", log, "articles"),
                         **add_kw)
    pub_events = g.add(PublishToLog("pub-events", log, "events"), **add_kw)

    if not live:
        g.connect(rss, "success", parser, **conn_kw)
        g.connect(fire, "success", parser)
        g.connect(ws, "success", pub_events, **conn_kw)
    else:
        # live acquisition: endpoints behind reconnecting poll loops feed
        # the same interior topology through ingress queues; late records
        # get their own landing topic instead of merging silently
        log.create_topic("late", partitions=1)
        pub_late = g.add(PublishToLog("pub-late", log, "late"), **add_kw)
        rt = AcquisitionRuntime(g, log, name="news")
        pol = live_policy or ConnectorPolicy(
            restart=RestartPolicy(max_restarts=1_000,
                                  backoff_base_sec=0.002,
                                  backoff_cap_sec=0.05),
            checkpoint_every_records=256,
            lateness_sec=4.0 * max(ooo_window, redelivery, 1))
        if congestion_mode is not None:
            pol = dataclasses.replace(pol, congestion_mode=congestion_mode)
        ingress_kw = {"durable": log} if durable else {}
        if max_retries:
            ingress_kw["max_retries"] = max_retries
        if live == "socket":
            connectors = [(_socket_connector(n, socket_endpoints), d)
                          for n, d in (("big-rss", parser),
                                       ("twitter", parser),
                                       ("websocket", pub_events))]
            if window_sec is None:
                window_sec = 64.0
        else:
            connectors = [
                (SimulatedEndpoint("big-rss", rss_gen, total=n_rss,
                                   ooo_window=ooo_window,
                                   redelivery=redelivery), parser),
                (SimulatedEndpoint("twitter", fire_gen, total=n_firehose,
                                   ooo_window=ooo_window,
                                   redelivery=redelivery), parser),
                (SimulatedEndpoint("websocket", ws_gen, total=n_ws,
                                   ooo_window=ooo_window,
                                   redelivery=redelivery), pub_events)]
        for ep, dest in connectors:
            rt.add_connector(ep, dest, policy=pol, late_dest=pub_late,
                             priority=(priorities or {}).get(ep.name, 0),
                             **ingress_kw)
        if window_sec:
            # watermark-driven aggregation stage: tumbling event-time
            # windows over the enriched article stream, closed only when
            # the fabric-wide low watermark passes them (idle-triggered,
            # so closes fire off OTHER connectors' progress too)
            log.create_topic("windows", partitions=1)
            pub_windows = g.add(PublishToLog("pub-windows", log, "windows"),
                                **add_kw)
            # the two article feeds are declared so a feed that finishes
            # before its records traverse to the window stage still gates
            # closes; the websocket connector routes to pub-events and is
            # deliberately NOT declared (it only bounds the clock while
            # active)
            windows = g.add(WindowedAggregate(
                "windows", rt.clock, window_sec,
                sources=("big-rss", "twitter")), **add_kw)
            g.connect(enrich, "success", windows, **conn_kw)
            g.connect(windows, "success", pub_windows, **conn_kw)
            g.connect(windows, "late", pub_late)
    g.connect(parser, "success", dedup, **conn_kw)
    g.connect(dedup, "unique", enrich, **conn_kw)
    g.connect(enrich, "success", route, **conn_kw)
    g.connect(route, "en", pub_articles, **conn_kw)
    g.connect(route, "other", pub_articles)   # all langs land, tagged
    if dead_letter_topic:
        dlq = g.add(DeadLetterQueue("dead-letter", log,
                                    topic=dead_letter_topic))
        g.route_dead_letters_to(dlq)
    return g, log


def _socket_connector(name: str,
                      endpoints: dict[str, tuple] | None) -> SourceConnector:
    """Build the wire-real connector for one named case-study source from a
    ``{"<name>": ("http"|"ws", host, port)}`` endpoint map."""
    if not endpoints or name not in endpoints:
        raise ValueError(
            f"live='socket' needs socket_endpoints[{name!r}] = "
            "('http'|'ws', host, port); start the in-repo feed servers "
            "(tests/net_fixtures.py) and pass their addresses")
    kind, host, port = endpoints[name]
    if kind == "http":
        return HttpPollConnector(name, host, int(port))
    if kind == "ws":
        return WebSocketConnector(name, host, int(port))
    raise ValueError(f"unknown socket endpoint kind {kind!r} for {name!r}")


def arm_news_chaos(*, crash_every: int = 500, source_nth: int = 4,
                   source_every: int = 8) -> None:
    """Arm the case study's standard chaos mix on the process-wide injector:
    the enrich stage chokes on poison records AND raises every
    ~``crash_every`` records (both absorbed by the retry machinery), while
    the RSS source — which has no input connection — raises on a trigger
    schedule, exercising the supervisor restart + replayable-generator
    fast-forward path. Caller must ``INJECTOR.reset()`` afterwards."""
    from ..core.faults import (INJECTOR, compose, raise_every_records,
                               raise_on)
    INJECTOR.arm("proc.enrich", compose(
        raise_on(lambda ff: ff.attributes.get("kind") == "poison",
                 "poison record"),
        raise_every_records(crash_every)), every=1)
    INJECTOR.arm("proc.big-rss", "raise", nth=source_nth, every=source_every)


def expected_clean_doc_ids(n_rss: int, seed: int,
                           poison_rate: float) -> set[str]:
    """Replay the seeded RSS source: the doc ids of every non-junk,
    non-poison article (duplicates collapse into the set) — the ground truth
    the zero-record-loss acceptance checks the landed topic against."""
    out: set[str] = set()
    for ff in RssAggregatorSource(n_rss, seed=seed,
                                  poison_rate=poison_rate)():
        if ff.attributes.get("kind") == "article":
            out.add(str(json.loads(ff.content)["id"]))
    return out


# ---------------------------------------------------------------------------
# multi-process fabric mode (core/fabric.py)
# ---------------------------------------------------------------------------

def fabric_shard_specs(*, workers: int, n_rss: int = 2000,
                       n_firehose: int = 2000, n_ws: int = 500,
                       partitions: int = 8, dedup_mode: str = "exact",
                       seed: int = 0, poison_rate: float = 0.0,
                       durable: bool = False, max_retries: int = 0,
                       ooo_window: int = 4, redelivery: int = 4,
                       timeout_sec: float = 300.0) -> list[dict]:
    """Split the news case study into ``workers`` shard-group specs.

    Each group ``g<i>`` gets a share of every source (distinct seeds, so the
    shards are independent feeds), a disjoint subset of the shared topics'
    partitions (articles: round-robin over ``max(partitions, workers)``;
    events/late: one partition per group) and its own checkpoint topic. The
    ``partitions`` map in each spec is exactly the fence unit a takeover
    advances — WAL topics are intentionally absent from it (see
    ``core/fabric.py``)."""
    if workers < 1:
        raise ValueError("workers must be >= 1")
    n_articles = max(partitions, workers)
    topics = {"articles": n_articles, "events": workers, "late": workers}

    def share(total: int, i: int) -> int:
        return total // workers + (1 if i < total % workers else 0)

    shards = []
    for i in range(workers):
        gid = f"g{i}"
        shards.append({
            "group": gid,
            "factory": "repro.data.pipeline:build_fabric_news_worker",
            "partitions": {
                "articles": [p for p in range(n_articles)
                             if p % workers == i],
                "events": [i],
                "late": [i],
                f"__acq__.news.{gid}": [0],
            },
            "timeout_sec": timeout_sec,
            "kwargs": {
                "n_rss": share(n_rss, i),
                "n_firehose": share(n_firehose, i),
                "n_ws": share(n_ws, i),
                "seed": seed + 1000 * i,
                "dedup_mode": dedup_mode,
                "poison_rate": poison_rate,
                "durable": durable,
                "max_retries": max_retries,
                "ooo_window": ooo_window,
                "redelivery": redelivery,
                "topics": topics,
            },
        })
    return shards


def build_news_fabric(root: str | Path, *, workers: int = 2,
                      heartbeat_sec: float = 0.2,
                      lease_timeout_sec: float = 2.0,
                      group_timeout_sec: float = 300.0,
                      **spec_kw) -> IngestionFabric:
    """Fabric mode of the case study: the coordinator store + topics plus an
    **unstarted** :class:`~repro.core.fabric.IngestionFabric` over
    ``workers`` processes. ``spec_kw`` forwards to
    :func:`fabric_shard_specs` (``n_rss=…``, ``durable=True`` for the
    crash-safety scenario, …). Call ``.start()`` then ``.wait()``; consume
    the landed topics from ``fabric.store`` afterwards."""
    root = Path(root)
    shards = fabric_shard_specs(
        workers=workers, timeout_sec=group_timeout_sec, **spec_kw)
    store = PartitionedLog(root / "log")
    for topic, nparts in shards[0]["kwargs"]["topics"].items():
        store.create_topic(topic, partitions=nparts)
    return IngestionFabric(root, store, shards=shards, workers=workers,
                           name="news-fabric",
                           heartbeat_sec=heartbeat_sec,
                           lease_timeout_sec=lease_timeout_sec,
                           group_timeout_sec=group_timeout_sec)


def build_fabric_news_worker(log: LogStore,
                             spec: dict) -> tuple[FlowGraph, AcquisitionRuntime]:
    """Worker-side factory (resolved by dotted path inside the worker
    process): rebuild one shard group's slice of the news topology against
    the coordinator's log, reached through ``RemoteLogStore``.

    Processor names carry the group id so per-group state topics (ingress
    WAL ``__wal__.__ingress__->parse.<gid>``, checkpoints
    ``__acq__.news.<gid>``) never collide across groups; the publish sinks
    are pinned to the group's owned partition subsets and stamped with an
    epoch-qualified producer id, so a fenced zombie's retries can never
    duplicate records under the new lease."""
    gid, epoch, kw = spec["group"], spec["epoch"], spec["kwargs"]
    owned = spec["partitions"]
    for topic, nparts in kw["topics"].items():
        log.create_topic(topic, partitions=nparts)   # idempotent

    from ..core import ProvenanceRepository
    g = FlowGraph(f"news-{gid}", provenance=ProvenanceRepository())

    def parse(ff):
        try:
            doc = ff.json()
        except (ValueError, UnicodeDecodeError):
            return None                                  # junk → DROP
        text = doc.get("title", "")
        body = doc.get("body") or doc.get("text") or ""
        if not body:
            return None
        return ff.with_attributes(
            doc_id=str(doc.get("id", "")),
            lang=str(doc.get("lang", "")),
            text=(text + " " + body).strip())

    parser = g.add(ExecuteScript(f"parse.{gid}", parse))
    dedup = g.add(DetectDuplicate(
        f"dedup.{gid}", mode=kw["dedup_mode"],
        key_fn=lambda ff: ff.attributes.get("text", "").encode()))
    enrich = g.add(LookupEnrich(
        f"enrich.{gid}", SOURCE_REGIONS,
        key_fn=lambda ff: ff.attributes.get("origin", "")))
    route = g.add(RouteOnAttribute(f"route.{gid}", {
        "en": lambda ff: ff.attributes.get("lang") == "en",
        "other": lambda ff: True,
    }))
    pid = f"{gid}.e{epoch}"
    pub_articles = g.add(PublishToLog(
        f"pub-articles.{gid}", log, "articles",
        partitions=owned["articles"], producer_id=f"{pid}.articles"))
    pub_events = g.add(PublishToLog(
        f"pub-events.{gid}", log, "events",
        partitions=owned["events"], producer_id=f"{pid}.events"))
    pub_late = g.add(PublishToLog(
        f"pub-late.{gid}", log, "late",
        partitions=owned["late"], producer_id=f"{pid}.late"))

    rt = AcquisitionRuntime(g, log, name=f"news.{gid}")
    pol = ConnectorPolicy(
        restart=RestartPolicy(max_restarts=1_000, backoff_base_sec=0.002,
                              backoff_cap_sec=0.05),
        checkpoint_every_records=256,
        lateness_sec=4.0 * max(kw["ooo_window"], kw["redelivery"], 1))
    # durable covers the whole path, as in the single-process builder: the
    # ingress WAL alone would still lose records sitting in interior queues
    # when a worker is killed
    ingress_kw: dict = {}
    conn_kw: dict = {}
    if kw["durable"]:
        ingress_kw["durable"] = log
        conn_kw["durable"] = log
    if kw["max_retries"]:
        ingress_kw["max_retries"] = kw["max_retries"]
        conn_kw["max_retries"] = kw["max_retries"]
    seed = kw["seed"]
    # generator names carry the group id too: the ``source`` attribute
    # survives into the landed records, giving the acceptance check an
    # exact per-shard ground truth even when doc ids collide across seeds
    feeds = [
        (SimulatedEndpoint(
            "big-rss",
            RssAggregatorSource(kw["n_rss"], seed=seed,
                                poison_rate=kw["poison_rate"],
                                name=f"big-rss.{gid}"),
            total=kw["n_rss"], ooo_window=kw["ooo_window"],
            redelivery=kw["redelivery"]), parser),
        (SimulatedEndpoint(
            "twitter",
            FirehoseSource(kw["n_firehose"], seed=seed + 1,
                           name=f"twitter.{gid}"),
            total=kw["n_firehose"], ooo_window=kw["ooo_window"],
            redelivery=kw["redelivery"]), parser),
        (SimulatedEndpoint(
            "websocket",
            WebSocketSource(kw["n_ws"], seed=seed + 2,
                            name=f"websocket.{gid}"),
            total=kw["n_ws"], ooo_window=kw["ooo_window"],
            redelivery=kw["redelivery"]), pub_events),
    ]
    for ep, dest in feeds:
        rt.add_connector(ep, dest, policy=pol, late_dest=pub_late,
                         **ingress_kw)
    g.connect(parser, "success", dedup, **conn_kw)
    g.connect(dedup, "unique", enrich, **conn_kw)
    g.connect(enrich, "success", route, **conn_kw)
    g.connect(route, "en", pub_articles, **conn_kw)
    g.connect(route, "other", pub_articles)
    return g, rt


def expected_fabric_doc_ids(shards: list[dict]) -> dict[str, set[str]]:
    """Per-shard ground truth for the fabric acceptance: ``{group: set of
    clean article doc ids that must land}`` (each shard replayed with its
    own seed/size/poison parameters)."""
    return {s["group"]: expected_clean_doc_ids(
        s["kwargs"]["n_rss"], s["kwargs"]["seed"],
        s["kwargs"]["poison_rate"]) for s in shards}


def landed_doc_ids_by_shard(store: LogStore, topic: str = "articles"
                            ) -> tuple[dict[str, set[str]], dict[str, int]]:
    """Scan the landed topic and split it by originating shard (the
    ``source`` attribute is ``big-rss.<gid>``). Returns ``({group: ids},
    {group: total article records})`` — the second map exposes duplicates
    (records minus unique ids)."""
    ids: dict[str, set[str]] = {}
    counts: dict[str, int] = {}
    for p in range(store.num_partitions(topic)):
        for rec in store.iter_records(topic, p):
            ff = FlowFile.from_record(rec.key, rec.value)
            src = ff.attributes.get("source", "")
            if not src.startswith("big-rss.") or \
                    ff.attributes.get("kind") != "article":
                continue
            gid = src.split(".", 1)[1]
            ids.setdefault(gid, set()).add(ff.attributes.get("doc_id", ""))
            counts[gid] = counts.get(gid, 0) + 1
    return ids, counts


def attach_training_loader(log: LogStore, *, topic: str = "articles",
                           group: str = "trainer", member: str = "host0",
                           batch_size: int = 8, seq_len: int = 256,
                           **kw) -> tuple[ConsumerGroup, StreamingDataLoader]:
    grp = ConsumerGroup(log, topic, group)
    consumer = grp.add_member(member)
    loader = StreamingDataLoader(
        consumer, batch_size=batch_size, seq_len=seq_len,
        text_fn=lambda ff: ff.attributes.get("text", ff.text()), **kw)
    return grp, loader
