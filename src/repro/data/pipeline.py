"""End-to-end pipeline builder — the paper's Fig. 2 topology as one call.

sources (RSS + firehose + websocket) → parse/filter → dedup → enrich →
route → PublishToLog(topic) ; consumers (training loaders / file sinks)
attach to the topic as consumer groups.
"""
from __future__ import annotations

import json
from pathlib import Path

from ..core import (ConsumerGroup, DeadLetterQueue, DetectDuplicate,
                    ExecuteScript, FlowGraph, LogStore, LookupEnrich,
                    PartitionedLog, PublishToLog, ReplicatedLog,
                    RestartPolicy, RouteOnAttribute,
                    RssAggregatorSource, FirehoseSource, Source,
                    WebSocketSource, WindowedAggregate)
from ..core.acquisition import (AcquisitionRuntime, ConnectorPolicy,
                                SimulatedEndpoint, SourceConnector)
from ..core.net_connectors import HttpPollConnector, WebSocketConnector
from ..core.delivery import Consumer
from .loader import StreamingDataLoader

SOURCE_REGIONS = {
    "reuters": {"region": "uk"}, "ap": {"region": "us"},
    "afp": {"region": "fr"}, "bbc": {"region": "uk"},
    "cbc": {"region": "ca"}, "nhk": {"region": "jp"},
    "dw": {"region": "de"}, "abc": {"region": "au"},
}


def build_news_pipeline(root: str | Path, *, n_rss: int = 2000,
                        n_firehose: int = 2000, n_ws: int = 500,
                        partitions: int = 8, dedup_mode: str = "exact",
                        seed: int = 0,
                        route_sample: int = 1,
                        restart_policy: RestartPolicy | None = None,
                        max_retries: int = 0,
                        dead_letter_topic: str | None = None,
                        durable: bool = False,
                        poison_rate: float = 0.0,
                        replicas: int = 1,
                        acks: str = "all",
                        live: bool | str = False,
                        live_policy: ConnectorPolicy | None = None,
                        ooo_window: int = 4,
                        redelivery: int = 4,
                        socket_endpoints: dict[str, tuple] | None = None,
                        window_sec: float | None = None
                        ) -> tuple[FlowGraph, LogStore]:
    """The paper §IV case study: returns (flow, log) with topic ``articles``
    (clean, deduped, enriched news) and topic ``events`` (websocket feed).

    Fault-tolerance knobs (all off by default — the seed topology):
    ``restart_policy`` supervises every non-source processor;
    ``max_retries`` arms record retry on every interior connection;
    ``dead_letter_topic`` wires a ``DeadLetterQueue`` quarantine;
    ``durable`` makes the interior connections WAL-backed through ``log``;
    ``poison_rate`` makes the RSS source emit records the enrich stage can be
    made to choke on (see ``faults.raise_on``);
    ``replicas``/``acks`` land everything (topics, WAL, quarantine) in an
    N-replica ``ReplicatedLog`` instead of the single-host store, so the
    landed stream survives replica loss (``replicas=1`` keeps the
    single-store hot path).

    ``live=True`` replaces the synchronous in-process ``Source`` processors
    with an :class:`AcquisitionRuntime` (``flow.acquisition``) driving three
    :class:`SimulatedEndpoint` connectors — RSS and firehose into the
    parser, websocket into the events sink — with reconnect-with-backoff,
    cursor checkpoints in the log (topic ``__acq__.news``; rebuilding over
    the same ``root`` resumes), bounded out-of-order delivery
    (``ooo_window``), reconnect redelivery (``redelivery``), and per-
    connector watermarks; late records land in topic ``late`` via a
    dedicated sink. Run a live flow with
    ``flow.acquisition.run_with_flow(timeout)`` instead of
    ``flow.run_to_completion``.

    ``live="socket"`` goes wire-real: the same topology is fed by the
    first-class network connectors (``core/net_connectors.py``) — an
    HTTP/RSS cursor-feed long-poller for the article sources and an RFC
    6455 WebSocket client for the event feed — against the endpoints named
    in ``socket_endpoints`` (``{"big-rss": ("http", host, port),
    "twitter": ("http", host, port), "websocket": ("ws", host, port)}``;
    the in-repo servers live in ``tests/net_fixtures.py``). Everything
    else — runtime, reconnect backoff, checkpoints, watermarks, WAL —
    is byte-for-byte the machinery the simulated endpoints run on. Note
    the stream *content* then comes from the remote servers: the size
    knobs (``n_rss``/``n_firehose``/``n_ws``) and ``ooo_window``/
    ``redelivery`` only shape the in-process generators and simulated
    endpoints, so in socket mode they serve ground-truth bookkeeping
    (``expected_clean_doc_ids``) and must match the parameters the feed
    servers were built with (see ``bench_socket_acquisition._build``).

    ``window_sec`` (any live mode; defaults to 64 event-time seconds when
    ``live="socket"``) adds the watermark-driven aggregation stage: a
    :class:`~repro.core.windows.WindowedAggregate` fans out from the
    enrich stage, closes tumbling event-time windows only when the
    fabric-wide low watermark passes them, lands them in topic
    ``windows`` and routes stragglers to the existing ``late`` topic."""
    root = Path(root)
    if window_sec and not live:
        raise ValueError(
            "window_sec requires a live acquisition mode (live=True or "
            "live='socket'): the window stage closes off the event-time "
            "clock the acquisition runtime maintains")
    log: LogStore
    if replicas > 1:
        log = ReplicatedLog(root / "log", replicas=replicas, acks=acks)
    else:
        log = PartitionedLog(root / "log")
    log.create_topic("articles", partitions=partitions)
    log.create_topic("events", partitions=max(1, partitions // 4))

    from ..core import ProvenanceRepository
    g = FlowGraph("news-pipeline",
                  provenance=ProvenanceRepository(route_sample=route_sample))
    conn_kw = {"max_retries": max_retries} if max_retries else {}
    if durable:
        conn_kw["durable"] = log
    add_kw = {"restart_policy": restart_policy} if restart_policy else {}
    rss_gen = RssAggregatorSource(n_rss, seed=seed, poison_rate=poison_rate)
    fire_gen = FirehoseSource(n_firehose, seed=seed + 1)
    ws_gen = WebSocketSource(n_ws, seed=seed + 2)
    if not live:
        rss = g.add(Source("big-rss", rss_gen), **add_kw)
        fire = g.add(Source("twitter", fire_gen), **add_kw)
        ws = g.add(Source("websocket", ws_gen), **add_kw)

    def parse(ff):
        try:
            doc = ff.json()
        except (ValueError, UnicodeDecodeError):
            return None                                  # junk → DROP
        text = doc.get("title", "")
        body = doc.get("body") or doc.get("text") or ""
        if not body:
            return None
        return ff.with_attributes(
            doc_id=str(doc.get("id", "")),
            lang=str(doc.get("lang", "")),
            text=(text + " " + body).strip())
    parser = g.add(ExecuteScript("parse", parse), **add_kw)

    dedup = g.add(DetectDuplicate(
        "dedup", mode=dedup_mode,
        key_fn=lambda ff: ff.attributes.get("text", "").encode()), **add_kw)

    enrich = g.add(LookupEnrich(
        "enrich", SOURCE_REGIONS,
        key_fn=lambda ff: ff.attributes.get("origin", "")), **add_kw)

    route = g.add(RouteOnAttribute("route", {
        "en": lambda ff: ff.attributes.get("lang") == "en",
        "other": lambda ff: True,
    }), **add_kw)

    pub_articles = g.add(PublishToLog("pub-articles", log, "articles"),
                         **add_kw)
    pub_events = g.add(PublishToLog("pub-events", log, "events"), **add_kw)

    if not live:
        g.connect(rss, "success", parser, **conn_kw)
        g.connect(fire, "success", parser)
        g.connect(ws, "success", pub_events, **conn_kw)
    else:
        # live acquisition: endpoints behind reconnecting poll loops feed
        # the same interior topology through ingress queues; late records
        # get their own landing topic instead of merging silently
        log.create_topic("late", partitions=1)
        pub_late = g.add(PublishToLog("pub-late", log, "late"), **add_kw)
        rt = AcquisitionRuntime(g, log, name="news")
        pol = live_policy or ConnectorPolicy(
            restart=RestartPolicy(max_restarts=1_000,
                                  backoff_base_sec=0.002,
                                  backoff_cap_sec=0.05),
            checkpoint_every_records=256,
            lateness_sec=4.0 * max(ooo_window, redelivery, 1))
        ingress_kw = {"durable": log} if durable else {}
        if max_retries:
            ingress_kw["max_retries"] = max_retries
        if live == "socket":
            connectors = [(_socket_connector(n, socket_endpoints), d)
                          for n, d in (("big-rss", parser),
                                       ("twitter", parser),
                                       ("websocket", pub_events))]
            if window_sec is None:
                window_sec = 64.0
        else:
            connectors = [
                (SimulatedEndpoint("big-rss", rss_gen, total=n_rss,
                                   ooo_window=ooo_window,
                                   redelivery=redelivery), parser),
                (SimulatedEndpoint("twitter", fire_gen, total=n_firehose,
                                   ooo_window=ooo_window,
                                   redelivery=redelivery), parser),
                (SimulatedEndpoint("websocket", ws_gen, total=n_ws,
                                   ooo_window=ooo_window,
                                   redelivery=redelivery), pub_events)]
        for ep, dest in connectors:
            rt.add_connector(ep, dest, policy=pol, late_dest=pub_late,
                             **ingress_kw)
        if window_sec:
            # watermark-driven aggregation stage: tumbling event-time
            # windows over the enriched article stream, closed only when
            # the fabric-wide low watermark passes them (idle-triggered,
            # so closes fire off OTHER connectors' progress too)
            log.create_topic("windows", partitions=1)
            pub_windows = g.add(PublishToLog("pub-windows", log, "windows"),
                                **add_kw)
            # the two article feeds are declared so a feed that finishes
            # before its records traverse to the window stage still gates
            # closes; the websocket connector routes to pub-events and is
            # deliberately NOT declared (it only bounds the clock while
            # active)
            windows = g.add(WindowedAggregate(
                "windows", rt.clock, window_sec,
                sources=("big-rss", "twitter")), **add_kw)
            g.connect(enrich, "success", windows, **conn_kw)
            g.connect(windows, "success", pub_windows, **conn_kw)
            g.connect(windows, "late", pub_late)
    g.connect(parser, "success", dedup, **conn_kw)
    g.connect(dedup, "unique", enrich, **conn_kw)
    g.connect(enrich, "success", route, **conn_kw)
    g.connect(route, "en", pub_articles, **conn_kw)
    g.connect(route, "other", pub_articles)   # all langs land, tagged
    if dead_letter_topic:
        dlq = g.add(DeadLetterQueue("dead-letter", log,
                                    topic=dead_letter_topic))
        g.route_dead_letters_to(dlq)
    return g, log


def _socket_connector(name: str,
                      endpoints: dict[str, tuple] | None) -> SourceConnector:
    """Build the wire-real connector for one named case-study source from a
    ``{"<name>": ("http"|"ws", host, port)}`` endpoint map."""
    if not endpoints or name not in endpoints:
        raise ValueError(
            f"live='socket' needs socket_endpoints[{name!r}] = "
            "('http'|'ws', host, port); start the in-repo feed servers "
            "(tests/net_fixtures.py) and pass their addresses")
    kind, host, port = endpoints[name]
    if kind == "http":
        return HttpPollConnector(name, host, int(port))
    if kind == "ws":
        return WebSocketConnector(name, host, int(port))
    raise ValueError(f"unknown socket endpoint kind {kind!r} for {name!r}")


def arm_news_chaos(*, crash_every: int = 500, source_nth: int = 4,
                   source_every: int = 8) -> None:
    """Arm the case study's standard chaos mix on the process-wide injector:
    the enrich stage chokes on poison records AND raises every
    ~``crash_every`` records (both absorbed by the retry machinery), while
    the RSS source — which has no input connection — raises on a trigger
    schedule, exercising the supervisor restart + replayable-generator
    fast-forward path. Caller must ``INJECTOR.reset()`` afterwards."""
    from ..core.faults import (INJECTOR, compose, raise_every_records,
                               raise_on)
    INJECTOR.arm("proc.enrich", compose(
        raise_on(lambda ff: ff.attributes.get("kind") == "poison",
                 "poison record"),
        raise_every_records(crash_every)), every=1)
    INJECTOR.arm("proc.big-rss", "raise", nth=source_nth, every=source_every)


def expected_clean_doc_ids(n_rss: int, seed: int,
                           poison_rate: float) -> set[str]:
    """Replay the seeded RSS source: the doc ids of every non-junk,
    non-poison article (duplicates collapse into the set) — the ground truth
    the zero-record-loss acceptance checks the landed topic against."""
    out: set[str] = set()
    for ff in RssAggregatorSource(n_rss, seed=seed,
                                  poison_rate=poison_rate)():
        if ff.attributes.get("kind") == "article":
            out.add(str(json.loads(ff.content)["id"]))
    return out


def attach_training_loader(log: LogStore, *, topic: str = "articles",
                           group: str = "trainer", member: str = "host0",
                           batch_size: int = 8, seq_len: int = 256,
                           **kw) -> tuple[ConsumerGroup, StreamingDataLoader]:
    grp = ConsumerGroup(log, topic, group)
    consumer = grp.add_member(member)
    loader = StreamingDataLoader(
        consumer, batch_size=batch_size, seq_len=seq_len,
        text_fn=lambda ff: ff.attributes.get("text", ff.text()), **kw)
    return grp, loader
