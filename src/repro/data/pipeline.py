"""End-to-end pipeline builder — the paper's Fig. 2 topology as one call.

sources (RSS + firehose + websocket) → parse/filter → dedup → enrich →
route → PublishToLog(topic) ; consumers (training loaders / file sinks)
attach to the topic as consumer groups.
"""
from __future__ import annotations

import json
from pathlib import Path

from ..core import (ConsumerGroup, DetectDuplicate, ExecuteScript, FlowGraph,
                    LookupEnrich, PartitionedLog, PublishToLog,
                    RouteOnAttribute, RssAggregatorSource, FirehoseSource,
                    Source, WebSocketSource)
from ..core.delivery import Consumer
from .loader import StreamingDataLoader

SOURCE_REGIONS = {
    "reuters": {"region": "uk"}, "ap": {"region": "us"},
    "afp": {"region": "fr"}, "bbc": {"region": "uk"},
    "cbc": {"region": "ca"}, "nhk": {"region": "jp"},
    "dw": {"region": "de"}, "abc": {"region": "au"},
}


def build_news_pipeline(root: str | Path, *, n_rss: int = 2000,
                        n_firehose: int = 2000, n_ws: int = 500,
                        partitions: int = 8, dedup_mode: str = "exact",
                        seed: int = 0,
                        route_sample: int = 1) -> tuple[FlowGraph, PartitionedLog]:
    """The paper §IV case study: returns (flow, log) with topic ``articles``
    (clean, deduped, enriched news) and topic ``events`` (websocket feed)."""
    root = Path(root)
    log = PartitionedLog(root / "log")
    log.create_topic("articles", partitions=partitions)
    log.create_topic("events", partitions=max(1, partitions // 4))

    from ..core import ProvenanceRepository
    g = FlowGraph("news-pipeline",
                  provenance=ProvenanceRepository(route_sample=route_sample))
    rss = g.add(Source("big-rss", RssAggregatorSource(n_rss, seed=seed)))
    fire = g.add(Source("twitter", FirehoseSource(n_firehose, seed=seed + 1)))
    ws = g.add(Source("websocket", WebSocketSource(n_ws, seed=seed + 2)))

    def parse(ff):
        try:
            doc = ff.json()
        except (ValueError, UnicodeDecodeError):
            return None                                  # junk → DROP
        text = doc.get("title", "")
        body = doc.get("body") or doc.get("text") or ""
        if not body:
            return None
        return ff.with_attributes(
            doc_id=str(doc.get("id", "")),
            lang=str(doc.get("lang", "")),
            text=(text + " " + body).strip())
    parser = g.add(ExecuteScript("parse", parse))

    dedup = g.add(DetectDuplicate(
        "dedup", mode=dedup_mode,
        key_fn=lambda ff: ff.attributes.get("text", "").encode()))

    enrich = g.add(LookupEnrich(
        "enrich", SOURCE_REGIONS,
        key_fn=lambda ff: ff.attributes.get("origin", "")))

    route = g.add(RouteOnAttribute("route", {
        "en": lambda ff: ff.attributes.get("lang") == "en",
        "other": lambda ff: True,
    }))

    pub_articles = g.add(PublishToLog("pub-articles", log, "articles"))
    pub_events = g.add(PublishToLog("pub-events", log, "events"))

    g.connect(rss, "success", parser)
    g.connect(fire, "success", parser)
    g.connect(ws, "success", pub_events)
    g.connect(parser, "success", dedup)
    g.connect(dedup, "unique", enrich)
    g.connect(enrich, "success", route)
    g.connect(route, "en", pub_articles)
    g.connect(route, "other", pub_articles)   # all langs land, tagged
    return g, log


def attach_training_loader(log: PartitionedLog, *, topic: str = "articles",
                           group: str = "trainer", member: str = "host0",
                           batch_size: int = 8, seq_len: int = 256,
                           **kw) -> tuple[ConsumerGroup, StreamingDataLoader]:
    grp = ConsumerGroup(log, topic, group)
    consumer = grp.add_member(member)
    loader = StreamingDataLoader(
        consumer, batch_size=batch_size, seq_len=seq_len,
        text_fn=lambda ff: ff.attributes.get("text", ff.text()), **kw)
    return grp, loader
