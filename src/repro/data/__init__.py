from .loader import StreamingDataLoader
from .packing import SequencePacker
from .pipeline import attach_training_loader, build_news_pipeline
from .tokenizer import ByteTokenizer

__all__ = ["ByteTokenizer", "SequencePacker", "StreamingDataLoader",
           "attach_training_loader", "build_news_pipeline"]
