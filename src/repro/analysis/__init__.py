"""Repo-specific static analysis + dynamic lock-order detection.

The fabric's concurrency and durability contracts — hard-won across PRs
2/5/7/8/9 — are encoded as machine-checked invariants:

* ``python -m repro.analysis --check src/`` runs the AST lint pass
  (see :mod:`repro.analysis.rules`) against the committed baseline; any new
  finding OR stale baseline entry fails. Gated by ``scripts/ci.sh``.
* ``REPRO_LOCK_ORDER=1`` arms the dynamic lock-order detector
  (:mod:`repro.analysis.lockorder`) — the tier-1 fast subset runs under it
  in CI and fails on any held-across lock-acquisition cycle.
"""
from .engine import (AnalysisConfig, Engine, Finding, ModuleContext, Rule,
                     load_config)
from .lockorder import (LockOrderMonitor, LockOrderViolation,
                        monitor_enabled_by_env)
from .rules import default_rules

__all__ = [
    "AnalysisConfig", "Engine", "Finding", "ModuleContext", "Rule",
    "load_config", "default_rules",
    "LockOrderMonitor", "LockOrderViolation", "monitor_enabled_by_env",
]
