"""CLI for the invariant analysis pass.

    python -m repro.analysis --check [paths...]     lint against the baseline
    python -m repro.analysis --write-baseline       regenerate the baseline
    python -m repro.analysis --list-rules           print rule ids + docs

``--check`` exits non-zero on any NEW finding, any STALE baseline entry
(drift in either direction), or any unused suppression pragma. Paths
default to ``[tool.repro-analysis] paths`` in pyproject.toml.
"""
from __future__ import annotations

import argparse
import sys

from .engine import Engine, load_config


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument("paths", nargs="*",
                    help="files/dirs to scan (default: configured paths)")
    ap.add_argument("--check", action="store_true",
                    help="fail on unbaselined findings and baseline drift")
    ap.add_argument("--write-baseline", action="store_true",
                    help="regenerate the committed baseline from this scan")
    ap.add_argument("--list-rules", action="store_true")
    ap.add_argument("--verbose", "-v", action="store_true",
                    help="also print suppressed (pragma'd) findings")
    args = ap.parse_args(argv)

    config = load_config()
    engine = Engine(config)

    if args.list_rules:
        for rule in engine.rules:
            print(f"{rule.id:24s} {rule.doc}")
        return 0

    result = engine.scan(args.paths or None)

    if args.write_baseline:
        path = engine.write_baseline(result)
        print(f"baseline: {len(result.findings)} finding(s) -> {path}")
        return 0

    baseline = engine.load_baseline()
    new, stale = result.partition_against(baseline)

    if args.verbose and result.suppressed:
        print(f"-- {len(result.suppressed)} suppressed (pragma'd):")
        for f in result.suppressed:
            print(f"   {f.render()}")
    status = 0
    if new:
        print(f"-- {len(new)} unbaselined finding(s):")
        for f in new:
            print(f"   {f.render()}")
        status = 1
    if stale:
        print(f"-- {len(stale)} stale baseline entr(y/ies) — fixed or moved; "
              "regenerate with --write-baseline:")
        for f in stale:
            print(f"   {f.render()}")
        status = 1
    if result.unused_pragmas:
        print(f"-- {len(result.unused_pragmas)} unused pragma(s) — the "
              "finding they suppressed is gone; delete them:")
        for path, line in result.unused_pragmas:
            print(f"   {path}:{line}")
        status = 1
    matched = len(result.findings) - len(new)
    print(f"repro.analysis: {result.files_scanned} files, "
          f"{len(result.findings)} finding(s) "
          f"({len(new)} new, {len(result.suppressed)} suppressed, "
          f"{matched} baselined)"
          + (" — FAIL" if status else " — ok"))
    return status


if __name__ == "__main__":
    sys.exit(main())
