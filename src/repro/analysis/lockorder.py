"""Dynamic lock-order detector: the deadlock class AST rules cannot see.

The static ``lock-blocking-call`` rule catches *blocking while holding a
lock*; it cannot catch two threads taking the same two locks in opposite
orders (connection ⇄ flow ⇄ transport is the codebase's most
deadlock-prone layer). This module wraps ``threading.Lock``/``RLock``
construction — **opt-in** via the ``REPRO_LOCK_ORDER=1`` env var, zero
cost otherwise (nothing is patched, callers get stock locks) — and records
the per-thread lock-*acquisition order* graph while the instrumented tier-1
subset runs:

* every lock constructed from code under the tracked prefixes (``repro/``
  by default) is identified by its **construction site** (``file:line``),
  so all instances of e.g. ``Connection._lock`` collapse into one node —
  which is exactly what makes cycles meaningful across object instances;
* when a thread acquires lock B while holding lock A, the edge ``A -> B``
  is recorded (first witness thread kept for the report);
* a cycle in that graph — including a self-edge: two *instances* of the
  same site held across each other — is a deadlock waiting for the right
  interleaving. :meth:`LockOrderMonitor.check` raises
  :class:`LockOrderViolation` with every cycle and its witnesses.

``Condition``/``Event`` built on tracked locks stay accurate for free:
they acquire/release through the lock object itself, so a ``wait()``
(which releases the lock while parked) correctly drops it from the held
set. Locks constructed outside the tracked prefixes (stdlib internals,
third-party) are returned unwrapped and never observed.
"""
from __future__ import annotations

import _thread
import os
import sys
import threading
from typing import Iterable

__all__ = ["LockOrderMonitor", "LockOrderViolation", "monitor_enabled_by_env",
           "ENV_VAR"]

ENV_VAR = "REPRO_LOCK_ORDER"

#: path fragments a construction frame must contain to be tracked
_DEFAULT_PREFIXES = ("repro",)

#: frames to walk up looking for a tracked construction site (skips
#: dataclasses' generated ``__init__`` and other stdlib trampolines)
_MAX_FRAME_WALK = 12


class LockOrderViolation(RuntimeError):
    """Raised by :meth:`LockOrderMonitor.check` when the recorded
    acquisition graph contains a cycle."""


class _TrackedLock:
    """Proxy over a stock lock that reports acquire/release to the monitor.
    Implements the subset of the lock protocol the codebase (and
    ``threading.Condition``) uses."""

    __slots__ = ("_inner", "_site", "_mon")

    def __init__(self, inner, site: str, mon: "LockOrderMonitor") -> None:
        self._inner = inner
        self._site = site
        self._mon = mon

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        ok = self._inner.acquire(blocking, timeout)
        if ok:
            self._mon._note_acquire(self)
        return ok

    def release(self) -> None:
        self._mon._note_release(self)
        self._inner.release()

    def locked(self) -> bool:
        return self._inner.locked()

    def __enter__(self) -> bool:
        return self.acquire()

    def __exit__(self, *exc) -> None:
        self.release()

    def __repr__(self) -> str:         # pragma: no cover - debugging aid
        return f"<TrackedLock {self._site} {self._inner!r}>"


class _TrackedRLock:
    """Reentrant variant: only the 0→1 acquisition (and the 1→0 release)
    touch the held-set, so recursion never self-edges. Exposes the
    ``_release_save``/``_acquire_restore``/``_is_owned`` trio so a
    ``Condition`` wrapping it keeps its recursion count across ``wait()``."""

    __slots__ = ("_inner", "_site", "_mon", "_count")

    def __init__(self, inner, site: str, mon: "LockOrderMonitor") -> None:
        self._inner = inner
        self._site = site
        self._mon = mon
        self._count = 0      # mutated only by the owning thread

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        ok = self._inner.acquire(blocking, timeout)
        if ok:
            self._count += 1
            if self._count == 1:
                self._mon._note_acquire(self)
        return ok

    def release(self) -> None:
        if self._count == 1:
            self._mon._note_release(self)
        self._count -= 1
        self._inner.release()

    def __enter__(self) -> bool:
        return self.acquire()

    def __exit__(self, *exc) -> None:
        self.release()

    # -- Condition integration (full release across wait()) -------------------
    def _release_save(self):
        count = self._count
        self._count = 0
        self._mon._note_release(self)
        return (self._inner._release_save(), count)

    def _acquire_restore(self, state) -> None:
        inner_state, count = state
        self._inner._acquire_restore(inner_state)
        self._count = count
        self._mon._note_acquire(self)

    def _is_owned(self) -> bool:
        return self._inner._is_owned()

    def __repr__(self) -> str:         # pragma: no cover - debugging aid
        return f"<TrackedRLock {self._site} {self._inner!r}>"


class LockOrderMonitor:
    """Records the lock-acquisition-order graph; detects cycles.

    Usage (what the conftest does under ``REPRO_LOCK_ORDER=1``)::

        mon = LockOrderMonitor()
        mon.install()
        try:
            ...  # run the workload
        finally:
            mon.uninstall()
        mon.check()     # raises LockOrderViolation on any cycle
    """

    def __init__(self, prefixes: Iterable[str] = _DEFAULT_PREFIXES) -> None:
        self.prefixes = tuple(prefixes)
        # edge (site_a, site_b) -> witness thread name; the map lock is a RAW
        # lock so the monitor never observes itself
        self._edges: dict[tuple[str, str], str] = {}
        self._edge_lock = _thread.allocate_lock()
        self._tls = threading.local()
        self._installed = False
        self._orig_lock = None
        self._orig_rlock = None
        self.tracked_sites: set[str] = set()

    # -- construction-site resolution -----------------------------------------
    def _caller_site(self) -> str | None:
        """First frame up the stack whose file lives under a tracked prefix
        (skipping this module). None == construction outside our code."""
        f = sys._getframe(2)
        for _ in range(_MAX_FRAME_WALK):
            if f is None:
                return None
            fn = f.f_code.co_filename
            if fn != __file__ and any(p in fn for p in self.prefixes) \
                    and "analysis" + os.sep + "lockorder" not in fn:
                parts = fn.replace("\\", "/").split("/")
                tail = "/".join(parts[-2:])
                return f"{tail}:{f.f_lineno}"
            f = f.f_back
        return None

    # -- factories (installed over threading.Lock / threading.RLock) ----------
    def _make_lock(self):
        site = self._caller_site()
        inner = _thread.allocate_lock()
        if site is None:
            return inner
        self.tracked_sites.add(site)
        return _TrackedLock(inner, site, self)

    def _make_rlock(self):
        site = self._caller_site()
        inner = _thread.RLock()
        if site is None:
            return inner
        self.tracked_sites.add(site)
        return _TrackedRLock(inner, site, self)

    def install(self) -> "LockOrderMonitor":
        if self._installed:
            return self
        self._orig_lock = threading.Lock
        self._orig_rlock = threading.RLock
        threading.Lock = self._make_lock          # type: ignore[assignment]
        threading.RLock = self._make_rlock        # type: ignore[assignment]
        self._installed = True
        return self

    def uninstall(self) -> None:
        if not self._installed:
            return
        threading.Lock = self._orig_lock          # type: ignore[assignment]
        threading.RLock = self._orig_rlock        # type: ignore[assignment]
        self._installed = False

    def __enter__(self) -> "LockOrderMonitor":
        return self.install()

    def __exit__(self, *exc) -> None:
        self.uninstall()

    # -- acquisition tracking --------------------------------------------------
    def _held(self) -> list:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    def _note_acquire(self, lock) -> None:
        stack = self._held()
        site = lock._site
        for held_site, held_lock in stack:
            if held_lock is lock:
                continue
            edge = (held_site, site)
            if edge not in self._edges:
                with self._edge_lock:
                    self._edges.setdefault(
                        edge, threading.current_thread().name)
        stack.append((site, lock))

    def _note_release(self, lock) -> None:
        stack = self._held()
        for i in range(len(stack) - 1, -1, -1):
            if stack[i][1] is lock:
                del stack[i]
                return

    # -- analysis ---------------------------------------------------------------
    def edges(self) -> dict[tuple[str, str], str]:
        with self._edge_lock:
            return dict(self._edges)

    def cycles(self) -> list[list[str]]:
        """Every elementary cycle's node set, as sorted site lists: the
        strongly connected components of the edge graph with more than one
        node, plus self-loops (same site held across another instance of
        itself)."""
        edges = self.edges()
        graph: dict[str, set[str]] = {}
        for (a, b) in edges:
            graph.setdefault(a, set()).add(b)
            graph.setdefault(b, set())
        # Tarjan SCC, iterative (worker threads can nest deep graphs)
        index: dict[str, int] = {}
        low: dict[str, int] = {}
        on_stack: set[str] = set()
        stack: list[str] = []
        counter = [0]
        sccs: list[list[str]] = []

        for start in graph:
            if start in index:
                continue
            work = [(start, iter(graph[start]))]
            index[start] = low[start] = counter[0]
            counter[0] += 1
            stack.append(start)
            on_stack.add(start)
            while work:
                node, it = work[-1]
                advanced = False
                for nxt in it:
                    if nxt not in index:
                        index[nxt] = low[nxt] = counter[0]
                        counter[0] += 1
                        stack.append(nxt)
                        on_stack.add(nxt)
                        work.append((nxt, iter(graph[nxt])))
                        advanced = True
                        break
                    if nxt in on_stack:
                        low[node] = min(low[node], index[nxt])
                if advanced:
                    continue
                work.pop()
                if work:
                    parent = work[-1][0]
                    low[parent] = min(low[parent], low[node])
                if low[node] == index[node]:
                    comp = []
                    while True:
                        w = stack.pop()
                        on_stack.discard(w)
                        comp.append(w)
                        if w == node:
                            break
                    if len(comp) > 1:
                        sccs.append(sorted(comp))
        loops = [[a] for (a, b) in edges if a == b]
        return sorted(sccs + loops)

    def report(self) -> str:
        edges = self.edges()
        cyc = self.cycles()
        lines = [f"lock-order monitor: {len(self.tracked_sites)} lock "
                 f"site(s), {len(edges)} ordering edge(s), "
                 f"{len(cyc)} cycle(s)"]
        for comp in cyc:
            lines.append("  CYCLE through: " + " ; ".join(comp))
            members = set(comp)
            for (a, b), thread in sorted(edges.items()):
                if a in members and b in members:
                    lines.append(f"    {a} -> {b}   (first seen on "
                                 f"thread {thread!r})")
        return "\n".join(lines)

    def check(self) -> None:
        """Raise :class:`LockOrderViolation` if any held-across cycle was
        recorded. Call after the workload, with the monitor uninstalled or
        quiescent."""
        if self.cycles():
            raise LockOrderViolation(self.report())


def monitor_enabled_by_env() -> LockOrderMonitor | None:
    """The conftest hook: a fresh monitor iff ``REPRO_LOCK_ORDER`` is set
    to a truthy value, else None (and nothing is ever patched)."""
    val = os.environ.get(ENV_VAR, "").strip().lower()
    if val in ("", "0", "false", "no", "off"):
        return None
    return LockOrderMonitor()
