"""Invariant-lint engine: the framework half of ``repro.analysis``.

The repo's concurrency and durability contracts (never block while holding a
lock, fsync-before-rename, declared fault sites, injected clocks, locked
stats mutation — see ``rules.py`` and the ROADMAP "Invariants as lint rules"
table) are enforced by an AST pass over ``src/``, gated by ``scripts/ci.sh``:

    python -m repro.analysis --check src/

Pieces:

* :class:`Rule` — one invariant, implemented as a visitor over a parsed
  module (:class:`ModuleContext` carries the tree, source lines, and path).
* suppression pragma — a finding on a line carrying
  ``# lint: ok(<rule>) — <reason>`` (same line or the line directly above)
  is a *deliberate exception*; the reason is mandatory, so every suppression
  documents itself. A pragma that matches no finding is itself reported
  (``unused-pragma``) so stale exceptions can't accumulate.
* baseline — grandfathered findings live in a committed JSON file
  (``analysis-baseline.json``). ``--check`` fails on any NEW finding *and*
  on any STALE baseline entry: the baseline can only drift by being
  regenerated (``--write-baseline``) in a reviewed commit.
* config — ``[tool.repro-analysis]`` in ``pyproject.toml`` (paths to scan,
  excluded seed-era dirs, baseline location, fault-registry module). Parsed
  with a deliberately tiny reader: this interpreter predates ``tomllib``
  and the section only holds strings and string lists.
"""
from __future__ import annotations

import ast
import json
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Iterable, Iterator, Sequence

__all__ = [
    "AnalysisConfig", "Engine", "Finding", "ModuleContext", "Rule",
    "load_config",
]

#: ``# lint: ok(<rules>) — <reason>`` (em-dash, double or single hyphen all
#: accepted as the reason separator; the reason itself is REQUIRED).
_PRAGMA_RE = re.compile(
    r"#\s*lint:\s*ok\(\s*(?P<rules>[a-z0-9_,\s-]+?)\s*\)"
    r"\s*(?:—|--|-)\s*(?P<reason>\S.*)")


@dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""

    rule: str
    path: str          # repo-relative, '/'-separated (stable across hosts)
    line: int          # 1-indexed
    message: str

    def key(self) -> str:
        """Identity used for baseline matching and dedup. Includes the
        message so two distinct findings on one line stay distinct."""
        return f"{self.path}:{self.line}:{self.rule}:{self.message}"

    def to_dict(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "message": self.message}

    @classmethod
    def from_dict(cls, d: dict) -> "Finding":
        return cls(rule=str(d["rule"]), path=str(d["path"]),
                   line=int(d["line"]), message=str(d["message"]))

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


@dataclass
class ModuleContext:
    """Everything a rule gets to look at for one file."""

    path: str                    # repo-relative
    tree: ast.Module
    lines: list[str]             # raw source lines (0-indexed)
    config: "AnalysisConfig"

    def line_text(self, lineno: int) -> str:
        """1-indexed source line (empty string past EOF)."""
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""


class Rule:
    """Base class for one invariant. Subclasses set ``id``/``doc`` and
    implement :meth:`check` yielding findings. ``doc`` is one line — it is
    what ``--list-rules`` prints and what the ROADMAP table cites."""

    id: str = ""
    doc: str = ""

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        raise NotImplementedError

    def finding(self, ctx: ModuleContext, node: ast.AST, message: str
                ) -> Finding:
        return Finding(rule=self.id, path=ctx.path,
                       line=getattr(node, "lineno", 0), message=message)


@dataclass
class AnalysisConfig:
    """Scan configuration (see ``[tool.repro-analysis]`` in pyproject.toml)."""

    root: Path                       # repo root (pyproject.toml's directory)
    paths: list[str] = field(default_factory=lambda: ["src"])
    exclude: list[str] = field(default_factory=list)
    baseline: str = "analysis-baseline.json"
    #: module whose ``SITES`` mapping declares every legal fault site
    fault_registry: str = "src/repro/core/faults.py"
    #: module whose ``ComponentStats`` dataclass declares the stats fields
    stats_module: str = "src/repro/core/metrics.py"

    def baseline_path(self) -> Path:
        return self.root / self.baseline

    def is_excluded(self, rel_path: str) -> bool:
        rel = rel_path.replace("\\", "/")
        return any(rel == ex or rel.startswith(ex.rstrip("/") + "/")
                   for ex in self.exclude)


def _parse_toml_section(text: str, section: str) -> dict:
    """Minimal TOML reader for one ``[section]`` of flat ``key = value``
    pairs where value is a string or a (possibly multi-line) string list.
    Good enough for our own config block; not a general TOML parser."""
    out: dict = {}
    lines = text.splitlines()
    in_section = False
    pending_key: str | None = None
    pending_items: list[str] = []
    for raw in lines:
        line = raw.strip()
        if line.startswith("["):
            in_section = line == f"[{section}]"
            pending_key = None
            continue
        if not in_section or not line or line.startswith("#"):
            continue
        if pending_key is not None:
            pending_items.extend(re.findall(r'"([^"]*)"', line))
            if line.rstrip(",").endswith("]"):
                out[pending_key] = pending_items
                pending_key, pending_items = None, []
            continue
        m = re.match(r'([A-Za-z0-9_-]+)\s*=\s*(.*)$', line)
        if not m:
            continue
        key, val = m.group(1), m.group(2).strip()
        if val.startswith("["):
            items = re.findall(r'"([^"]*)"', val)
            if val.rstrip(",").endswith("]"):
                out[key] = items
            else:
                pending_key, pending_items = key, items
        else:
            sm = re.match(r'"([^"]*)"', val)
            if sm:
                out[key] = sm.group(1)
    return out


def load_config(root: str | Path | None = None) -> AnalysisConfig:
    """Read ``[tool.repro-analysis]`` from ``<root>/pyproject.toml``. With
    no ``root``, walk up from this file to the directory holding one (the
    repo checkout)."""
    if root is None:
        here = Path(__file__).resolve()
        for cand in here.parents:
            if (cand / "pyproject.toml").is_file():
                root = cand
                break
        else:                                    # pragma: no cover
            root = Path.cwd()
    root = Path(root)
    cfg = AnalysisConfig(root=root)
    pyproject = root / "pyproject.toml"
    if pyproject.is_file():
        data = _parse_toml_section(pyproject.read_text(), "tool.repro-analysis")
        for key in ("paths", "exclude"):
            if key in data:
                setattr(cfg, key, list(data[key]))
        for key in ("baseline", "fault_registry", "stats_module"):
            if key in data:
                setattr(cfg, key, str(data[key]))
    return cfg


@dataclass
class PragmaIndex:
    """Per-file map of suppression pragmas: line -> (rules, reason).
    ``"*"`` in rules suppresses any rule on that line (discouraged; spell
    the rule out so the suppression survives rule renames loudly)."""

    by_line: dict[int, tuple[frozenset[str], str]]

    @classmethod
    def scan(cls, lines: Sequence[str]) -> "PragmaIndex":
        by_line: dict[int, tuple[frozenset[str], str]] = {}
        for i, text in enumerate(lines, start=1):
            m = _PRAGMA_RE.search(text)
            if not m:
                continue
            rules = frozenset(r.strip() for r in m.group("rules").split(",")
                              if r.strip())
            by_line[i] = (rules, m.group("reason").strip())
        return by_line and cls(by_line) or cls({})

    def suppresses(self, finding: Finding) -> bool:
        """A pragma applies to findings on its own line or the line directly
        below it (pragma-above style for lines with no trailing room)."""
        for line in (finding.line, finding.line - 1):
            entry = self.by_line.get(line)
            if entry and (finding.rule in entry[0] or "*" in entry[0]):
                return True
        return False

    def unused(self, findings: Iterable[Finding],
               all_raw: Iterable[Finding]) -> list[int]:
        """Pragma lines that matched no raw finding at all — stale
        suppressions that should be deleted."""
        hit: set[int] = set()
        for f in all_raw:
            for line in (f.line, f.line - 1):
                entry = self.by_line.get(line)
                if entry and (f.rule in entry[0] or "*" in entry[0]):
                    hit.add(line)
        return sorted(set(self.by_line) - hit)


@dataclass
class ScanResult:
    findings: list[Finding]          # post-suppression, pre-baseline
    suppressed: list[Finding]        # pragma'd deliberate exceptions
    unused_pragmas: list[tuple[str, int]]   # (path, line)
    files_scanned: int = 0
    scanned_paths: set[str] = field(default_factory=set)

    def partition_against(self, baseline: list[Finding]
                          ) -> tuple[list[Finding], list[Finding]]:
        """Split into (new findings, stale baseline entries). A baseline
        entry for a file OUTSIDE this scan (e.g. ``--check src/repro/core``
        with a baselined finding under ``checkpoint/``) is neither new nor
        stale — staleness is only judged for files actually rescanned."""
        current = {f.key() for f in self.findings}
        base = {f.key() for f in baseline}
        new = [f for f in self.findings if f.key() not in base]
        stale = [f for f in baseline
                 if f.path in self.scanned_paths and f.key() not in current]
        return new, stale


class Engine:
    """Runs every registered rule over every configured file."""

    def __init__(self, config: AnalysisConfig,
                 rules: Sequence[Rule] | None = None) -> None:
        self.config = config
        if rules is None:
            from .rules import default_rules
            rules = default_rules(config)
        self.rules = list(rules)

    # -- file discovery -------------------------------------------------------
    def iter_files(self, paths: Sequence[str] | None = None) -> Iterator[Path]:
        root = self.config.root
        for p in (paths or self.config.paths):
            target = (root / p) if not Path(p).is_absolute() else Path(p)
            if target.is_file() and target.suffix == ".py":
                yield target
                continue
            for f in sorted(target.rglob("*.py")):
                rel = f.relative_to(root).as_posix() if f.is_relative_to(root) \
                    else f.as_posix()
                if not self.config.is_excluded(rel):
                    yield f

    # -- scanning -------------------------------------------------------------
    def scan_file(self, path: Path) -> tuple[list[Finding], list[Finding],
                                             list[tuple[str, int]]]:
        src = path.read_text()
        rel = (path.relative_to(self.config.root).as_posix()
               if path.is_relative_to(self.config.root) else path.as_posix())
        try:
            tree = ast.parse(src, filename=rel)
        except SyntaxError as e:
            return ([Finding("syntax-error", rel, e.lineno or 0,
                             f"unparseable: {e.msg}")], [], [])
        lines = src.splitlines()
        ctx = ModuleContext(path=rel, tree=tree, lines=lines,
                            config=self.config)
        raw: list[Finding] = []
        for rule in self.rules:
            raw.extend(rule.check(ctx))
        pragmas = PragmaIndex.scan(lines)
        kept = [f for f in raw if not pragmas.suppresses(f)]
        suppressed = [f for f in raw if pragmas.suppresses(f)]
        unused = [(rel, line) for line in pragmas.unused(kept, raw)]
        return kept, suppressed, unused

    def scan(self, paths: Sequence[str] | None = None) -> ScanResult:
        findings: list[Finding] = []
        suppressed: list[Finding] = []
        unused: list[tuple[str, int]] = []
        n = 0
        seen: set[Path] = set()
        scanned: set[str] = set()
        for f in self.iter_files(paths):
            if f in seen:
                continue
            seen.add(f)
            n += 1
            scanned.add(f.relative_to(self.config.root).as_posix()
                        if f.is_relative_to(self.config.root) else f.as_posix())
            kept, supp, un = self.scan_file(f)
            findings.extend(kept)
            suppressed.extend(supp)
            unused.extend(un)
        findings.sort(key=lambda f: (f.path, f.line, f.rule))
        return ScanResult(findings=findings, suppressed=suppressed,
                          unused_pragmas=sorted(unused), files_scanned=n,
                          scanned_paths=scanned)

    # -- baseline -------------------------------------------------------------
    def load_baseline(self) -> list[Finding]:
        path = self.config.baseline_path()
        if not path.is_file():
            return []
        data = json.loads(path.read_text())
        return [Finding.from_dict(d) for d in data.get("findings", [])]

    def write_baseline(self, result: ScanResult) -> Path:
        path = self.config.baseline_path()
        payload = {
            "comment": ("Grandfathered findings. Regenerate ONLY via "
                        "`python -m repro.analysis --write-baseline` in a "
                        "reviewed commit; ci.sh fails on any drift."),
            "findings": [f.to_dict() for f in result.findings],
        }
        path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
        return path
