"""The repo-specific invariant rules. Each one encodes a bug class this
codebase actually shipped and fixed (the PR numbers refer to CHANGES.md
postmortems; the ROADMAP "Invariants as lint rules" table is the index):

* ``lock-blocking-call``   — PR 2 (`_wal_lock` held across a backpressure
  wait) and PR 8 (`_call` held the client lock across a round trip): no
  blocking call inside a ``with <lock>:`` body.
* ``durability-rename``    — PR 5 torn-rename sweep: every rename/replace
  of a freshly written file goes through ``logstore.atomic_write_bytes``
  (fsync file, rename, fsync parent dir) or it can lose acked data on a
  machine crash.
* ``fault-site-registry``  — a ``fire("...")`` / ``arm("...")`` site name
  must be declared in ``core/faults.py::SITES``; a typo'd site silently
  never fires, and the test that armed it silently tests nothing.
* ``naked-clock``          — PR 9 monkeypatch cleanup: a class that accepts
  an injected ``clock=`` must route every time read through it; a direct
  ``time.monotonic()``/``time.time()`` resurrects the untestable path.
* ``stats-direct-mutation``— PR 7 stats races: ``ComponentStats`` counters
  are mutated from several threads; writes must go through the locked
  ``add()``/``set()`` helpers (``+=`` is three bytecodes and loses updates).

Rules are syntactic and conservative by design: they key on the idioms this
codebase actually uses (lock-ish attribute names, ``x.stats.<field>``
chains). A deliberate exception takes a one-line pragma —
``# lint: ok(<rule>) — <reason>`` — so it documents itself in place.
"""
from __future__ import annotations

import ast
import re
from typing import Iterator, Sequence

from .engine import AnalysisConfig, Finding, ModuleContext, Rule

__all__ = ["default_rules", "LockBlockingCallRule", "DurabilityRenameRule",
           "FaultSiteRegistryRule", "NakedClockRule",
           "StatsDirectMutationRule"]

#: with-context names that count as "holding a lock". Matches the terminal
#: attribute/name: ``self._lock``, ``node.pool_lock``, ``self._cv``,
#: ``self._not_full`` (a Condition wraps its lock), ``self._send_locks[w]``.
_LOCK_NAME_RE = re.compile(
    r"(^|_)(lock|locks|rlock|mutex|cv|cond|condition|not_full|not_empty)$",
    re.IGNORECASE)


def _terminal_name(node: ast.expr) -> str | None:
    """The rightmost identifier of a Name/Attribute/Subscript chain
    (``self._send_locks[wid]`` -> ``_send_locks``)."""
    if isinstance(node, ast.Subscript):
        return _terminal_name(node.value)
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def _dotted(node: ast.expr) -> str:
    """Best-effort dotted rendering (``os.path.rename`` -> "os.path.rename";
    non-name parts render as ``?``)."""
    if isinstance(node, ast.Attribute):
        return f"{_dotted(node.value)}.{node.attr}"
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Call):
        return f"{_dotted(node.func)}()"
    return "?"


def _is_lockish(node: ast.expr) -> bool:
    name = _terminal_name(node)
    return bool(name and _LOCK_NAME_RE.search(name))


class LockBlockingCallRule(Rule):
    id = "lock-blocking-call"
    doc = ("no socket recv/sendall, untimed Condition.wait()/.join(), "
           "offer/offer_batch, time.sleep, or os.fsync inside a "
           "`with <lock>:` body (PR 2 _wal_lock, PR 8 transport _call)")

    #: attribute calls that block on a peer or another thread, flagged on
    #: any receiver
    _BLOCKING_ATTRS = frozenset({
        "recv", "recv_into", "recvfrom", "sendall", "accept",
        "offer", "offer_batch",
    })
    #: ``send`` blocks too but is too common a method name; only flag it on
    #: receivers that look like sockets
    _SOCKISH_RE = re.compile(r"(^|_)(sock|socket|conn)s?$", re.IGNORECASE)

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, (ast.With, ast.AsyncWith)):
                continue
            lock_names = [_dotted(item.context_expr)
                          for item in node.items
                          if _is_lockish(item.context_expr)]
            if not lock_names:
                continue
            held = ", ".join(lock_names)
            for call in self._calls_in_body(node.body):
                msg = self._blocking_reason(call)
                if msg:
                    yield self.finding(
                        ctx, call, f"{msg} while holding {held}")

    def _calls_in_body(self, body: Sequence[ast.stmt]) -> Iterator[ast.Call]:
        """Every Call in the with-body, skipping nested function/class
        definitions (defining is not calling) but descending into nested
        with/if/for/try blocks (the lock is still held there)."""
        stack: list[ast.AST] = list(body)
        while stack:
            node = stack.pop()
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda, ast.ClassDef)):
                continue
            if isinstance(node, ast.Call):
                yield node
            stack.extend(ast.iter_child_nodes(node))

    def _blocking_reason(self, call: ast.Call) -> str | None:
        func = call.func
        dotted = _dotted(func)
        if dotted in ("time.sleep", "sleep"):
            return "time.sleep()"
        if dotted in ("os.fsync", "fsync"):
            return "os.fsync()"
        if not isinstance(func, ast.Attribute):
            return None
        attr = func.attr
        if attr in self._BLOCKING_ATTRS:
            return f"blocking .{attr}()"
        if attr == "send" and (
                (n := _terminal_name(func.value)) and self._SOCKISH_RE.search(n)):
            return "blocking socket .send()"
        if attr == "wait" and not call.args and not call.keywords:
            # cond.wait() with a timeout is a bounded stall the caller chose;
            # without one it parks the thread until a notify that a crashed
            # or fenced peer may never deliver
            return "untimed .wait()"
        if attr == "join" and not call.args and not call.keywords:
            return "untimed .join()"
        return None


class DurabilityRenameRule(Rule):
    id = "durability-rename"
    doc = ("os.rename/os.replace/Path.rename outside "
           "logstore.atomic_write_bytes — a bare write+rename tears on "
           "machine crash (PR 5 fsync-before-rename sweep)")

    #: the one blessed home of the fsync+rename+dirfsync idiom
    _ALLOWED = ("logstore.py", "atomic_write_bytes")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        allowed_file = ctx.path.endswith(self._ALLOWED[0])
        for func, call in self._walk_calls(ctx.tree):
            dotted = _dotted(call.func)
            is_rename = dotted in ("os.rename", "os.replace")
            if not is_rename and isinstance(call.func, ast.Attribute) \
                    and call.func.attr == "rename":
                is_rename = True
                dotted = f"{_dotted(call.func.value)}.rename"
            if not is_rename:
                continue
            if allowed_file and func is not None \
                    and func.name == self._ALLOWED[1]:
                continue
            yield self.finding(
                ctx, call,
                f"{dotted}() outside logstore.atomic_write_bytes — "
                "fsync-before-rename is not enforced here")

    def _walk_calls(self, tree: ast.Module
                    ) -> Iterator[tuple[ast.FunctionDef | None, ast.Call]]:
        """Yield (enclosing function, call) pairs."""
        def visit(node: ast.AST, func: ast.FunctionDef | None
                  ) -> Iterator[tuple[ast.FunctionDef | None, ast.Call]]:
            for child in ast.iter_child_nodes(node):
                f = child if isinstance(
                    child, (ast.FunctionDef, ast.AsyncFunctionDef)) else func
                if isinstance(child, ast.Call):
                    yield func, child
                yield from visit(child, f)
        yield from visit(tree, None)


class FaultSiteRegistryRule(Rule):
    id = "fault-site-registry"
    doc = ("every fire(\"...\")/arm(\"...\") string literal must be declared "
           "in core/faults.py SITES (a typo'd site silently never fires)")

    def __init__(self, config: AnalysisConfig) -> None:
        self._registry_rel = config.fault_registry.replace("\\", "/")
        self._sites, self._prefixes = self._load_registry(config)

    @staticmethod
    def _load_registry(config: AnalysisConfig
                       ) -> tuple[frozenset[str], tuple[str, ...]]:
        """Extract SITES from the registry module's AST (no import — the
        analyzer must run on a checkout that may not even import cleanly)."""
        path = config.root / config.fault_registry
        sites: set[str] = set()
        if path.is_file():
            tree = ast.parse(path.read_text())
            for node in ast.walk(tree):
                if isinstance(node, ast.Assign):
                    if not any(isinstance(t, ast.Name) and t.id == "SITES"
                               for t in node.targets):
                        continue
                elif isinstance(node, ast.AnnAssign):
                    if not (isinstance(node.target, ast.Name)
                            and node.target.id == "SITES"):
                        continue
                else:
                    continue
                value = node.value
                if isinstance(value, ast.Dict):
                    # {site: one-line doc}: the keys are the registry
                    for k in value.keys:
                        if isinstance(k, ast.Constant) \
                                and isinstance(k.value, str):
                            sites.add(k.value)
                elif value is not None:
                    for sub in ast.walk(value):
                        if isinstance(sub, ast.Constant) \
                                and isinstance(sub.value, str):
                            sites.add(sub.value)
        exact = frozenset(s for s in sites if not s.endswith(".*"))
        prefixes = tuple(s[:-1] for s in sites if s.endswith(".*"))
        return exact, prefixes

    def declared(self, site: str) -> bool:
        return site in self._sites or site.startswith(self._prefixes)

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        if ctx.path == self._registry_rel:
            return      # the registry's own docstrings/keys are not calls
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            name = (node.func.attr if isinstance(node.func, ast.Attribute)
                    else node.func.id if isinstance(node.func, ast.Name)
                    else None)
            if name not in ("fire", "arm"):
                continue
            site_arg: ast.expr | None = node.args[0] if node.args else None
            for kw in node.keywords:
                if kw.arg == "site":
                    site_arg = kw.value
            if not isinstance(site_arg, ast.Constant) \
                    or not isinstance(site_arg.value, str):
                continue          # dynamic site (f-string/var): runtime check
            site = site_arg.value
            if not self.declared(site):
                yield self.finding(
                    ctx, node,
                    f"fault site {site!r} is not declared in "
                    "core/faults.py SITES")


class NakedClockRule(Rule):
    id = "naked-clock"
    doc = ("direct time.monotonic()/time.time() inside a class that accepts "
           "clock= — route it through the injected clock (PR 9 cleanup)")

    _CLOCK_CALLS = frozenset({"time.monotonic", "time.time",
                              "monotonic"})

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        for cls in ast.walk(ctx.tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            if not self._accepts_clock(cls):
                continue
            for method in cls.body:
                if not isinstance(method, (ast.FunctionDef,
                                           ast.AsyncFunctionDef)):
                    continue
                if method.name == "_now":
                    # the designated clock-routing helper: its body is where
                    # the injected-clock-or-real-clock dispatch lives
                    continue
                for node in ast.walk(method):
                    if isinstance(node, ast.Call) \
                            and _dotted(node.func) in self._CLOCK_CALLS:
                        yield self.finding(
                            ctx, node,
                            f"{_dotted(node.func)}() in clock-injectable "
                            f"class {cls.name} — use the injected clock")

    @staticmethod
    def _accepts_clock(cls: ast.ClassDef) -> bool:
        for method in cls.body:
            if isinstance(method, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and method.name == "__init__":
                args = method.args
                names = [a.arg for a in args.args + args.kwonlyargs]
                return "clock" in names
        return False


class StatsDirectMutationRule(Rule):
    id = "stats-direct-mutation"
    doc = ("assignment to a ComponentStats field bypassing the locked "
           "add()/set() helpers loses concurrent updates (PR 7 sweep)")

    def __init__(self, config: AnalysisConfig) -> None:
        self._fields = self._load_fields(config)
        self._stats_rel = config.stats_module.replace("\\", "/")

    @staticmethod
    def _load_fields(config: AnalysisConfig) -> frozenset[str]:
        path = config.root / config.stats_module
        fields: set[str] = set()
        if path.is_file():
            tree = ast.parse(path.read_text())
            for node in ast.walk(tree):
                if isinstance(node, ast.ClassDef) \
                        and node.name == "ComponentStats":
                    for stmt in node.body:
                        if isinstance(stmt, ast.AnnAssign) \
                                and isinstance(stmt.target, ast.Name) \
                                and not stmt.target.id.startswith("_"):
                            fields.add(stmt.target.id)
        return frozenset(fields)

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        if ctx.path == self._stats_rel or not self._fields:
            # the helpers themselves (and the dataclass defaults) live here
            return
        for node in ast.walk(ctx.tree):
            targets: list[ast.expr] = []
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                targets = [node.target]
            for t in targets:
                if not isinstance(t, ast.Attribute) \
                        or t.attr not in self._fields:
                    continue
                owner = t.value
                if isinstance(owner, ast.Attribute) and owner.attr == "stats" \
                        or isinstance(owner, ast.Name) and owner.id == "stats":
                    aug = "+= " if isinstance(node, ast.AugAssign) else "= "
                    yield self.finding(
                        ctx, node,
                        f"direct write {_dotted(t)} {aug.strip()}... — use "
                        "the locked ComponentStats.add()/set() helpers")


def default_rules(config: AnalysisConfig) -> list[Rule]:
    return [
        LockBlockingCallRule(),
        DurabilityRenameRule(),
        FaultSiteRegistryRule(config),
        NakedClockRule(),
        StatsDirectMutationRule(config),
    ]
