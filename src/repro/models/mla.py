"""Multi-head Latent Attention (DeepSeek-V2): the KV cache is a per-token
low-rank latent ``c_kv`` (kv_lora) plus one shared rope key — ~1/16 the bytes
of a dense GQA cache at this geometry.

Train/prefill use the decompressed formulation (k/v expanded per head);
decode uses the *absorbed* formulation: W_uk is folded into the query and
W_uv into the output, so per-step flops scale with kv_lora, not H·dh·S.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import NEG_INF, attention_prefill, attention_train
from .common import ShardCtx, apply_rope, causal_mask, rms_norm


def _split_q(q, cfg):
    b, s, _ = q.shape
    h = cfg.n_heads
    q = q.reshape(b, s, h, cfg.qk_nope_dim + cfg.qk_rope_dim)
    return q[..., :cfg.qk_nope_dim], q[..., cfg.qk_nope_dim:]


def _latents(h, p, cfg):
    kv_a = h @ p["wkv_a"]                                   # (B,S,lora+rope)
    c_kv = rms_norm(kv_a[..., :cfg.kv_lora], p["kv_norm"], cfg.norm_eps)
    k_rope = kv_a[..., cfg.kv_lora:]                        # (B,S,rope)
    return c_kv, k_rope


def _decompress(c_kv, p, cfg):
    b, s, _ = c_kv.shape
    h = cfg.n_heads
    k_nope = (c_kv @ p["wk_b"]).reshape(b, s, h, cfg.qk_nope_dim)
    v = (c_kv @ p["wv_b"]).reshape(b, s, h, cfg.v_head_dim)
    return k_nope, v


def mla_full(hid, p, cfg, ctx: ShardCtx, positions, mode: str):
    """Train/prefill. hid: (B,S,d). Returns (out, cache_entries)."""
    b, s, _ = hid.shape
    nh = cfg.n_heads
    q_nope, q_rope = _split_q(hid @ p["wq"], cfg)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    c_kv, k_rope = _latents(hid, p, cfg)
    k_rope = apply_rope(k_rope[..., None, :], positions, cfg.rope_theta)[..., 0, :]
    k_nope, v = _decompress(c_kv, p, cfg)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)          # (B,S,H,nope+rope)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (b, s, nh, cfg.qk_rope_dim))], axis=-1)
    # pad v up to qk dim for the shared attention helpers? No — helpers accept
    # differing value dim because out shape follows v.
    if mode == "train":
        out = attention_train(q, k, v, causal_mask(s, s), ctx)
    else:
        out = attention_prefill(q, k, v, ctx)
    out = out.reshape(b, s, nh * cfg.v_head_dim) @ p["wo"]
    return out, {"c_kv": c_kv, "k_rope": k_rope}


def mla_decode(hid, p, cfg, ctx: ShardCtx, cache, pos):
    """Absorbed decode. hid: (B,1,d); cache: c_kv (B,Smax,lora),
    k_rope (B,Smax,rope)."""
    b = hid.shape[0]
    nh = cfg.n_heads
    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    q_nope, q_rope = _split_q(hid @ p["wq"], cfg)           # (B,1,H,·)
    q_rope = apply_rope(q_rope, jnp.full((b, 1), pos), cfg.rope_theta)

    # write new latent into the cache
    c_new, kr_new = _latents(hid, p, cfg)
    kr_new = apply_rope(kr_new[..., None, :],
                        jnp.full((b, 1), pos), cfg.rope_theta)[..., 0, :]
    c_kv = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_new.astype(cache["c_kv"].dtype), pos, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), pos, axis=1)

    # absorbed scores: q_eff = q_nope @ W_uk  → (B,1,H,lora)
    wk_b = p["wk_b"].reshape(cfg.kv_lora, nh, cfg.qk_nope_dim)
    q_eff = jnp.einsum("bqhd,rhd->bqhr", q_nope, wk_b)
    s_nope = jnp.einsum("bqhr,bkr->bhqk", q_eff, c_kv)
    s_rope = jnp.einsum("bqhd,bkd->bhqk", q_rope, k_rope)
    s = ((s_nope + s_rope) * scale).astype(jnp.float32)
    valid = jnp.arange(c_kv.shape[1])[None, None, None, :] <= pos
    s = jnp.where(valid, s, NEG_INF)
    pweights = jax.nn.softmax(s, axis=-1).astype(hid.dtype)

    # absorbed values: weighted latent, then expand through W_uv
    ctx_lat = jnp.einsum("bhqk,bkr->bqhr", pweights, c_kv)   # (B,1,H,lora)
    wv_b = p["wv_b"].reshape(cfg.kv_lora, nh, cfg.v_head_dim)
    out = jnp.einsum("bqhr,rhv->bqhv", ctx_lat, wv_b)
    out = out.reshape(b, 1, nh * cfg.v_head_dim) @ p["wo"]
    return out, {"c_kv": c_kv, "k_rope": k_rope}
