"""Feed-forward variants: SwiGLU (llama family), squared-ReLU (nemotron-4),
GELU (whisper)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ShardCtx


def ffn_forward(h, p, kind: str, ctx: ShardCtx):
    """h: (B,S,d). p holds wi/wi_gate/wi_up/wo for this layer."""
    dp = ctx.dp or None
    def mid(x):        # (B,S,d_ff) sharded over model
        return ctx.cs(x, dp, None, "model") if ctx.mesh else x
    if kind == "swiglu":
        g = mid(h @ p["wi_gate"])
        u = mid(h @ p["wi_up"])
        z = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * u
    elif kind == "sq_relu":
        z = mid(h @ p["wi"])
        z = jnp.square(jax.nn.relu(z.astype(jnp.float32))).astype(h.dtype)
    elif kind == "gelu":
        z = mid(h @ p["wi"])
        z = jax.nn.gelu(z.astype(jnp.float32)).astype(h.dtype)
    else:
        raise ValueError(f"unknown ffn kind {kind!r}")
    return z @ p["wo"]
