"""Parameter templates per architecture family.

One place defines every tensor's shape, dtype, initializer and TP sharding
spec; init_params / abstract_params / param_spec_tree all derive from here.
Stacked per-layer tensors carry a leading num_layers axis (consumed by scan).
"""
from __future__ import annotations

from jax.sharding import PartitionSpec as P

from .common import ArchConfig, ParamDef


def _attn_defs(cfg: ArchConfig, L: int, prefix: str,
               n_heads=None, n_kv=None, d_head=None) -> dict[str, ParamDef]:
    h = n_heads or cfg.n_heads
    kv = n_kv or cfg.n_kv_heads
    dh = d_head or cfg.d_head
    d = cfg.d_model
    t = cfg.dtype
    out = {
        f"{prefix}/wq": ParamDef((L, d, h * dh), P(None, None, "model"), dtype=t),
        f"{prefix}/wk": ParamDef((L, d, kv * dh), P(None, None, "model"), dtype=t),
        f"{prefix}/wv": ParamDef((L, d, kv * dh), P(None, None, "model"), dtype=t),
        f"{prefix}/wo": ParamDef((L, h * dh, d), P(None, "model", None), dtype=t),
    }
    if cfg.qk_norm:
        out[f"{prefix}/q_norm"] = ParamDef((L, dh), P(), init="ones", dtype=t)
        out[f"{prefix}/k_norm"] = ParamDef((L, dh), P(), init="ones", dtype=t)
    if cfg.meta_tokens:
        m = cfg.meta_tokens
        out[f"{prefix}/meta_k"] = ParamDef((L, m, kv, dh), P(), dtype=t,
                                           fan_in=dh)
        out[f"{prefix}/meta_v"] = ParamDef((L, m, kv, dh), P(), dtype=t,
                                           fan_in=dh)
    return out


def _ffn_defs(cfg: ArchConfig, L: int, prefix: str, d_ff=None,
              kind=None) -> dict[str, ParamDef]:
    d, t = cfg.d_model, cfg.dtype
    f = d_ff or cfg.d_ff
    k = kind or cfg.ffn
    if k == "swiglu":
        return {
            f"{prefix}/wi_gate": ParamDef((L, d, f), P(None, None, "model"), dtype=t),
            f"{prefix}/wi_up": ParamDef((L, d, f), P(None, None, "model"), dtype=t),
            f"{prefix}/wo": ParamDef((L, f, d), P(None, "model", None), dtype=t),
        }
    return {
        f"{prefix}/wi": ParamDef((L, d, f), P(None, None, "model"), dtype=t),
        f"{prefix}/wo": ParamDef((L, f, d), P(None, "model", None), dtype=t),
    }


def _moe_defs(cfg: ArchConfig, L: int, prefix: str) -> dict[str, ParamDef]:
    d, t, e, f = cfg.d_model, cfg.dtype, cfg.n_experts, cfg.d_ff
    out = {
        f"{prefix}/router": ParamDef((L, d, e), P(), dtype=t),
        f"{prefix}/experts/wi_gate": ParamDef((L, e, d, f),
                                              P(None, "model", None, None), dtype=t),
        f"{prefix}/experts/wi_up": ParamDef((L, e, d, f),
                                            P(None, "model", None, None), dtype=t),
        f"{prefix}/experts/wo": ParamDef((L, e, f, d),
                                         P(None, "model", None, None), dtype=t,
                                         fan_in=f),
    }
    if cfg.n_shared_experts:
        fs = cfg.n_shared_experts * cfg.d_ff
        out.update(_ffn_defs(cfg, L, f"{prefix}/shared", d_ff=fs, kind="swiglu"))
    return out


def _mla_defs(cfg: ArchConfig, L: int, prefix: str) -> dict[str, ParamDef]:
    d, t, h = cfg.d_model, cfg.dtype, cfg.n_heads
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    return {
        f"{prefix}/wq": ParamDef((L, d, h * qk), P(None, None, "model"), dtype=t),
        f"{prefix}/wkv_a": ParamDef((L, d, cfg.kv_lora + cfg.qk_rope_dim),
                                    P(), dtype=t),
        f"{prefix}/kv_norm": ParamDef((L, cfg.kv_lora), P(), init="ones", dtype=t),
        f"{prefix}/wk_b": ParamDef((L, cfg.kv_lora, h * cfg.qk_nope_dim),
                                   P(None, None, "model"), dtype=t),
        f"{prefix}/wv_b": ParamDef((L, cfg.kv_lora, h * cfg.v_head_dim),
                                   P(None, None, "model"), dtype=t),
        f"{prefix}/wo": ParamDef((L, h * cfg.v_head_dim, d),
                                 P(None, "model", None), dtype=t),
    }


def _ssm_defs(cfg: ArchConfig, L: int, prefix: str) -> dict[str, ParamDef]:
    d, t = cfg.d_model, cfg.dtype
    di, h = cfg.d_inner, cfg.ssm_heads
    gn = cfg.ssm_ngroups * cfg.ssm_state
    k = cfg.ssm_conv
    return {
        f"{prefix}/in_z": ParamDef((L, d, di), P(None, None, "model"), dtype=t),
        f"{prefix}/in_x": ParamDef((L, d, di), P(None, None, "model"), dtype=t),
        f"{prefix}/in_B": ParamDef((L, d, gn), P(), dtype=t),
        f"{prefix}/in_C": ParamDef((L, d, gn), P(), dtype=t),
        f"{prefix}/in_dt": ParamDef((L, d, h), P(), dtype=t),
        f"{prefix}/dt_bias": ParamDef((L, h), P(), init="ssm_dt", dtype=t),
        f"{prefix}/conv_x": ParamDef((L, k, di), P(None, None, "model"),
                                     dtype=t, fan_in=k),
        f"{prefix}/conv_B": ParamDef((L, k, gn), P(), dtype=t, fan_in=k),
        f"{prefix}/conv_C": ParamDef((L, k, gn), P(), dtype=t, fan_in=k),
        f"{prefix}/A_log": ParamDef((L, h), P(), init="ssm_a", dtype=t),
        f"{prefix}/D": ParamDef((L, h), P(), init="ones", dtype=t),
        f"{prefix}/gate_norm": ParamDef((L, di), P(), init="ones", dtype=t),
        f"{prefix}/out_proj": ParamDef((L, di, d), P(None, "model", None),
                                       dtype=t, fan_in=di),
    }


def _norm(L: int, d: int, name: str, t) -> dict[str, ParamDef]:
    return {name: ParamDef((L, d), P(), init="ones", dtype=t)}


def template(cfg: ArchConfig) -> dict[str, ParamDef]:
    d, t, L, V = cfg.d_model, cfg.dtype, cfg.num_layers, cfg.vocab_size
    out: dict[str, ParamDef] = {
        "embed": ParamDef((V, d), P("model", None), dtype=t, fan_in=d),
        "lm_head": ParamDef((d, V), P(None, "model"), dtype=t),
        "final_norm": ParamDef((d,), P(), init="ones", dtype=t),
    }
    if cfg.family == "vlm":
        out["img_proj/w1"] = ParamDef((cfg.img_embed_dim, d),
                                      P(None, "model"), dtype=t)
        out["img_proj/w2"] = ParamDef((d, d), P("model", None), dtype=t)

    if cfg.family in ("dense", "vlm"):
        out.update(_norm(L, d, "layers/attn_norm", t))
        out.update(_attn_defs(cfg, L, "layers/attn"))
        out.update(_norm(L, d, "layers/ffn_norm", t))
        out.update(_ffn_defs(cfg, L, "layers/ffn"))

    elif cfg.family == "moe":
        out.update(_norm(L, d, "layers/attn_norm", t))
        if cfg.kv_lora:                               # deepseek: MLA attention
            out.update(_mla_defs(cfg, L, "layers/attn"))
        else:
            out.update(_attn_defs(cfg, L, "layers/attn"))
        out.update(_norm(L, d, "layers/ffn_norm", t))
        out.update(_moe_defs(cfg, L, "layers/moe"))

    elif cfg.family == "ssm":
        out.update(_norm(L, d, "layers/norm", t))
        out.update(_ssm_defs(cfg, L, "layers/ssm"))

    elif cfg.family == "hybrid":
        n_full = len(cfg.full_attn_layers)
        n_swa = L - n_full
        for name, n in (("layers_full", n_full), ("layers_swa", n_swa)):
            out.update(_norm(n, d, f"{name}/attn_norm", t))
            out.update(_attn_defs(cfg, n, f"{name}/attn"))
            out.update(_ssm_defs(cfg, n, f"{name}/ssm"))
            out[f"{name}/fuse/attn_out_norm"] = ParamDef((n, d), P(), init="ones", dtype=t)
            out[f"{name}/fuse/ssm_out_norm"] = ParamDef((n, d), P(), init="ones", dtype=t)
            out[f"{name}/fuse/beta_attn"] = ParamDef((n, d), P(), init="ones", dtype=t)
            out[f"{name}/fuse/beta_ssm"] = ParamDef((n, d), P(), init="ones", dtype=t)
            out.update(_norm(n, d, f"{name}/ffn_norm", t))
            out.update(_ffn_defs(cfg, n, f"{name}/ffn"))

    elif cfg.family == "encdec":
        E = cfg.enc_layers
        out.update(_norm(E, d, "enc_layers/attn_norm", t))
        out.update(_attn_defs(cfg, E, "enc_layers/attn"))
        out.update(_norm(E, d, "enc_layers/ffn_norm", t))
        out.update(_ffn_defs(cfg, E, "enc_layers/ffn"))
        out["enc_final_norm"] = ParamDef((d,), P(), init="ones", dtype=t)
        out.update(_norm(L, d, "layers/attn_norm", t))
        out.update(_attn_defs(cfg, L, "layers/attn"))
        out.update(_norm(L, d, "layers/cross_norm", t))
        out.update(_attn_defs(cfg, L, "layers/cross"))
        out.update(_norm(L, d, "layers/ffn_norm", t))
        out.update(_ffn_defs(cfg, L, "layers/ffn"))
    else:
        raise ValueError(f"unknown family {cfg.family!r}")
    return out
