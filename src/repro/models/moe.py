"""Mixture-of-Experts FFN: top-k routing with capacity-bounded scatter
dispatch (GShard-style token dropping, but WITHOUT the O(T·E·C·d) dispatch
einsum — tokens are scatter-added into per-expert capacity buffers, so the
dominant HLO flops are the expert matmuls themselves).

Sharding: experts over 'model' (expert parallelism); tokens over DP. GSPMD
turns the token→expert-buffer scatter into the EP dispatch collective, and
the gather back into the return path.

Covers: olmoe (64e top-8, no shared), deepseek-v2-lite (64e top-6 + 2 shared
experts; router-prob normalization over the selected experts).

An auxiliary load-balancing loss (Switch-style) is returned to the trainer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ShardCtx
from .ffn import ffn_forward


def moe_capacity(n_tokens: int, n_experts: int, top_k: int,
                 capacity_factor: float) -> int:
    cap = int(n_tokens * top_k * capacity_factor / n_experts + 0.5)
    return max(8, -(-cap // 8) * 8)                 # round up to 8


def moe_forward(h, p, cfg, ctx: ShardCtx):
    """h: (B,S,d) -> (B,S,d), aux_loss (scalar fp32).

    p: router (d,E); experts/{wi_gate,wi_up,wo}: (E,d,f),(E,d,f),(E,f,d);
       optional shared/{wi_gate,wi_up,wo} dense FFN.

    Two dispatch layouts:
      dense   — global (E,C,d) capacity buffer; the cross-DP scatter turns
                into an all-reduce of the whole buffer (baseline).
      chunked — per-data-shard capacity chunks aligned with the batch
                sharding; scatters stay shard-local and the expert einsum
                reshards tokens chunk→expert as a true all-to-all
                (EXPERIMENTS §Perf cell E).
    """
    b, s, d = h.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    if getattr(cfg, "moe_chunk_dispatch", False) and ctx.mesh is not None \
            and ctx.parallelism == "tp":
        chunks = ctx.mesh.shape["data"]
        if b % chunks == 0 or (t % chunks == 0 and s % chunks == 0):
            return _moe_forward_chunked(h, p, cfg, ctx, chunks)
    cap = moe_capacity(t, e, k, cfg.capacity_factor)
    x = h.reshape(t, d)

    # --- routing (fp32) ----------------------------------------------------
    logits = (x.astype(jnp.float32) @ p["router"].astype(jnp.float32))  # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)                     # (T,k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)     # normalize over top-k

    # aux load-balance loss (Switch): E * sum_e f_e * p_e
    me = probs.mean(axis=0)                                             # (E,)
    onehot_top1 = jax.nn.one_hot(expert_idx[:, 0], e, dtype=jnp.float32)
    ce = onehot_top1.mean(axis=0)
    aux = e * jnp.sum(me * ce)

    # --- capacity positions (rank of each (token,slot) within its expert) --
    sel = jax.nn.one_hot(expert_idx, e, dtype=jnp.int32)                # (T,k,E)
    sel_flat = sel.reshape(t * k, e)
    pos_in_expert = jnp.cumsum(sel_flat, axis=0) - sel_flat             # (T*k,E)
    pos = (pos_in_expert.reshape(t, k, e) * sel).sum(-1)                # (T,k)
    keep = pos < cap                                                     # drop overflow
    dest = jnp.where(keep, expert_idx * cap + pos, e * cap)             # sentinel

    # --- dispatch: scatter tokens into (E*C+1, d) buffers --------------------
    contrib = jnp.broadcast_to(x[:, None, :], (t, k, d)).reshape(t * k, d)
    contrib = contrib * keep.reshape(t * k, 1).astype(x.dtype)
    buf = jnp.zeros((e * cap + 1, d), x.dtype).at[dest.reshape(-1)].add(contrib)
    xe = buf[:e * cap].reshape(e, cap, d)
    xe = ctx.cs(xe, "model", None, None)

    # --- expert FFN (the real flops) ----------------------------------------
    g = jnp.einsum("ecd,edf->ecf", xe, p["experts"]["wi_gate"])
    u = jnp.einsum("ecd,edf->ecf", xe, p["experts"]["wi_up"])
    z = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * u
    ye = jnp.einsum("ecf,efd->ecd", z, p["experts"]["wo"])
    ye = ctx.cs(ye, "model", None, None)

    # --- gather back + weighted combine --------------------------------------
    ye_flat = jnp.concatenate([ye.reshape(e * cap, d),
                               jnp.zeros((1, d), ye.dtype)], axis=0)
    back = ye_flat[dest.reshape(-1)].reshape(t, k, d)
    y = (back.astype(jnp.float32) * gate_vals[..., None]).sum(axis=1)
    y = y.astype(h.dtype).reshape(b, s, d)

    if "shared" in p:                                  # deepseek shared experts
        y = y + ffn_forward(h, p["shared"], "swiglu", ctx)
    return y, aux


def _moe_forward_chunked(h, p, cfg, ctx: ShardCtx, chunks: int):
    """EP dispatch with per-chunk capacity; chunks align with the 'data'
    batch sharding so routing/scatter are shard-local and GSPMD moves only
    tokens (all-to-all) between the chunk and expert shardings."""
    b, s, d = h.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    tc = t // chunks
    cap = moe_capacity(tc, e, k, cfg.capacity_factor)
    x = h.reshape(chunks, tc, d)
    x = ctx.cs(x, "data", None, None)

    logits = x.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                     # (X,Tc,E)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)             # (X,Tc,k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    me = probs.reshape(t, e).mean(axis=0)
    onehot_top1 = jax.nn.one_hot(expert_idx[..., 0].reshape(t), e,
                                 dtype=jnp.float32)
    aux = e * jnp.sum(me * onehot_top1.mean(axis=0))

    sel = jax.nn.one_hot(expert_idx, e, dtype=jnp.int32)        # (X,Tc,k,E)
    sel_flat = sel.reshape(chunks, tc * k, e)
    pos = (jnp.cumsum(sel_flat, axis=1) - sel_flat)             # per-chunk rank
    pos = (pos.reshape(chunks, tc, k, e) * sel).sum(-1)         # (X,Tc,k)
    keep = pos < cap
    dest = jnp.where(keep, expert_idx * cap + pos, e * cap)     # (X,Tc,k)

    contrib = jnp.broadcast_to(x[:, :, None, :], (chunks, tc, k, d))
    contrib = (contrib * keep[..., None].astype(x.dtype)
               ).reshape(chunks, tc * k, d)
    buf = jnp.zeros((chunks, e * cap + 1, d), x.dtype)
    buf = buf.at[jnp.arange(chunks)[:, None],
                 dest.reshape(chunks, tc * k)].add(contrib)
    xe = buf[:, :e * cap].reshape(chunks, e, cap, d)
    # chunk axis on 'data', expert axis on 'model': the reshard that feeds
    # the expert matmul is the EP all-to-all
    xe = ctx.cs(xe, "data", "model", None, None)

    g = jnp.einsum("xecd,edf->xecf", xe, p["experts"]["wi_gate"])
    u = jnp.einsum("xecd,edf->xecf", xe, p["experts"]["wi_up"])
    z = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * u
    ye = jnp.einsum("xecf,efd->xecd", z, p["experts"]["wo"])
    ye = ctx.cs(ye, "data", "model", None, None)

    ye_flat = jnp.concatenate(
        [ye.reshape(chunks, e * cap, d),
         jnp.zeros((chunks, 1, d), ye.dtype)], axis=1)
    ye_flat = ctx.cs(ye_flat, "data", None, None)               # a2a back
    back = ye_flat[jnp.arange(chunks)[:, None],
                   dest.reshape(chunks, tc * k)]
    back = back.reshape(chunks, tc, k, d)
    y = (back.astype(jnp.float32) * gate_vals[..., None]).sum(axis=2)
    y = y.astype(h.dtype).reshape(b, s, d)
    if "shared" in p:
        y = y + ffn_forward(h, p["shared"], "swiglu", ctx)
    return y, aux
