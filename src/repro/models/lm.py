"""Model assembly: one class serving all six families
(dense / vlm / moe[+mla] / ssm / hybrid / encdec) with three entry points:

  loss_fn(params, batch)            — training loss (CE + MoE aux)
  prefill(params, batch)            — full-sequence forward → (last logits, cache)
  decode_step(params, cache, tok)   — one token with KV/SSM cache

Layers are stacked and consumed by lax.scan (remat per layer); the hybrid
family splits its stack into full-attention and sliding-window sub-stacks so
SWA layers keep O(window) ring caches instead of O(context).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import mamba as mb
from . import mla
from .attention import (attention_decode, attention_prefill, attention_train,
                        update_kv_cache)
from .common import (ArchConfig, ShardCtx, abstract_params, apply_rope,
                     causal_mask, cross_entropy_loss, dp_axes, init_params,
                     rms_norm, swa_mask, unflatten)
from .ffn import ffn_forward
from .moe import moe_forward

PAD_ID = 256
MOE_AUX_WEIGHT = 0.01


def _kv_quantize(t):
    """Per-token-per-head absmax int8: t (..., dh) -> (int8 codes, f32 scale
    over the dh axis)."""
    tf = t.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(tf), axis=-1), 1e-8) / 127.0
    q = jnp.clip(jnp.round(tf / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale


def _kv_dequantize(q, scale, dtype):
    return (q.astype(jnp.float32) * scale[..., None].astype(jnp.float32)
            ).astype(dtype)


# ---------------------------------------------------------------------------
def _tree_slice(tree, start: int, size: int):
    return jax.tree.map(lambda a: jax.lax.slice_in_dim(a, start, start + size,
                                                       axis=0), tree)


def _hybrid_plan(cfg: ArchConfig):
    """Execution order of (kind, index-within-stack, count) segments."""
    full = set(cfg.full_attn_layers)
    plan, i_full, i_swa = [], 0, 0
    run = 0
    for layer in range(cfg.num_layers):
        if layer in full:
            if run:
                plan.append(("swa", i_swa, run)); i_swa += run; run = 0
            plan.append(("full", i_full, 1)); i_full += 1
        else:
            run += 1
    if run:
        plan.append(("swa", i_swa, run))
    return plan


class Model:
    def __init__(self, cfg: ArchConfig, mesh: Mesh | None = None,
                 parallelism: str = "tp") -> None:
        self.cfg = cfg
        self.mesh = mesh
        self.parallelism = parallelism
        self.ctx = ShardCtx(mesh, cfg, parallelism)
        self._dec_hints = (None, None)   # (batch spec, cache seq spec)

    # -- params ----------------------------------------------------------------
    def init(self, rng):
        return init_params(self.cfg, rng)

    def abstract_params(self):
        return abstract_params(self.cfg, self.mesh, self.parallelism)

    # -- embedding / head -------------------------------------------------------
    def _embed(self, params, tokens):
        h = jnp.take(params["embed"], tokens, axis=0)
        return self.ctx.act(h)

    def _fuse_images(self, params, h, image_embeds):
        w1, w2 = params["img_proj"]["w1"], params["img_proj"]["w2"]
        img = jax.nn.gelu((image_embeds.astype(w1.dtype) @ w1)
                          .astype(jnp.float32)).astype(h.dtype) @ w2
        n = img.shape[1]
        return jnp.concatenate([img, h[:, n:]], axis=1)

    def _logits(self, params, h):
        h = rms_norm(h, params["final_norm"], self.cfg.norm_eps)
        return h @ params["lm_head"]

    # ------------------------------------------------------------------
    # Attention sub-blocks (GQA; qk-norm; meta tokens; SWA)
    # ------------------------------------------------------------------
    def _qkv(self, x, ap, positions=None, rope: bool = True):
        cfg = self.cfg
        b, s, _ = x.shape
        q = (x @ ap["wq"]).reshape(b, s, cfg.n_heads, cfg.d_head)
        k = (x @ ap["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.d_head)
        v = (x @ ap["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.d_head)
        if cfg.qk_norm:
            q = rms_norm(q, ap["q_norm"], cfg.norm_eps)
            k = rms_norm(k, ap["k_norm"], cfg.norm_eps)
        if rope and positions is not None:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
        return q, k, v

    def _attn_full_seq(self, x, ap, positions, mode: str, *, window: int = 0,
                       bidir: bool = False, want_cache: bool = False):
        """Self-attention over a full sequence (train or prefill)."""
        cfg, ctx = self.cfg, self.ctx
        b, s, _ = x.shape
        q, k, v = self._qkv(x, ap, positions)
        cache = None
        if want_cache:
            if cfg.kv_quant:
                kq, ks = _kv_quantize(k)
                vq, vs = _kv_quantize(v)
                cache = {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
            else:
                cache = {"k": k, "v": v}
        prefix = 0
        if cfg.meta_tokens:
            mk = jnp.broadcast_to(ap["meta_k"][None], (b,) + ap["meta_k"].shape)
            mv = jnp.broadcast_to(ap["meta_v"][None], (b,) + ap["meta_v"].shape)
            k = jnp.concatenate([mk.astype(k.dtype), k], axis=1)
            v = jnp.concatenate([mv.astype(v.dtype), v], axis=1)
            prefix = cfg.meta_tokens
        if mode == "train" or bidir:
            if bidir:
                mask = jnp.ones((s, k.shape[1]), bool)
            else:
                base = (swa_mask(s, s, window) if window
                        else causal_mask(s, s))
                if prefix:
                    mask = jnp.concatenate(
                        [jnp.ones((s, prefix), bool), base], axis=1)
                else:
                    mask = base
            o = attention_train(q, k, v, mask, ctx)
        else:
            o = attention_prefill(q, k, v, ctx, window=window, prefix=prefix)
        return o.reshape(b, s, -1) @ ap["wo"], cache

    def _decode_shard_hints(self, batch: int):
        """Mirror of cache_template's layout decision, used to pin the
        flash-decode sharding pattern (see attention_decode docstring)."""
        mesh = self.mesh
        if mesh is None:
            return (None, None)
        dp = self.ctx.dp
        dp_total = 1
        for a in dp:
            dp_total *= mesh.shape[a]
        bshard = dp if (batch % max(dp_total, 1) == 0
                        and batch >= dp_total) else None
        if self.parallelism == "fsdp":
            seq = None if bshard is not None else ("data", "model")
        elif bshard is None:
            seq = ("data", "model")
        else:
            seq = "model" if not self.ctx.kv_head_sharded else None
        return (bshard, seq)

    def _attn_decode(self, x, ap, cache_l, pos, *, window: int = 0):
        """One-token self-attention against a cache (ring buffer when SWA)."""
        cfg, ctx = self.cfg, self.ctx
        bspec, seq_spec = self._dec_hints
        b = x.shape[0]
        positions = jnp.full((b, 1), pos)
        q, k_new, v_new = self._qkv(x, ap, positions)
        ring = window if (window and cache_l["k"].shape[1] == window) else 0
        if cfg.kv_quant:
            kq, ks = _kv_quantize(k_new)
            vq, vs = _kv_quantize(v_new)
            idx = pos % ring if ring else pos
            kc = jax.lax.dynamic_update_slice_in_dim(cache_l["k"], kq, idx, 1)
            vc = jax.lax.dynamic_update_slice_in_dim(cache_l["v"], vq, idx, 1)
            ksc = jax.lax.dynamic_update_slice_in_dim(
                cache_l["k_scale"], ks, idx, 1)
            vsc = jax.lax.dynamic_update_slice_in_dim(
                cache_l["v_scale"], vs, idx, 1)
            new_cache = {"k": kc, "v": vc, "k_scale": ksc, "v_scale": vsc}
            # dequant at read; on TPU this fuses into the decode kernel's
            # HBM->VMEM stream (the Pallas decode kernel reads int8 tiles)
            k_cache = _kv_dequantize(kc, ksc, x.dtype)
            v_cache = _kv_dequantize(vc, vsc, x.dtype)
        else:
            k_cache, v_cache = update_kv_cache(cache_l["k"], cache_l["v"],
                                               k_new, v_new, pos,
                                               ring_window=ring)
            new_cache = {"k": k_cache, "v": v_cache}
        if cfg.meta_tokens:
            mk = jnp.broadcast_to(ap["meta_k"][None], (b,) + ap["meta_k"].shape)
            mv = jnp.broadcast_to(ap["meta_v"][None], (b,) + ap["meta_v"].shape)
            m = cfg.meta_tokens
            smax = k_cache.shape[1]
            kj = jnp.concatenate([mk.astype(k_cache.dtype), k_cache], axis=1)
            vj = jnp.concatenate([mv.astype(v_cache.dtype), v_cache], axis=1)
            j = jnp.arange(m + smax)
            if ring:
                tail_ok = (j - m) < jnp.minimum(pos + 1, smax)
            else:
                tail_ok = (j - m) <= pos
                if window:
                    tail_ok &= (pos - (j - m)) < window
            valid = (j < m) | tail_ok
            o = attention_decode(q, kj, vj, pos, ctx, valid=valid,
                                 bspec=bspec, seq_spec=seq_spec)
        else:
            o = attention_decode(q, k_cache, v_cache, pos, ctx,
                                 window=0 if ring else window, ring=bool(ring),
                                 bspec=bspec, seq_spec=seq_spec)
        return o.reshape(b, 1, -1) @ ap["wo"], new_cache

    # ------------------------------------------------------------------
    # Per-family blocks. Each returns (h, extras).
    # ------------------------------------------------------------------
    def _block_dense(self, h, lp, positions, mode, want_cache=False,
                     window=0, bidir=False):
        cfg, ctx = self.cfg, self.ctx
        x = rms_norm(h, lp["attn_norm"], cfg.norm_eps)
        attn, cache = self._attn_full_seq(x, lp["attn"], positions, mode,
                                          window=window, bidir=bidir,
                                          want_cache=want_cache)
        h = ctx.act(h + attn)
        x = rms_norm(h, lp["ffn_norm"], cfg.norm_eps)
        h = ctx.act(h + ffn_forward(x, lp["ffn"], cfg.ffn, ctx))
        return h, cache

    def _block_dense_decode(self, h, lp, cache_l, pos, window=0):
        cfg, ctx = self.cfg, self.ctx
        x = rms_norm(h, lp["attn_norm"], cfg.norm_eps)
        attn, new_cache = self._attn_decode(x, lp["attn"], cache_l, pos,
                                            window=window)
        h = ctx.act(h + attn)
        x = rms_norm(h, lp["ffn_norm"], cfg.norm_eps)
        h = ctx.act(h + ffn_forward(x, lp["ffn"], cfg.ffn, ctx))
        return h, new_cache

    def _block_moe(self, h, lp, positions, mode, want_cache=False):
        cfg, ctx = self.cfg, self.ctx
        x = rms_norm(h, lp["attn_norm"], cfg.norm_eps)
        if cfg.kv_lora:
            attn, cache = mla.mla_full(x, lp["attn"], cfg, ctx, positions, mode)
            if not want_cache:
                cache = None
        else:
            attn, cache = self._attn_full_seq(x, lp["attn"], positions, mode,
                                              want_cache=want_cache)
        h = ctx.act(h + attn)
        x = rms_norm(h, lp["ffn_norm"], cfg.norm_eps)
        y, aux = moe_forward(x, lp["moe"], cfg, ctx)
        h = ctx.act(h + y)
        return h, (cache, aux)

    def _block_moe_decode(self, h, lp, cache_l, pos):
        cfg, ctx = self.cfg, self.ctx
        x = rms_norm(h, lp["attn_norm"], cfg.norm_eps)
        if cfg.kv_lora:
            attn, new_cache = mla.mla_decode(x, lp["attn"], cfg, ctx,
                                             cache_l, pos)
        else:
            attn, new_cache = self._attn_decode(x, lp["attn"], cache_l, pos)
        h = ctx.act(h + attn)
        x = rms_norm(h, lp["ffn_norm"], cfg.norm_eps)
        y, _ = moe_forward(x, lp["moe"], cfg, ctx)
        h = ctx.act(h + y)
        return h, new_cache

    def _block_ssm(self, h, lp, mode):
        cfg, ctx = self.cfg, self.ctx
        x = rms_norm(h, lp["norm"], cfg.norm_eps)
        if mode == "prefill":
            y, cache = mb.mamba_prefill(x, lp["ssm"], cfg, ctx)
            return ctx.act(h + y), cache
        y = mb.mamba_forward(x, lp["ssm"], cfg, ctx)
        return ctx.act(h + y), None

    def _block_ssm_decode(self, h, lp, cache_l, pos):
        cfg, ctx = self.cfg, self.ctx
        x = rms_norm(h, lp["norm"], cfg.norm_eps)
        y, new_cache = mb.mamba_decode(x, lp["ssm"], cfg, ctx, cache_l)
        return ctx.act(h + y), new_cache

    def _block_hybrid(self, h, lp, positions, mode, *, window, want_cache):
        cfg, ctx = self.cfg, self.ctx
        x = rms_norm(h, lp["attn_norm"], cfg.norm_eps)
        attn, acache = self._attn_full_seq(x, lp["attn"], positions, mode,
                                           window=window,
                                           want_cache=want_cache)
        if mode == "prefill":
            sy, scache = mb.mamba_prefill(x, lp["ssm"], cfg, ctx)
        else:
            sy, scache = mb.mamba_forward(x, lp["ssm"], cfg, ctx), None
        f = lp["fuse"]
        fused = 0.5 * (rms_norm(attn, f["attn_out_norm"], cfg.norm_eps)
                       * f["beta_attn"]
                       + rms_norm(sy, f["ssm_out_norm"], cfg.norm_eps)
                       * f["beta_ssm"])
        h = ctx.act(h + fused)
        x = rms_norm(h, lp["ffn_norm"], cfg.norm_eps)
        h = ctx.act(h + ffn_forward(x, lp["ffn"], cfg.ffn, ctx))
        cache = None
        if want_cache:
            if window:      # keep only the trailing ring window
                s = acache["k"].shape[1]
                w = min(window, s)
                acache = {"k": acache["k"][:, s - w:],
                          "v": acache["v"][:, s - w:]}
            cache = {"attn": acache, "ssm": scache}
        return h, cache

    def _block_hybrid_decode(self, h, lp, cache_l, pos, *, window):
        cfg, ctx = self.cfg, self.ctx
        x = rms_norm(h, lp["attn_norm"], cfg.norm_eps)
        attn, new_ac = self._attn_decode(x, lp["attn"], cache_l["attn"], pos,
                                         window=window)
        sy, new_sc = mb.mamba_decode(x, lp["ssm"], cfg, ctx, cache_l["ssm"])
        f = lp["fuse"]
        fused = 0.5 * (rms_norm(attn, f["attn_out_norm"], cfg.norm_eps)
                       * f["beta_attn"]
                       + rms_norm(sy, f["ssm_out_norm"], cfg.norm_eps)
                       * f["beta_ssm"])
        h = ctx.act(h + fused)
        x = rms_norm(h, lp["ffn_norm"], cfg.norm_eps)
        h = ctx.act(h + ffn_forward(x, lp["ffn"], cfg.ffn, ctx))
        return h, {"attn": new_ac, "ssm": new_sc}

    def _block_encdec_dec(self, h, lp, enc_out, positions, mode,
                          want_cache=False):
        cfg, ctx = self.cfg, self.ctx
        x = rms_norm(h, lp["attn_norm"], cfg.norm_eps)
        attn, cache = self._attn_full_seq(x, lp["attn"], positions, mode,
                                          want_cache=want_cache)
        h = ctx.act(h + attn)
        x = rms_norm(h, lp["cross_norm"], cfg.norm_eps)
        b, s, _ = x.shape
        q = (x @ lp["cross"]["wq"]).reshape(b, s, cfg.n_heads, cfg.d_head)
        ek = (enc_out @ lp["cross"]["wk"]).reshape(
            b, -1, cfg.n_kv_heads, cfg.d_head)
        ev = (enc_out @ lp["cross"]["wv"]).reshape(
            b, -1, cfg.n_kv_heads, cfg.d_head)
        mask = jnp.ones((s, ek.shape[1]), bool)
        cross = attention_train(q, ek, ev, mask, ctx)
        h = ctx.act(h + cross.reshape(b, s, -1) @ lp["cross"]["wo"])
        x = rms_norm(h, lp["ffn_norm"], cfg.norm_eps)
        h = ctx.act(h + ffn_forward(x, lp["ffn"], cfg.ffn, ctx))
        if want_cache:
            cache = {"self": cache, "cross_k": ek, "cross_v": ev}
        return h, cache

    def _block_encdec_dec_decode(self, h, lp, cache_l, pos):
        cfg, ctx = self.cfg, self.ctx
        x = rms_norm(h, lp["attn_norm"], cfg.norm_eps)
        attn, new_self = self._attn_decode(x, lp["attn"], cache_l["self"], pos)
        h = ctx.act(h + attn)
        x = rms_norm(h, lp["cross_norm"], cfg.norm_eps)
        b = x.shape[0]
        q = (x @ lp["cross"]["wq"]).reshape(b, 1, cfg.n_heads, cfg.d_head)
        bspec, seq_spec = self._dec_hints
        o = attention_decode(q, cache_l["cross_k"], cache_l["cross_v"],
                             cache_l["cross_k"].shape[1] - 1, ctx,
                             bspec=bspec, seq_spec=seq_spec)
        h = ctx.act(h + o.reshape(b, 1, -1) @ lp["cross"]["wo"])
        x = rms_norm(h, lp["ffn_norm"], cfg.norm_eps)
        h = ctx.act(h + ffn_forward(x, lp["ffn"], cfg.ffn, ctx))
        return h, {"self": new_self, "cross_k": cache_l["cross_k"],
                   "cross_v": cache_l["cross_v"]}

    # ------------------------------------------------------------------
    # Scans
    # ------------------------------------------------------------------
    def _scan(self, body, h, layer_tree, extra_xs=None):
        if extra_xs is None:
            xs = layer_tree
        else:
            xs = (layer_tree, extra_xs)

        def pinned(carry, x):
            if self.parallelism == "fsdp":
                x = jax.tree.map(self.ctx.layer_param, x)
            return body(carry, x)

        wrapped = jax.checkpoint(pinned)
        return jax.lax.scan(wrapped, h, xs)

    # ------------------------------------------------------------------
    # Full-sequence forward (train / prefill)
    # ------------------------------------------------------------------
    def forward(self, params, batch, mode: str = "train"):
        """Returns (logits, extras) where extras = {'aux': scalar,
        'cache': pytree or None}."""
        cfg, ctx = self.cfg, self.ctx
        tokens = batch["tokens"] if mode != "train" else batch["tokens"][:, :-1]
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        h = self._embed(params, tokens)
        if cfg.family == "vlm":
            h = self._fuse_images(params, h, batch["image_embeds"])
        want_cache = mode == "prefill"
        aux_total = jnp.zeros((), jnp.float32)
        cache: dict[str, Any] = {}

        if cfg.family in ("dense", "vlm"):
            def body(hh, lp):
                hh, c = self._block_dense(hh, lp, positions, mode,
                                          want_cache=want_cache,
                                          window=cfg.sliding_window)
                return hh, c
            h, layer_cache = self._scan(body, h, params["layers"])
            cache["layers"] = layer_cache

        elif cfg.family == "moe":
            def body(hh, lp):
                hh, (c, aux) = self._block_moe(hh, lp, positions, mode,
                                               want_cache=want_cache)
                return hh, (c, aux)
            h, (layer_cache, auxes) = self._scan(body, h, params["layers"])
            aux_total = jnp.sum(auxes)
            cache["layers"] = layer_cache

        elif cfg.family == "ssm":
            def body(hh, lp):
                return self._block_ssm(hh, lp, mode)
            h, layer_cache = self._scan(body, h, params["layers"])
            cache["layers"] = layer_cache

        elif cfg.family == "hybrid":
            caches_full, caches_swa = [], []
            for kind, idx, count in _hybrid_plan(cfg):
                stack = params["layers_full" if kind == "full" else "layers_swa"]
                seg = _tree_slice(stack, idx, count)
                window = 0 if kind == "full" else cfg.sliding_window
                def body(hh, lp, _w=window):
                    return self._block_hybrid(hh, lp, positions, mode,
                                              window=_w,
                                              want_cache=want_cache)
                h, seg_cache = self._scan(body, h, seg)
                (caches_full if kind == "full" else caches_swa).append(seg_cache)
            if want_cache:
                cache["layers_full"] = jax.tree.map(
                    lambda *xs: jnp.concatenate(xs, 0), *caches_full)
                cache["layers_swa"] = jax.tree.map(
                    lambda *xs: jnp.concatenate(xs, 0), *caches_swa)

        elif cfg.family == "encdec":
            enc = batch["enc_frames"].astype(cfg.dtype)
            ep = jnp.broadcast_to(jnp.arange(enc.shape[1])[None],
                                  (b, enc.shape[1]))
            def enc_body(hh, lp):
                hh, _ = self._block_dense(hh, lp, ep, "train", bidir=True)
                return hh, None
            enc_h = ctx.act(enc)
            enc_h, _ = self._scan(enc_body, enc_h, params["enc_layers"])
            enc_out = rms_norm(enc_h, params["enc_final_norm"], cfg.norm_eps)

            def dec_body(hh, lp):
                return self._block_encdec_dec(hh, lp, enc_out, positions,
                                              mode, want_cache=want_cache)
            h, layer_cache = self._scan(dec_body, h, params["layers"])
            cache["layers"] = layer_cache
        else:
            raise ValueError(cfg.family)

        logits = self._logits(params, h)
        return logits, {"aux": aux_total, "cache": cache if want_cache else None}

    # ------------------------------------------------------------------
    def loss_fn(self, params, batch):
        cfg = self.cfg
        logits, extras = self.forward(params, batch, mode="train")
        labels = batch["tokens"][:, 1:]
        mask = labels != PAD_ID
        if cfg.family == "vlm":
            pos = jnp.arange(labels.shape[1])[None]
            mask &= pos >= cfg.img_tokens
        loss = cross_entropy_loss(logits, labels, mask)
        return loss + MOE_AUX_WEIGHT * extras["aux"], {
            "ce_loss": loss, "aux_loss": extras["aux"]}

    # ------------------------------------------------------------------
    def prefill(self, params, batch, max_len: int | None = None):
        """max_len reserves cache room for subsequent decode_step growth."""
        logits, extras = self.forward(params, batch, mode="prefill")
        cache = extras["cache"]
        s = batch["tokens"].shape[1]
        if max_len is not None and max_len > s:
            cache = self._grow_cache(cache, batch["tokens"].shape[0],
                                     s, max_len)
        cache["pos"] = jnp.asarray(s, jnp.int32)
        return logits[:, -1], cache

    def _grow_cache(self, cache, batch_size: int, s: int, max_len: int):
        """Zero-pad sequence axes up to the decode-time cache template
        (ring/SWA and SSM leaves already have their final shapes)."""
        if self.cfg.sliding_window:
            w = self.cfg.sliding_window
            assert s <= w or s % w == 0, \
                "prompt must be <= window or a window multiple (ring layout)"
        target = self.abstract_cache(batch_size, max_len)
        target.pop("pos", None)

        def pad(x, t):
            if tuple(x.shape) == tuple(t.shape):
                return x
            pads = [(0, ts - xs) for xs, ts in zip(x.shape, t.shape)]
            assert all(p[1] >= 0 for p in pads), (x.shape, t.shape)
            return jnp.pad(x, pads)

        return jax.tree.map(pad, cache, target)

    # ------------------------------------------------------------------
    def decode_step(self, params, cache, tokens):
        """tokens: (B,1) — returns (logits (B,V), new_cache)."""
        cfg, ctx = self.cfg, self.ctx
        pos = cache["pos"]
        self._dec_hints = self._decode_shard_hints(tokens.shape[0])
        h = self._embed(params, tokens)
        new_cache: dict[str, Any] = {"pos": pos + 1}

        if cfg.family in ("dense", "vlm"):
            def body(hh, xs):
                lp, cl = xs
                return self._block_dense_decode(hh, lp, cl, pos,
                                                window=cfg.sliding_window)
            h, nc = jax.lax.scan(body, h, (params["layers"], cache["layers"]))
            new_cache["layers"] = nc

        elif cfg.family == "moe":
            def body(hh, xs):
                lp, cl = xs
                return self._block_moe_decode(hh, lp, cl, pos)
            h, nc = jax.lax.scan(body, h, (params["layers"], cache["layers"]))
            new_cache["layers"] = nc

        elif cfg.family == "ssm":
            def body(hh, xs):
                lp, cl = xs
                return self._block_ssm_decode(hh, lp, cl, pos)
            h, nc = jax.lax.scan(body, h, (params["layers"], cache["layers"]))
            new_cache["layers"] = nc

        elif cfg.family == "hybrid":
            nc_full, nc_swa = [], []
            for kind, idx, count in _hybrid_plan(cfg):
                stack_name = "layers_full" if kind == "full" else "layers_swa"
                seg_p = _tree_slice(params[stack_name], idx, count)
                seg_c = _tree_slice(cache[stack_name], idx, count)
                window = 0 if kind == "full" else cfg.sliding_window
                def body(hh, xs, _w=window):
                    lp, cl = xs
                    return self._block_hybrid_decode(hh, lp, cl, pos, window=_w)
                h, nc = jax.lax.scan(body, h, (seg_p, seg_c))
                (nc_full if kind == "full" else nc_swa).append(nc)
            new_cache["layers_full"] = jax.tree.map(
                lambda *xs: jnp.concatenate(xs, 0), *nc_full)
            new_cache["layers_swa"] = jax.tree.map(
                lambda *xs: jnp.concatenate(xs, 0), *nc_swa)

        elif cfg.family == "encdec":
            def body(hh, xs):
                lp, cl = xs
                return self._block_encdec_dec_decode(hh, lp, cl, pos)
            h, nc = jax.lax.scan(body, h, (params["layers"], cache["layers"]))
            new_cache["layers"] = nc
        else:
            raise ValueError(cfg.family)

        logits = self._logits(params, h)[:, 0]
        return logits, new_cache

    # ------------------------------------------------------------------
    # Cache construction
    # ------------------------------------------------------------------
    def cache_template(self, batch: int, max_len: int):
        """Flat path -> (shape, dtype, PartitionSpec)."""
        cfg = self.cfg
        mesh = self.mesh
        dp = self.ctx.dp
        dp_total = 1
        if mesh is not None:
            for a in dp:
                dp_total *= mesh.shape[a]
        bshard = dp if (mesh is not None and batch % max(dp_total, 1) == 0
                        and batch >= dp_total) else None
        # cache layout: shard the KV head axis only when the KV head count
        # divides TP (repeat-KV archs keep heads whole, shard the seq axis)
        if self.parallelism == "fsdp":
            head_shard = None
            seq_shard = None if bshard is not None else ("data", "model")
        elif bshard is None and mesh is not None:
            seq_shard = ("data", "model")       # batch too small: split seq wide
            head_shard = None
        else:
            seq_shard = "model" if not self.ctx.kv_head_sharded else None
            head_shard = "model" if self.ctx.kv_head_sharded else None
        t = cfg.dtype
        out: dict[str, tuple] = {"pos": ((), jnp.int32, P())}

        def kv(prefix, L, s_len, n_kv, dh, seq_sh):
            kv_t = jnp.int8 if cfg.kv_quant else t
            out[f"{prefix}/k"] = ((L, batch, s_len, n_kv, dh), kv_t,
                                  P(None, bshard, seq_sh, head_shard, None))
            out[f"{prefix}/v"] = ((L, batch, s_len, n_kv, dh), kv_t,
                                  P(None, bshard, seq_sh, head_shard, None))
            if cfg.kv_quant:
                for nm in ("k_scale", "v_scale"):
                    out[f"{prefix}/{nm}"] = (
                        (L, batch, s_len, n_kv), jnp.float32,
                        P(None, bshard, seq_sh, head_shard))

        def ssm(prefix, L):
            h_sh = "model" if (mesh is not None
                               and cfg.ssm_heads % mesh.shape["model"] == 0) else None
            out[f"{prefix}/ssm"] = ((L, batch, cfg.ssm_heads, cfg.ssm_state,
                                     cfg.ssm_headdim), jnp.float32,
                                    P(None, bshard, h_sh, None, None))
            k = cfg.ssm_conv
            gn = cfg.ssm_ngroups * cfg.ssm_state
            di_sh = "model" if (mesh is not None
                                and cfg.ssm_heads % mesh.shape["model"] == 0) else None
            out[f"{prefix}/conv_x"] = ((L, batch, k - 1, cfg.d_inner), t,
                                       P(None, bshard, None, di_sh))
            out[f"{prefix}/conv_B"] = ((L, batch, k - 1, gn), t,
                                       P(None, bshard, None, None))
            out[f"{prefix}/conv_C"] = ((L, batch, k - 1, gn), t,
                                       P(None, bshard, None, None))

        L = cfg.num_layers
        if cfg.family in ("dense", "vlm"):
            kv("layers", L, max_len, cfg.n_kv_heads, cfg.d_head, seq_shard)
        elif cfg.family == "moe":
            if cfg.kv_lora:
                lora_sh = "model" if mesh is not None else None
                out["layers/c_kv"] = ((L, batch, max_len, cfg.kv_lora), t,
                                      P(None, bshard, None, lora_sh))
                out["layers/k_rope"] = ((L, batch, max_len, cfg.qk_rope_dim), t,
                                        P(None, bshard, None, lora_sh))
            else:
                kv("layers", L, max_len, cfg.n_kv_heads, cfg.d_head, seq_shard)
        elif cfg.family == "ssm":
            ssm("layers", L)
        elif cfg.family == "hybrid":
            n_full = len(cfg.full_attn_layers)
            n_swa = L - n_full
            w = min(cfg.sliding_window, max_len)
            kv("layers_full/attn", n_full, max_len, cfg.n_kv_heads,
               cfg.d_head, seq_shard)
            kv("layers_swa/attn", n_swa, w, cfg.n_kv_heads, cfg.d_head,
               "model" if (mesh is not None and not self.ctx.head_sharded
                           and w % mesh.shape["model"] == 0) else None)
            ssm("layers_full/ssm", n_full)
            ssm("layers_swa/ssm", n_swa)
        elif cfg.family == "encdec":
            kv("layers/self", L, max_len, cfg.n_kv_heads, cfg.d_head, seq_shard)
            kv("layers/cross", L, cfg.enc_seq, cfg.n_kv_heads, cfg.d_head,
               seq_shard)
        return out

    def init_cache(self, batch: int, max_len: int):
        tmpl = self.cache_template(batch, max_len)
        flat = {}
        for path, (shape, dtype, _) in tmpl.items():
            flat[path] = jnp.zeros(shape, dtype)
        cache = unflatten(flat)
        return self._fix_cache_layout(cache)

    def abstract_cache(self, batch: int, max_len: int):
        tmpl = self.cache_template(batch, max_len)
        flat = {}
        for path, (shape, dtype, spec) in tmpl.items():
            if self.mesh is None:
                flat[path] = jax.ShapeDtypeStruct(shape, dtype)
            else:
                flat[path] = jax.ShapeDtypeStruct(
                    shape, dtype, sharding=NamedSharding(self.mesh, spec))
        return self._fix_cache_layout(unflatten(flat))

    def _fix_cache_layout(self, cache):
        """encdec stores cross k/v under names matching decode-block access."""
        cfg = self.cfg
        if cfg.family == "encdec":
            lay = cache["layers"]
            cache["layers"] = {"self": lay["self"],
                               "cross_k": lay["cross"]["k"],
                               "cross_v": lay["cross"]["v"]}
        return cache

    def cache_specs(self, batch: int, max_len: int):
        tmpl = self.cache_template(batch, max_len)
        flat = {path: spec for path, (_, _, spec) in tmpl.items()}
        cache = unflatten(flat)
        cfg = self.cfg
        if cfg.family == "encdec":
            lay = cache["layers"]
            cache["layers"] = {"self": lay["self"],
                               "cross_k": lay["cross"]["k"],
                               "cross_v": lay["cross"]["v"]}
        return cache
