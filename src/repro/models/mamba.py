"""Mamba-2 (SSD) block: projections + causal depthwise conv + SSD scan +
gated RMSNorm + output projection. Used standalone (mamba2-370m) and as the
SSM branch of the Hymba hybrid block.

Layouts: separate projections per stream (z, x, B, C, dt) so TP sharding is
clean (no uneven slices of one fused projection).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels.ssd import ops as ssd_ops
from .common import ShardCtx, rms_norm


def _causal_conv(x, w, state=None):
    """Depthwise causal conv. x: (B,S,C), w: (K,C). state: (B,K-1,C) carry
    for decode. Returns (y, new_state)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)           # (B,S+K-1,C)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
            for i in range(k))
    new_state = xp[:, -(k - 1):, :] if k > 1 else None
    return y, new_state


def _project_streams(h, p, cfg, ctx: ShardCtx):
    dp = ctx.dp or None
    di = cfg.d_inner
    g, n, nh = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_heads
    z = h @ p["in_z"]                                 # (B,S,di)
    xs = h @ p["in_x"]
    if ctx.mesh is not None and nh % ctx.tp == 0:
        z = ctx.cs(z, dp, None, "model")
        xs = ctx.cs(xs, dp, None, "model")
    bs = h @ p["in_B"]                                # (B,S,G*N)
    cs = h @ p["in_C"]
    dt = h @ p["in_dt"] + p["dt_bias"]                # (B,S,H)
    dt = jax.nn.softplus(dt.astype(jnp.float32))
    return z, xs, bs, cs, dt


def _to_heads(xs, bs, cs, cfg):
    b, s, _ = xs.shape
    nh, hp = cfg.ssm_heads, cfg.ssm_headdim
    g, n = cfg.ssm_ngroups, cfg.ssm_state
    x = xs.reshape(b, s, nh, hp)
    bm = bs.reshape(b, s, g, n)
    cm = cs.reshape(b, s, g, n)
    rep = nh // g
    bm = jnp.repeat(bm, rep, axis=2)                  # (B,S,H,N)
    cm = jnp.repeat(cm, rep, axis=2)
    return x, bm, cm


def mamba_forward(h, p, cfg, ctx: ShardCtx):
    """Training/prefill path over a full sequence. h: (B,S,d)."""
    z, xs, bs, cs, dt = _project_streams(h, p, cfg, ctx)
    xs, _ = _causal_conv(xs, p["conv_x"])
    bs, _ = _causal_conv(bs, p["conv_B"])
    cs, _ = _causal_conv(cs, p["conv_C"])
    xs, bs, cs = (jax.nn.silu(t.astype(jnp.float32)).astype(h.dtype)
                  for t in (xs, bs, cs))
    x, bm, cm = _to_heads(xs, bs, cs, cfg)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, _ = ssd_ops.ssd(x, dt, A, bm, cm, chunk=cfg.ssm_chunk)
    y = y + x * p["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(h.shape[0], h.shape[1], cfg.d_inner)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["gate_norm"], cfg.norm_eps)
    return y @ p["out_proj"]


def mamba_prefill(h, p, cfg, ctx: ShardCtx):
    """Like forward but also returns the recurrent cache for decode."""
    z, xs, bs, cs, dt = _project_streams(h, p, cfg, ctx)
    xs, conv_x_state = _causal_conv(xs, p["conv_x"])
    bs, conv_b_state = _causal_conv(bs, p["conv_B"])
    cs, conv_c_state = _causal_conv(cs, p["conv_C"])
    xs, bs, cs = (jax.nn.silu(t.astype(jnp.float32)).astype(h.dtype)
                  for t in (xs, bs, cs))
    x, bm, cm = _to_heads(xs, bs, cs, cfg)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, state = ssd_ops.ssd(x, dt, A, bm, cm, chunk=cfg.ssm_chunk)
    y = y + x * p["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(h.shape[0], h.shape[1], cfg.d_inner)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["gate_norm"], cfg.norm_eps)
    cache = {"ssm": state,                                 # (B,H,N,P) fp32
             "conv_x": conv_x_state, "conv_B": conv_b_state,
             "conv_C": conv_c_state}
    return y @ p["out_proj"], cache


def mamba_decode(h, p, cfg, ctx: ShardCtx, cache):
    """One-token step. h: (B,1,d). cache: {'ssm','conv_x','conv_B','conv_C'}."""
    z, xs, bs, cs, dt = _project_streams(h, p, cfg, ctx)
    xs, cx = _causal_conv(xs, p["conv_x"], cache["conv_x"])
    bs, cb = _causal_conv(bs, p["conv_B"], cache["conv_B"])
    cs, cc = _causal_conv(cs, p["conv_C"], cache["conv_C"])
    xs, bs, cs = (jax.nn.silu(t.astype(jnp.float32)).astype(h.dtype)
                  for t in (xs, bs, cs))
    x, bm, cm = _to_heads(xs, bs, cs, cfg)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, state = ssd_ops.ssd_decode_step(
        cache["ssm"], x[:, 0], dt[:, 0], A, bm[:, 0], cm[:, 0])
    y = y[:, None] + x * p["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(h.shape[0], 1, cfg.d_inner)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["gate_norm"], cfg.norm_eps)
    new_cache = {"ssm": state, "conv_x": cx, "conv_B": cb, "conv_C": cc}
    return y @ p["out_proj"], new_cache


def mamba_cache_shape(cfg, batch: int) -> dict:
    """Per-layer cache shapes (fp32 state, bf16 conv carries)."""
    k = cfg.ssm_conv
    g, n = cfg.ssm_ngroups, cfg.ssm_state
    return {
        "ssm": ((batch, cfg.ssm_heads, n, cfg.ssm_headdim), jnp.float32),
        "conv_x": ((batch, k - 1, cfg.d_inner), cfg.dtype),
        "conv_B": ((batch, k - 1, g * n), cfg.dtype),
        "conv_C": ((batch, k - 1, g * n), cfg.dtype),
    }
