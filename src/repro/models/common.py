"""Shared model infrastructure: configs, parameter templates (shape + init +
sharding spec in one place), norms, rope, losses, sharding helpers.

Conventions
-----------
* Params are nested dicts of jnp arrays; per-layer params are STACKED with a
  leading ``num_layers`` axis and consumed by ``lax.scan`` (compile time and
  HLO size O(1) in depth).
* Every parameter is declared once as a ``ParamDef`` carrying its shape,
  dtype, initializer and ``PartitionSpec`` — ``init_params`` materializes
  real arrays (smoke tests / examples), ``abstract_params`` materializes
  ``jax.ShapeDtypeStruct`` with ``NamedSharding`` (the multi-pod dry-run
  never allocates).
* Mesh axes: ``model`` = TP/EP/SP; ``data`` (+ ``pod`` when present) = DP.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# ---------------------------------------------------------------------------
# Configs
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int
    ffn: str = "swiglu"            # swiglu | sq_relu | gelu
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    # attention activation-sharding strategy: 'heads' needs n%tp==0,
    # 'sequence' is context parallelism (used when head counts don't divide)
    attn_shard: str = "heads"
    sliding_window: int = 0        # 0 = full attention
    full_attn_layers: tuple[int, ...] = ()   # hybrid: layers w/ full attn
    meta_tokens: int = 0           # hymba: learnable KV-prefix registers
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    # MLA (deepseek)
    kv_lora: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # SSM (mamba2 / hymba heads)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_ngroups: int = 1
    ssm_chunk: int = 256
    ssm_conv: int = 4
    # encoder-decoder (whisper)
    enc_layers: int = 0
    enc_seq: int = 0               # stub frontend sequence length
    # vlm (llava)
    img_tokens: int = 0
    img_embed_dim: int = 0
    # numerics
    dtype: Any = jnp.bfloat16
    # int8 KV cache for decode (per-token-per-head absmax scales) — halves
    # the HBM traffic that dominates the decode roofline (KIVI-style)
    kv_quant: bool = False
    # MoE dispatch layout: per-data-shard capacity chunks (all-to-all) vs
    # one global capacity buffer (all-reduce). See EXPERIMENTS §Perf cell E.
    moe_chunk_dispatch: bool = False
    # long-context capability (gates the long_500k shape)
    subquadratic: bool = False

    @property
    def d_inner(self) -> int:      # SSD inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def param_count(self) -> int:
        """Total parameters N (embeddings included)."""
        return int(sum(np.prod(d.shape) for d in
                       param_template(self).values()))

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        total = 0
        for name, d in param_template(self).items():
            n = int(np.prod(d.shape))
            if ".experts." in name:
                n = n * (self.top_k / self.n_experts)
            total += int(n)
        return total


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str                       # train | prefill | decode
    seq_len: int
    global_batch: int
    num_microbatches: int = 1       # train only


# ---------------------------------------------------------------------------
# Parameter templates
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    spec: P                          # how the LIVE param is sharded
    init: str = "normal"             # normal | zeros | ones | scaled
    dtype: Any = jnp.bfloat16
    fan_in: int | None = None        # for 'scaled' init


def _norm(spec_extra: int = 0) -> P:
    return P()                       # norms replicated


def dense_spec(in_shard: str | None, out_shard: str | None, *lead) -> P:
    return P(*lead, in_shard, out_shard)


def param_template(cfg: ArchConfig) -> dict[str, ParamDef]:
    """Flat dict 'path/like/this' -> ParamDef. Stacked layer params carry a
    leading num_layers axis. Built per family."""
    from . import families            # local import to avoid cycles
    return families.template(cfg)


# -- materialization ---------------------------------------------------------
def _init_leaf(key, d: ParamDef):
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    if d.init == "ssm_a":            # mamba A_log in [0, ~ln16]
        u = jax.random.uniform(key, d.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(d.dtype)
    if d.init == "ssm_dt":           # dt_bias ~ softplus^-1(U(1e-3, 0.1))
        u = jax.random.uniform(key, d.shape, jnp.float32, 1e-3, 0.1)
        return jnp.log(jnp.expm1(u)).astype(d.dtype)
    fan_in = d.fan_in or (d.shape[-2] if len(d.shape) >= 2 else d.shape[-1])
    std = 1.0 / math.sqrt(max(1, fan_in))
    return (jax.random.normal(key, d.shape, jnp.float32) * std).astype(d.dtype)


def unflatten(flat: dict[str, Any]) -> dict:
    out: dict = {}
    for path, v in flat.items():
        node = out
        parts = path.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return out


def init_params(cfg: ArchConfig, rng) -> dict:
    tmpl = param_template(cfg)
    keys = jax.random.split(rng, len(tmpl))
    return unflatten({path: _init_leaf(k, d)
                      for k, (path, d) in zip(keys, sorted(tmpl.items()))})


def fsdp_spec(shape: tuple[int, ...], axis_size: int,
              axis: str = "model") -> P:
    """ZeRO-3 layout: shard the largest divisible dim over ``axis``.
    Stacked layer params skip the leading L axis (scan slices it)."""
    best, best_dim = -1, 0
    for i, dim in enumerate(shape):
        if i == 0 and len(shape) > 1:
            continue                      # leading stack axis stays whole
        if dim % axis_size == 0 and dim > best_dim:
            best, best_dim = i, dim
    parts = [None] * len(shape)
    if best >= 0:
        parts[best] = axis
    return P(*parts)


def resolved_spec(d: ParamDef, mesh: Mesh | None,
                  parallelism: str = "tp") -> P:
    if parallelism == "fsdp" and mesh is not None:
        return fsdp_spec(d.shape, mesh.shape["model"])
    return d.spec


def abstract_params(cfg: ArchConfig, mesh: Mesh | None,
                    parallelism: str = "tp") -> dict:
    tmpl = param_template(cfg)
    def mk(d: ParamDef):
        if mesh is None:
            return jax.ShapeDtypeStruct(d.shape, d.dtype)
        return jax.ShapeDtypeStruct(
            d.shape, d.dtype,
            sharding=NamedSharding(mesh, resolved_spec(d, mesh, parallelism)))
    return unflatten({path: mk(d) for path, d in tmpl.items()})


def param_spec_tree(cfg: ArchConfig, mesh: Mesh | None = None,
                    parallelism: str = "tp") -> dict:
    return unflatten({path: resolved_spec(d, mesh, parallelism)
                      for path, d in param_template(cfg).items()})


# ---------------------------------------------------------------------------
# Sharding helpers
# ---------------------------------------------------------------------------
def dp_axes(mesh: Mesh | None) -> tuple[str, ...]:
    if mesh is None:
        return ()
    return (("pod", "data") if "pod" in mesh.axis_names else ("data",))


class ShardCtx:
    """Carries the mesh through model code; no-ops when mesh is None so the
    same model runs unsharded on one CPU device (smoke tests).

    parallelism:
      'tp'   — Megatron tensor parallelism on the 'model' axis (baseline)
      'fsdp' — the 'model' axis joins data parallelism for activations;
               params are ZeRO-3 sharded over it and all-gathered per layer
               by GSPMD. No TP activation constraints apply.
    """

    def __init__(self, mesh: Mesh | None, cfg: ArchConfig,
                 parallelism: str = "tp") -> None:
        assert parallelism in ("tp", "fsdp")
        self.mesh = mesh
        self.cfg = cfg
        self.parallelism = parallelism
        self.dp = dp_axes(mesh)
        if parallelism == "fsdp" and mesh is not None:
            self.dp = self.dp + ("model",)
        tp = 1 if (mesh is None or parallelism == "fsdp") \
            else mesh.shape["model"]
        self.tp = tp
        # resolved attention activation sharding:
        #  head_sharded    — q-head axis over 'model' (KV repeated to q heads
        #                    when n_kv doesn't divide tp)
        #  kv_head_sharded — the KV cache head axis itself is shardable
        self.head_sharded = (cfg.attn_shard == "heads" and mesh is not None
                             and cfg.n_heads % tp == 0)
        self.kv_head_sharded = (self.head_sharded
                                and cfg.n_kv_heads % tp == 0)

    def cs(self, x, *spec):
        if self.mesh is None:
            return x
        if self.parallelism == "fsdp":
            # drop TP feature-dim constraints; only batch stays pinned
            spec = tuple(self.dp if s == self.dp else None for s in spec)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*spec)))

    # common layouts
    def act(self, x):                     # (B, S, d) — residual stream:
        # batch over DP, d replicated across 'model' (Megatron residual)
        return self.cs(x, self.dp or None, None, None) if self.mesh else x

    def layer_param(self, x):
        """FSDP: pin a sliced per-layer param to its shard layout inside the
        scan body, so the weight all-gather happens per-iteration in VMEM-
        sized pieces instead of XLA hoisting a whole-stack gather out of the
        loop (measured: full f32 params resident without this)."""
        if self.parallelism != "fsdp" or self.mesh is None or x.ndim == 0:
            return x
        size = self.mesh.shape["model"]
        best, best_dim = -1, 0
        for i, dim in enumerate(x.shape):
            if dim % size == 0 and dim > best_dim:
                best, best_dim = i, dim
        if best < 0:
            return x
        parts = [None] * x.ndim
        parts[best] = "model"
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*parts)))

    def batch_seq(self, x):               # (B, S) tokens
        return self.cs(x, self.dp or None, None) if self.mesh else x


# ---------------------------------------------------------------------------
# Numerics
# ---------------------------------------------------------------------------
def rms_norm(x, scale, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def rope_freqs(d_head: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: (..., S, H, dh) or (..., S, dh); positions: (..., S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, dh/2)
    if x.ndim == angles.ndim + 1:                       # head axis present
        angles = angles[..., None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def cross_entropy_loss(logits, labels, mask=None):
    """logits: (B, S, V) any float dtype; labels: (B, S) int32.
    Computed in fp32; supports vocab-sharded logits (GSPMD reduces)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def causal_mask(s_q: int, s_kv: int, q_offset=0):
    """True where attention is allowed."""
    qi = jnp.arange(s_q)[:, None] + q_offset
    kj = jnp.arange(s_kv)[None, :]
    return qi >= kj


def swa_mask(s_q: int, s_kv: int, window: int, q_offset=0):
    qi = jnp.arange(s_q)[:, None] + q_offset
    kj = jnp.arange(s_kv)[None, :]
    return (qi >= kj) & (qi - kj < window)
