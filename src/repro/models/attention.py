"""Attention: GQA/MHA with three execution modes and two sharding strategies.

Modes
-----
train    — full masked scores (one layer's scores materialize only inside the
           per-layer remat window; memory-safe at 4k, exact flops).
prefill  — blockwise streaming softmax over KV blocks (lax.scan): O(S·blk)
           memory at 32k prompts. No grad needed on this path.
decode   — q_len=1 against the KV cache with a position mask.

Sharding strategies (resolved in ShardCtx):
heads    — head axis over 'model' (requires divisibility)
sequence — q-sequence over 'model' (context parallelism; K/V gathered by
           GSPMD). Used for llava (56H/8KV), whisper (20H), hymba (25H/5KV)
           on the 16-way model axis.

All einsums run in the model dtype (bf16); softmax statistics in fp32.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .common import ShardCtx

NEG_INF = -1e30


def _q_spec(ctx: ShardCtx):
    dp = ctx.dp or None
    if ctx.head_sharded:
        return (dp, None, "model", None, None)     # (B,S,Hkv,G,dh)
    return (dp, "model", None, None, None)          # sequence sharding


def _kv_spec(ctx: ShardCtx, seq_shard: bool = False):
    dp = ctx.dp or None
    if ctx.head_sharded:
        return (dp, None, "model", None)
    if seq_shard:
        return (dp, "model", None, None)
    return (dp, None, None, None)


def _group(q, n_kv):
    b, s, h, d = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, d)


def _expand_kv(q, k, v, ctx: ShardCtx):
    """When q-heads are TP-sharded but the KV head count doesn't divide TP,
    repeat KV up to the q-head count so the shared head axis shards evenly
    (duplicated KV is tiny next to activations; flops unchanged)."""
    n_kv = k.shape[2]
    if ctx.head_sharded and not ctx.kv_head_sharded and n_kv != q.shape[2]:
        rep = q.shape[2] // n_kv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    return k, v


def attention_train(q, k, v, mask, ctx: ShardCtx, softcap: float = 0.0):
    """q: (B,Sq,Hq,dh), k/v: (B,Skv,Hkv,dh), mask: (Sq,Skv) or (B,Sq,Skv)."""
    k, v = _expand_kv(q, k, v, ctx)
    n_kv = k.shape[2]
    qg = _group(q, n_kv)                             # (B,Sq,Hkv,G,dh)
    qg = ctx.cs(qg, *_q_spec(ctx))
    k = ctx.cs(k, *_kv_spec(ctx))
    v = ctx.cs(v, *_kv_spec(ctx))
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k) * scale
    s = s.astype(jnp.float32)
    if mask.ndim == 2:
        mask = mask[None]
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v)
    b, sq, hq, _ = q.shape
    return o.reshape(b, sq, hq, v.shape[-1])


def attention_prefill(q, k, v, ctx: ShardCtx, *, window: int = 0,
                      block: int = 512, prefix: int = 0):
    """Blockwise causal (optionally sliding-window) attention; memory is
    O(Sq·block) instead of O(Sq·Skv). Flops identical to the full product.
    ``prefix`` marks leading KV positions (meta tokens) visible to every
    query regardless of causality/window.
    """
    b, sq, hq, dh = q.shape
    k, v = _expand_kv(q, k, v, ctx)
    n_kv = k.shape[2]
    skv = k.shape[1]
    blk = block if skv % block == 0 else skv
    nb = skv // blk
    qg = ctx.cs(_group(q, n_kv), *_q_spec(ctx))
    scale = dh ** -0.5
    kb = k.reshape(b, nb, blk, n_kv, k.shape[-1]).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nb, blk, n_kv, v.shape[-1]).transpose(1, 0, 2, 3, 4)

    q_idx = jnp.arange(sq)[:, None]                  # (Sq,1)

    def step(carry, xs):
        m, l, acc = carry
        jblk, kj, vj = xs
        s = (jnp.einsum("bqhgd,bkhd->bhgqk", qg, kj) * scale).astype(jnp.float32)
        k_idx = jblk * blk + jnp.arange(blk)[None, :] - prefix
        ok = q_idx >= k_idx
        if window:
            ok &= (q_idx - k_idx) < window
        if prefix:
            ok |= k_idx < 0                          # meta tokens always visible
        s = jnp.where(ok[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(q.dtype), vj)
        acc = acc * corr[..., None] + pv.astype(jnp.float32)
        return (m_new, l, acc), None

    g = hq // n_kv
    dv = v.shape[-1]
    m0 = jnp.full((b, n_kv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, n_kv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, n_kv, g, sq, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  (jnp.arange(nb), kb, vb))
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    return o.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, dv).astype(q.dtype)


def attention_decode(q, k_cache, v_cache, pos, ctx: ShardCtx, *,
                     window: int = 0, ring: bool = False, valid=None,
                     bspec=None, seq_spec=None):
    """q: (B,1,Hq,dh); caches: (B,Smax,Hkv,dh); pos: scalar index of the new
    token. With ``ring`` the cache is a rotating window buffer (entry j is
    valid once written; masking handles the warm-up phase). An explicit
    ``valid`` (broadcastable to (Smax,)) overrides the built-in masking.

    When the cache is SEQUENCE-sharded (``seq_spec``), q is constrained to
    replicated heads and the score matrix to the cache's seq sharding —
    flash-decode over shards: each chip attends over its KV slice, and only
    the (B,H,1,dh) partial outputs + softmax statistics cross the network.
    Without this, GSPMD resolves the q-heads/KV-seq sharding conflict by
    ALL-GATHERING THE WHOLE CACHE per layer (measured: 1 GiB f32 × L on
    qwen3 decode_32k)."""
    b, _, hq, dh = q.shape
    if seq_spec is None:
        # head-sharded layout may need KV repeated up to a shardable count
        k_cache, v_cache = _expand_kv(q, k_cache, v_cache, ctx)
    # seq-sharded (flash-decode) layout: grouped einsum handles GQA natively,
    # repeating KV here would multiply HBM reads by Hq/Hkv for nothing
    n_kv = k_cache.shape[2]
    smax = k_cache.shape[1]
    qg = _group(q, n_kv)
    if seq_spec is not None and ctx.mesh is not None:
        qg = ctx.cs(qg, bspec, None, None, None, None)
    scale = dh ** -0.5
    s = (jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_cache) * scale).astype(jnp.float32)
    if seq_spec is not None and ctx.mesh is not None:
        s = ctx.cs(s, bspec, None, None, None, seq_spec)
    if valid is None:
        j = jnp.arange(smax)
        if ring:
            valid = j < jnp.minimum(pos + 1, smax)    # warm-up mask
        else:
            valid = j <= pos
            if window:
                valid &= (pos - j) < window
    valid = valid[None, None, None, None, :]
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v_cache)
    return o.reshape(b, 1, hq, v_cache.shape[-1])


def update_kv_cache(k_cache, v_cache, k_new, v_new, pos, *, ring_window: int = 0):
    """Insert new K/V rows at ``pos`` (or pos % window for ring buffers)."""
    if ring_window:
        idx = pos % ring_window
    else:
        idx = pos
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new.astype(k_cache.dtype), idx, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new.astype(v_cache.dtype), idx, axis=1)
    return k_cache, v_cache
