from .common import (ArchConfig, ShapeConfig, ShardCtx, abstract_params,
                     init_params, param_spec_tree, param_template)
from .lm import Model, PAD_ID

__all__ = ["ArchConfig", "Model", "PAD_ID", "ShapeConfig", "ShardCtx",
           "abstract_params", "init_params", "param_spec_tree",
           "param_template"]
