from .adamw import (OptConfig, adamw_init, adamw_update, clip_by_global_norm,
                    global_norm, opt_state_specs, path_tree_of, warmup_cosine,
                    zero_spec)

__all__ = ["OptConfig", "adamw_init", "adamw_update", "clip_by_global_norm",
           "global_norm", "opt_state_specs", "path_tree_of", "warmup_cosine",
           "zero_spec"]
