"""AdamW with fp32 master weights and m/v moments, built for ZeRO-1 sharding:
optimizer state lives in its own pytree whose sharding adds the 'data' axis
on the largest divisible dimension of each tensor (see ``zero_spec``).

Params stay bf16; the update path is fp32 end-to-end
(grad -> m/v -> master -> cast-down), so repeated restarts are bit-stable.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def warmup_cosine(cfg: OptConfig, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.peak_lr * warm * cos


def adamw_init(params):
    f32 = lambda t: jax.tree.map(lambda x: x.astype(jnp.float32), t)
    zeros = lambda t: jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), t)
    return {"m": zeros(params), "v": zeros(params), "master": f32(params),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def _decay_mask(path: str) -> float:
    """No weight decay on norms/scalars (standard)."""
    last = path.split("/")[-1]
    if "norm" in last or last in ("A_log", "D", "dt_bias", "beta_attn",
                                  "beta_ssm"):
        return 0.0
    return 1.0


def adamw_update(grads, state, params, step, cfg: OptConfig,
                 path_tree=None):
    """Returns (new_params (model dtype), new_state). grads may be any float
    dtype (bf16 accumulators upcast here)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    lr = warmup_cosine(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    count = state["count"] + 1
    c1 = 1 - b1 ** count.astype(jnp.float32)
    c2 = 1 - b2 ** count.astype(jnp.float32)

    def upd(g, m, v, master, wd_scale):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / c1
        vhat = v / c2
        step_vec = mhat / (jnp.sqrt(vhat) + cfg.eps)
        master = master - lr * (step_vec + cfg.weight_decay * wd_scale * master)
        return m, v, master

    if path_tree is None:
        wd = jax.tree.map(lambda _: 1.0, params)
    else:
        wd = jax.tree.map(_decay_mask, path_tree)
    out = jax.tree.map(upd, grads, state["m"], state["v"], state["master"], wd)
    m = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    v = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    master = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    new_params = jax.tree.map(lambda mstr, p: mstr.astype(p.dtype),
                              master, params)
    new_state = {"m": m, "v": v, "master": master, "count": count}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


def path_tree_of(params) -> dict:
    """Mirror pytree whose leaves are their own 'a/b/c' paths."""
    def walk(node, prefix):
        if isinstance(node, dict):
            return {k: walk(v, f"{prefix}/{k}" if prefix else k)
                    for k, v in node.items()}
        return prefix
    return walk(params, "")


def zero_spec(shape: tuple[int, ...], spec: P, data_size: int,
              min_dim: int = 128) -> P:
    """ZeRO-1: add 'data' to the largest unsharded, divisible axis."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    best, best_dim = -1, 0
    for i, (dim, sh) in enumerate(zip(shape, parts)):
        if sh is None and dim % data_size == 0 and dim >= max(min_dim, data_size):
            if dim > best_dim:
                best, best_dim = i, dim
    if best >= 0:
        parts[best] = "data"
    return P(*parts)


def opt_state_specs(param_defs: dict, data_size: int):
    """param_defs: flat path -> ParamDef. Returns flat path -> P for one
    fp32 state tensor (same for m, v, master)."""
    return {path: zero_spec(d.shape, d.spec, data_size)
            for path, d in param_defs.items()}
