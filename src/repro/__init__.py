"""StreamFlow — a scalable and robust data-stream ingestion fabric for
multi-pod JAX training and serving.

Reproduction (adapted to TPU clusters) of: Isah & Zulkernine, "A Scalable and
Robust Framework for Data Stream Ingestion", 2018.

Subpackages:
  core        the paper's dataflow-management framework (ingestion fabric)
  data        tokenizer / packing / streaming loader (log -> sharded jax.Array)
  models      the 10 assigned architectures (JAX, scan-over-layers)
  kernels     Pallas TPU kernels (flash attn, decode attn, SSD, rmsnorm)
  optim       AdamW + schedules (ZeRO-sharded states)
  checkpoint  async sharded checkpointing w/ stream offsets
  runtime     Trainer / Server loops, fault tolerance, elasticity
  configs     per-arch configs + shape suites
  launch      production mesh, multi-pod dry-run, train/serve CLIs
"""

__version__ = "1.0.0"
