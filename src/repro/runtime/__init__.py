from .serve_loop import ServeConfig, Server, make_decode_fn, make_prefill_fn
from .train_loop import (SimulatedFailure, Trainer, TrainerConfig,
                         make_train_step, opt_spec_tree, shard_batch)

__all__ = ["ServeConfig", "Server", "SimulatedFailure", "Trainer",
           "TrainerConfig", "make_decode_fn", "make_prefill_fn",
           "make_train_step", "opt_spec_tree", "shard_batch"]
