"""Training runtime: jit'd train step (grad-accumulation scan, ZeRO'd AdamW)
plus a fault-tolerant ``Trainer`` that wires the ingestion fabric to the
device mesh: stream → loader → sharded batch → step, with checkpoints that
embed the loader's exactly-once state, failure injection, and auto-resume.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..checkpoint import CheckpointManager, to_device
from ..data.loader import StreamingDataLoader
from ..models import Model, param_spec_tree
from ..models.common import dp_axes, unflatten, param_template
from ..optim import (OptConfig, adamw_init, adamw_update, opt_state_specs,
                     path_tree_of)


# ---------------------------------------------------------------------------
def make_train_step(model: Model, opt_cfg: OptConfig, *,
                    num_microbatches: int = 1,
                    accum_dtype=jnp.float32,
                    donate: bool = True,
                    grad_reduce_scatter: bool = True):
    """Builds step(params, opt_state, batch, step_idx) -> (params, opt_state,
    metrics). Batch leaves have leading global_batch; with microbatching the
    loss/grads are averaged over a lax.scan of microbatches (activation
    memory = one microbatch).

    grad_reduce_scatter (ZeRO-2): constrain gradients to the optimizer-state
    sharding before the update, so GSPMD emits reduce-scatter instead of
    all-reduce for the cross-DP gradient reduction (≈2× less traffic)."""

    grad_specs = None
    if grad_reduce_scatter and model.mesh is not None:
        ospecs = opt_spec_tree(model, model.mesh)
        grad_specs = ospecs["m"]

    def constrain_grads(grads):
        if grad_specs is None:
            return grads
        return jax.tree.map(
            lambda g, sp: jax.lax.with_sharding_constraint(
                g, NamedSharding(model.mesh, sp)), grads, grad_specs)

    def loss_of(params, batch):
        loss, metrics = model.loss_fn(params, batch)
        return loss, metrics

    def split_mb(batch):
        def rs(x):
            gb = x.shape[0]
            assert gb % num_microbatches == 0, (gb, num_microbatches)
            return x.reshape(num_microbatches, gb // num_microbatches,
                             *x.shape[1:])
        return jax.tree.map(rs, batch)

    def step(params, opt_state, batch, step_idx):
        if num_microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params, batch)
            grads = constrain_grads(grads)
        else:
            mb = split_mb(batch)
            acc0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params)
            if grad_specs is not None:
                # ZeRO-2 accumulation: the carry itself is RS-sharded, so
                # each microbatch contributes a reduce-scatter, never a full
                # all-reduce, and the buffer is 1/dp the size
                acc0 = constrain_grads(acc0)

            def body(acc, microbatch):
                (l, m), g = jax.value_and_grad(loss_of, has_aux=True)(
                    params, microbatch)
                g = constrain_grads(g)
                acc = jax.tree.map(lambda a, gg: a + gg.astype(accum_dtype),
                                   acc, g)
                return acc, (l, m)

            acc, (losses, metricses) = jax.lax.scan(body, acc0, mb)
            grads = jax.tree.map(lambda a: a / num_microbatches, acc)
            loss = jnp.mean(losses)
            metrics = jax.tree.map(jnp.mean, metricses)

        paths = path_tree_of(params)
        new_params, new_opt, opt_metrics = adamw_update(
            grads, opt_state, params, step_idx, opt_cfg, path_tree=paths)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    donate_argnums = (0, 1) if donate else ()
    return jax.jit(step, donate_argnums=donate_argnums)


def shard_batch(batch: dict, mesh: Mesh | None):
    if mesh is None:
        return jax.tree.map(jnp.asarray, batch)
    dp = dp_axes(mesh)
    def put(x):
        spec = P(dp, *([None] * (x.ndim - 1)))
        return jax.device_put(x, NamedSharding(mesh, spec))
    return jax.tree.map(put, batch)


def opt_spec_tree(model: Model, mesh: Mesh | None):
    """Sharding spec pytree matching adamw state (ZeRO over 'data')."""
    if mesh is None:
        return None
    from ..models.common import resolved_spec
    from ..optim import zero_spec
    defs = param_template(model.cfg)
    zspecs = unflatten({
        path: zero_spec(d.shape,
                        resolved_spec(d, mesh, model.parallelism),
                        mesh.shape["data"])
        for path, d in defs.items()})
    return {"m": zspecs, "v": zspecs, "master": zspecs, "count": P()}


# ---------------------------------------------------------------------------
class SimulatedFailure(RuntimeError):
    """Injected node failure (tests/benchmarks)."""


@dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 25
    ckpt_dir: str = "checkpoints"
    keep_ckpts: int = 3
    log_every: int = 10
    num_microbatches: int = 1
    seed: int = 0
    fail_at_step: int = -1          # failure injection (exercises recovery)


class Trainer:
    """End-to-end driver: owns model, optimizer state, loader, checkpoints.

    Restart contract: ``Trainer.resume()`` (or constructing over an existing
    ckpt_dir) restores params, optimizer, RNG and the loader's stream
    positions — continuing the run produces the SAME batches and, with
    deterministic kernels, the same loss trajectory as an uninterrupted run.
    """

    def __init__(self, model: Model, loader: StreamingDataLoader,
                 opt_cfg: OptConfig, tcfg: TrainerConfig,
                 mesh: Mesh | None = None) -> None:
        self.model = model
        self.loader = loader
        self.opt_cfg = opt_cfg
        self.tcfg = tcfg
        self.mesh = mesh
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep_ckpts)
        self.step_idx = 0
        self.params = None
        self.opt_state = None
        self._step_fn = make_train_step(
            model, opt_cfg, num_microbatches=tcfg.num_microbatches)
        self.history: list[dict] = []

    # -- lifecycle ------------------------------------------------------------
    def init_state(self) -> None:
        rng = jax.random.PRNGKey(self.tcfg.seed)
        self.params = self.model.init(rng)
        self.opt_state = adamw_init(self.params)

    def resume(self) -> bool:
        """Restore newest intact checkpoint; returns True if resumed."""
        if self.ckpt.latest_step() is None:
            return False
        step, trees, meta = self.ckpt.restore()
        pspecs = (param_spec_tree(self.model.cfg, self.mesh,
                                  self.model.parallelism)
                  if self.mesh else None)
        ospecs = opt_spec_tree(self.model, self.mesh)
        self.params = to_device(trees["params"], pspecs, self.mesh)
        self.opt_state = to_device(trees["opt"], ospecs, self.mesh)
        # counts arrive as np scalars
        self.opt_state["count"] = jnp.asarray(self.opt_state["count"],
                                              jnp.int32)
        self.loader.restore(meta["loader"])
        self.step_idx = step
        return True

    def save(self) -> None:
        self.ckpt.save(self.step_idx,
                       {"params": self.params, "opt": self.opt_state},
                       meta={"loader": self.loader.state(),
                             "step": self.step_idx})

    # -- main loop --------------------------------------------------------------
    def run(self, steps: int | None = None) -> dict:
        steps = steps if steps is not None else self.tcfg.steps
        if self.params is None and not self.resume():
            self.init_state()
        t0 = time.monotonic()
        trained = 0
        while trained < steps:
            if self.step_idx == self.tcfg.fail_at_step:
                raise SimulatedFailure(f"injected at step {self.step_idx}")
            batch_np = self.loader.next_batch()
            if batch_np is None:
                break                                   # stream exhausted
            batch = shard_batch({"tokens": batch_np}, self.mesh)
            self.params, self.opt_state, metrics = self._step_fn(
                self.params, self.opt_state, batch, self.step_idx)
            self.step_idx += 1
            trained += 1
            if self.step_idx % self.tcfg.log_every == 0 or trained == steps:
                row = {k: float(v) for k, v in metrics.items()}
                row["step"] = self.step_idx
                row["starved_polls"] = self.loader.starved_polls
                self.history.append(row)
            if self.tcfg.ckpt_every and self.step_idx % self.tcfg.ckpt_every == 0:
                self.save()
        self.ckpt.wait()
        dt = time.monotonic() - t0
        return {"steps": trained, "wall_sec": dt,
                "final_loss": self.history[-1]["loss"] if self.history else None}
