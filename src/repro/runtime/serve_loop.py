"""Serving runtime: batched prefill+decode driven by the ingestion fabric.

Requests arrive as FlowFiles on a 'requests' topic (any producer — REST
bridge, another pipeline); the server consumes them as a consumer group
member, forms fixed-size batches, runs prefill + greedy decode, and
publishes completions to a 'completions' topic. Adding more servers =
adding group members (the paper's elastic-consumer property applied to
inference).
"""
from __future__ import annotations

import json
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..core import LogStore
from ..core.delivery import Consumer
from ..core.flowfile import FlowFile
from ..data.tokenizer import ByteTokenizer
from ..models import Model


@dataclass
class ServeConfig:
    batch_size: int = 4
    prompt_len: int = 64          # fixed prefill window (pad/truncate)
    max_new_tokens: int = 32
    eos_id: int = ByteTokenizer.EOS


def make_decode_fn(model: Model):
    return jax.jit(model.decode_step)


def make_prefill_fn(model: Model, max_len: int):
    def fn(params, batch):
        return model.prefill(params, batch, max_len=max_len)
    return jax.jit(fn)


class Server:
    def __init__(self, model: Model, params, consumer: Consumer,
                 out_log: LogStore, scfg: ServeConfig) -> None:
        self.model = model
        self.params = params
        self.consumer = consumer
        self.out_log = out_log
        self.scfg = scfg
        self.tok = ByteTokenizer()
        max_len = scfg.prompt_len + scfg.max_new_tokens
        self._prefill = make_prefill_fn(model, max_len)
        self._decode = make_decode_fn(model)
        self.served = 0

    def _batch_prompts(self, ffs) -> tuple[np.ndarray, list[str]]:
        s = self.scfg
        toks = np.full((len(ffs), s.prompt_len), self.tok.PAD, np.int32)
        ids = []
        for i, ff in enumerate(ffs):
            req = json.loads(ff.value) if hasattr(ff, "value") else ff.json()
            ids.append(str(req.get("id", i)))
            enc = self.tok.encode(req.get("prompt", ""), add_eos=False)
            enc = enc[-s.prompt_len:]
            toks[i, :len(enc)] = enc       # left-aligned, right-padded
        return toks, ids

    def serve_once(self) -> int:
        """Poll one batch of requests, decode, publish. Returns #served."""
        s = self.scfg
        recs = self.consumer.poll(max_records=s.batch_size)
        if not recs:
            return 0
        while len(recs) < s.batch_size:   # pad batch with a copy (masked out)
            recs.append(recs[0])
        toks, req_ids = self._batch_prompts(recs)
        batch = {"tokens": jnp.asarray(toks)}
        logits, cache = self._prefill(self.params, batch)
        out_tokens = np.zeros((toks.shape[0], s.max_new_tokens), np.int32)
        cur = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        for t in range(s.max_new_tokens):
            out_tokens[:, t] = np.asarray(cur)[:, 0]
            logits, cache = self._decode(self.params, cache, cur)
            cur = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        n = 0
        for i, rid in enumerate(req_ids[:len(set(req_ids))]):
            text = self.tok.decode(out_tokens[i].tolist())
            payload = json.dumps({"id": rid, "completion_ids":
                                  out_tokens[i].tolist(), "text": text})
            self.out_log.append("completions", rid.encode(), payload.encode())
            n += 1
        self.consumer.commit()            # at-least-once for serving
        self.served += n
        return n
