"""Watermark-driven event-time windows (paper §II/III: the AlertMix-style
consumer of the fabric's event-time clock).

PR 4 built the clock — per-connector :class:`~repro.core.watermark
.WatermarkTracker`\\ s aggregated by :class:`~repro.core.watermark
.LowWatermarkClock` — but nothing consumed it. :class:`WindowedAggregate`
is the first consumer: a processor that buckets records into tumbling
event-time windows and **closes a window only when the fabric-wide low
watermark passes its end** — the point after which no on-time record for
that window can still arrive from *any* active connector. Closes therefore
fire off ``LowWatermarkClock`` advancement, not wall time and not record
arrival: the flow engine's idle triggers (``Processor.idle_trigger_sec``)
re-trigger the processor while its own input is quiet, so windows close as
soon as the *other* connectors' progress advances the clock.

Records that arrive for an already-closed window are emitted on the
``late`` relationship (wire it to the late landing topic the acquisition
layer already maintains) instead of silently reopening or corrupting the
aggregate — same policy the runtime applies per-connector, now enforced at
the aggregation stage.

One subtlety: the clock is read *live* (trackers advance at admission
time, and a finished connector leaves the aggregate immediately), so it
can outrun records still in flight between admission and this stage —
closing on the raw clock would mark whole queues late, worst of all the
drained-but-undelivered tail of a connector that just finished. Closes
are therefore additionally gated on **per-source stage frontiers**: the
newest event time this stage has seen from each source (records carry
their connector's name in the ``source`` attribute; interior queues are
FIFO, so a source's frontier trails its in-flight suffix by at most the
admission disorder bound). A source stops gating once the clock marks it
finished *and* its frontier has reached its final watermark — i.e. its
tail has drained through this stage. Sources the stage has not seen yet
cannot gate by observation alone (the gate would fail open for a small
feed that finishes before any of its records traverse to this stage), so
``sources=(...)`` declares the connectors expected to feed the stage:
a declared-but-unseen source holds every close until its first record
arrives — while connectors that never route here (a separate event sink's
feed) are simply left undeclared and only bound the clock while active.
Declared names must be the clock's connector names (which the news
pipeline also stamps as each record's ``source`` attribute); declaring a
name the clock doesn't know raises at the first close attempt instead of
silently wedging closes forever, and with a declaration in place ONLY the
declared sources gate — records arriving under an unexpected source name
route late (visible) rather than pinning the frontier (invisible).
The gate only ever *delays* a close, so the invariant stands: a close's
``window.close.wm`` is at or behind the fabric-wide low watermark.

Crash safety composes with the WAL: ``buffers_across_triggers`` defers
durable-connection acks to the final flush, so a crash replays every record
still buffered in open windows (at-least-once — a window that already
closed may be re-emitted after a crash; one that never closed cannot be
lost).
"""
from __future__ import annotations

from typing import Callable, Iterable

from .acquisition import default_event_ts
from .flowfile import FlowFile
from .processor import Processor, REL_SUCCESS
from .watermark import LowWatermarkClock

__all__ = ["WindowedAggregate"]

#: attributes stamped on every closed-window FlowFile
ATTR_WINDOW_START = "window.start"
ATTR_WINDOW_END = "window.end"
ATTR_WINDOW_COUNT = "window.count"
#: the low watermark that authorized the close — or ``"final"`` when the
#: window was flushed at end-of-stream (every source finished; the clock
#: can no longer advance past it)
ATTR_WINDOW_CLOSE_WM = "window.close.wm"


class WindowedAggregate(Processor):
    """Tumbling event-time windows closed by the low watermark.

    Each record is bucketed by ``event_ts_fn`` (default: the ``event.ts``
    attribute stamped by the acquisition layer) into
    ``[k*window_sec, (k+1)*window_sec)``. On every trigger — including the
    flow engine's idle triggers while the input is quiet — the processor
    reads ``clock.current()`` and emits one merged FlowFile per window
    whose end is at or behind it, stamped with
    ``window.start/end/count/close.wm``. The merged content is the
    records' contents joined by ``separator`` in event-time order
    (``aggregate_fn`` overrides to produce any summary payload).

    The invariant the acceptance scenario checks: a window close carries
    ``window.close.wm`` ≥ ``window.end`` — closes fire *only at or behind*
    the low watermark (or at final flush, once every stream finished).
    """

    relationships = (REL_SUCCESS, "late")
    buffers_across_triggers = True     # durable inputs defer acks (see base)

    def __init__(self, name: str, clock: LowWatermarkClock,
                 window_sec: float, *,
                 sources: "tuple[str, ...] | None" = None,
                 event_ts_fn: Callable[[FlowFile], float] = default_event_ts,
                 aggregate_fn: Callable[[list[tuple[float, FlowFile]]],
                                        bytes] | None = None,
                 separator: bytes = b"\n",
                 idle_trigger_sec: float = 0.02) -> None:
        super().__init__(name)
        if window_sec <= 0:
            raise ValueError("window_sec must be positive")
        self.clock = clock
        self.window_sec = float(window_sec)
        #: connectors expected to feed this stage (``source`` attribute
        #: values): declared-but-unseen sources hold closes — see module
        #: docstring. None = gate only on sources already observed.
        self.expected_sources = sources
        self.event_ts_fn = event_ts_fn
        self.aggregate_fn = aggregate_fn
        self.separator = separator
        #: re-trigger cadence while the input is idle, so closes fire off
        #: clock advancement driven by other parts of the fabric
        self.idle_trigger_sec = idle_trigger_sec
        #: open windows: start -> [(event_ts, record), ...]
        self._open: dict[float, list[tuple[float, FlowFile]]] = {}
        #: strictly increasing close frontier: every window with
        #: ``end <= _closed_through`` has been closed (or was never opened
        #: and is late by definition)
        self._closed_through = float("-inf")
        #: newest event time that reached THIS stage, per source — the
        #: close gate's second input (see module docstring)
        self._stage_frontiers: dict[str, float] = {}
        self.windows_closed = 0
        self.late_records = 0

    # -- bucketing -----------------------------------------------------------
    def _window_start(self, ts: float) -> float:
        return (ts // self.window_sec) * self.window_sec

    def _bundle(self, start: float, wm: float | str) -> FlowFile:
        entries = self._open.pop(start)
        entries.sort(key=lambda e: e[0])        # event-time order
        if self.aggregate_fn is not None:
            content = self.aggregate_fn(entries)
        else:
            content = self.separator.join(ff.content for _, ff in entries)
        first = entries[0][1]
        self.windows_closed += 1
        return first.derive(content=content, attributes={
            ATTR_WINDOW_START: f"{start:.6f}",
            ATTR_WINDOW_END: f"{start + self.window_sec:.6f}",
            ATTR_WINDOW_COUNT: str(len(entries)),
            ATTR_WINDOW_CLOSE_WM: (wm if isinstance(wm, str)
                                   else f"{wm:.6f}"),
        })

    # -- trigger path --------------------------------------------------------
    def on_trigger(self, batch: list[FlowFile]
                   ) -> Iterable[tuple[str, FlowFile]]:
        frontiers = self._stage_frontiers
        for ff in batch:
            ts = self.event_ts_fn(ff)
            src = ff.attributes.get("source", "")
            if ts > frontiers.get(src, float("-inf")):
                frontiers[src] = ts
            start = self._window_start(ts)
            if start + self.window_sec <= self._closed_through:
                # its window already closed: a straggler, never merged
                self.late_records += 1
                yield "late", ff.with_attributes(**{
                    "window.late": "1",
                    ATTR_WINDOW_START: f"{start:.6f}"})
                continue
            self._open.setdefault(start, []).append((ts, ff))
        frontier = self._close_frontier()
        if frontier is None or frontier <= self._closed_through:
            return
        # the frontier advanced: close every window it passed, oldest first
        for start in sorted(self._open):
            if start + self.window_sec <= frontier:
                yield REL_SUCCESS, self._bundle(start, frontier)
        # advance the frontier even past empty windows: a record for any
        # window it passed is late from now on, buffered or not
        self._closed_through = frontier

    def _close_frontier(self) -> float | None:
        """``min(low watermark, stage frontier of every source still
        gating)`` — see the module docstring. A source releases its gate
        once the clock marks it finished AND the stage has seen its final
        watermark (the in-flight tail drained, up to the disorder bound);
        a declared-but-unseen source gates at ``-inf`` (its whole stream
        is still in flight)."""
        snap = self.clock.snapshot()
        wm = snap["low_watermark"]
        if wm is None or not self._stage_frontiers:
            return None
        finished = snap["finished"]
        per_source = snap["per_source"]
        if self.expected_sources is not None:
            unknown = [s for s in self.expected_sources
                       if s not in per_source]
            if unknown:
                # a typo'd declaration would gate at -inf forever — a
                # silent wedge; fail loudly at the first close attempt
                raise ValueError(
                    f"{self.name}: declared sources {unknown} are not "
                    f"clock-registered connectors {sorted(per_source)}")
            gates = {s: self._stage_frontiers.get(s, float("-inf"))
                     for s in self.expected_sources}
        else:
            gates = dict(self._stage_frontiers)
        frontier = wm
        for src, seen in gates.items():
            if src in finished:
                final_wm = per_source.get(src)
                # released once its tail drained — or immediately when it
                # finished without ever producing a watermark (an empty
                # stream has no tail to wait for; holding it would gate
                # every close at -inf forever)
                if final_wm is None or seen >= final_wm:
                    continue
            frontier = min(frontier, seen)
        return frontier

    def final_flush(self) -> Iterable[tuple[str, FlowFile]]:
        """End of stream: every source finished, so the clock can never
        advance past the remaining windows — flush them, marked final."""
        for start in sorted(self._open):
            yield REL_SUCCESS, self._bundle(start, "final")

    # -- observability --------------------------------------------------------
    def snapshot_windows(self) -> dict:
        return {"open_windows": len(self._open),
                "buffered_records": sum(len(v) for v in self._open.values()),
                "closed_through": self._closed_through,
                "stage_frontiers": dict(self._stage_frontiers),
                "windows_closed": self.windows_closed,
                "late_records": self.late_records}
