"""LogStore — the storage contract of the distribution layer (paper §III.C).

Every component that moves records through the durable log — the batching
``delivery.Producer``, consumer groups, WAL-backed ``DurableConnection``,
``PublishToLog``/``DeadLetterQueue``, and the streaming training loader —
programs against this interface, not against a concrete store. Two
implementations ship today:

  * :class:`~repro.core.log.PartitionedLog` — the single-host segment store
    (the seed implementation; still the hot-path default), and
  * :class:`~repro.core.replicated.ReplicatedLog` — N coordinated replica
    sets per partition with a deterministic leader, follower segment
    shipping, configurable durability (``acks``), and epoch-fenced failover.

The contract (all methods thread-safe):

  * topics are created explicitly with a fixed partition count;
  * ``append``/``append_batch`` assign dense consecutive offsets per
    partition and are at-least-once from the producer's view;
  * ``read`` returns committed records ``[offset, offset+n)`` of one
    partition in offset order — readers may trail arbitrarily and replay;
  * ``begin_offset``/``end_offset`` bound the retained range (retention and
    WAL GC may advance ``begin_offset``);
  * ``flush``/``flush_topic`` make appended records durable
    (``fsync=True`` upgrades process-crash to machine-crash durability);
  * ``enforce_retention``/``drop_segments_below`` discard old whole
    segments, never the active tail.
"""
from __future__ import annotations

import abc
import os
import threading
from dataclasses import dataclass
from pathlib import Path
from typing import Iterator, Sequence


def atomic_write_bytes(path: Path, data: bytes, *, fsync: bool = True) -> None:
    """Crash-safe whole-file replace: write a tmp file, fsync its fd, rename
    over ``path``, then fsync the parent directory. The plain
    ``write + os.replace`` idiom is only atomic against a *process* crash —
    after a machine crash the rename target can be torn (the rename may be
    journaled before the tmp file's data blocks), which loses the previous
    contents too. Every durable metadata file (committed offsets,
    replication metadata) goes through here."""
    tmp = path.with_name(path.name + ".tmp")
    fd = os.open(tmp, os.O_WRONLY | os.O_CREAT | os.O_TRUNC, 0o644)
    try:
        view = memoryview(data)
        while view:                # os.write may land short (signals, large
            view = view[os.write(fd, view):]   # buffers) — never fsync+
        if fsync:                  # rename a truncated payload over the
            os.fsync(fd)           # previous good file
    finally:
        os.close(fd)
    os.replace(tmp, path)
    if fsync:
        try:
            dfd = os.open(path.parent, os.O_RDONLY)
        except OSError:            # platforms without O_RDONLY dir opens
            return
        try:
            os.fsync(dfd)
        finally:
            os.close(dfd)


@dataclass(frozen=True, slots=True)
class DedupEntry:
    """Last accepted batch of one ``(topic, partition, producer_id)``."""

    base_seq: int
    count: int
    first_offset: int


class ProducerDedupTable:
    """Idempotent-producer sequence table (Kafka's idempotent producer,
    reduced to the last-batch window that matters here).

    A producer stamps each per-partition batch with ``(producer_id,
    base_seq)`` where ``base_seq`` counts records, not batches; the store
    records the last accepted batch per ``(topic, partition, producer_id)``.
    :meth:`classify` then tells an append attempt apart:

      * ``"new"``   — first batch, the next batch (``base_seq`` == previous
        ``base_seq + count``), or a forward gap (the table guards against
        duplication, not loss — a producer that skipped sequences is its own
        problem);
      * ``"retry"`` — exactly the last batch again (same ``base_seq`` and
        ``count``): the producer resent after an ambiguous failure (socket
        reconnect, fenced leader re-append) and the store must not append it
        twice;
      * anything else raises ``ValueError`` (an overlapping or rewinding
        batch is a protocol violation, not a retry).

    The contract is **single writer per producer_id** (enforced by callers:
    ``delivery.Producer`` drains under its lock). The table is in-memory
    only — across a store process restart the window is lost and delivery
    degrades to the documented at-least-once (persisting producer state in
    the log itself is Kafka's full protocol, out of scope)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._entries: dict[tuple[str, int, str], DedupEntry] = {}

    def classify(self, topic: str, partition: int, producer_id: str,
                 base_seq: int, count: int
                 ) -> tuple[str, DedupEntry | None]:
        if base_seq < 0 or count < 1:
            raise ValueError("base_seq must be >= 0 and count >= 1")
        with self._lock:
            entry = self._entries.get((topic, partition, producer_id))
        if entry is None or base_seq >= entry.base_seq + entry.count:
            return "new", entry
        if base_seq == entry.base_seq and count == entry.count:
            return "retry", entry
        raise ValueError(
            f"out-of-sequence batch from producer {producer_id!r} on "
            f"{topic}/{partition}: got base_seq={base_seq} count={count}, "
            f"last accepted base_seq={entry.base_seq} count={entry.count}")

    def record(self, topic: str, partition: int, producer_id: str,
               base_seq: int, count: int, first_offset: int) -> None:
        with self._lock:
            self._entries[(topic, partition, producer_id)] = DedupEntry(
                base_seq, count, first_offset)


@dataclass(frozen=True, slots=True)
class LogRecord:
    """One committed record, as handed to consumers."""

    topic: str
    partition: int
    offset: int
    key: bytes
    value: bytes

    @property
    def size(self) -> int:
        return len(self.key) + len(self.value)


class LogStore(abc.ABC):
    """Abstract durable partitioned pub-sub log.

    Concrete stores expose ``root`` (a directory that namespaces the store's
    on-disk state — consumer-group offset stores default to living inside
    it).
    """

    root: Path

    # -- topic admin ----------------------------------------------------------
    @abc.abstractmethod
    def create_topic(self, topic: str, partitions: int = 1) -> None:
        """Idempotent; raises if the topic exists with a different count."""

    @abc.abstractmethod
    def topics(self) -> list[str]: ...

    @abc.abstractmethod
    def num_partitions(self, topic: str) -> int: ...

    # -- producer --------------------------------------------------------------
    @abc.abstractmethod
    def append(self, topic: str, key: bytes, value: bytes,
               partition: int | None = None) -> tuple[int, int]:
        """Append one record; returns ``(partition, offset)``. With
        ``partition=None`` the record is routed by key hash."""

    @abc.abstractmethod
    def append_batch(self, topic: str,
                     records: Sequence[tuple[bytes, bytes]],
                     partition: int | None = None, *,
                     producer_id: str | None = None,
                     base_seq: int | None = None
                     ) -> list[tuple[int, int]]:
        """Append many records (the high-throughput entry point); returns
        ``(partition, offset)`` per record in input order.

        ``producer_id``/``base_seq`` stamp the batch for idempotent-producer
        dedup (see :class:`ProducerDedupTable`): a retried batch returns the
        originally assigned offsets instead of appending twice. Requires an
        explicit ``partition`` (the producer resolves routing so sequence
        numbers are per-partition)."""

    @abc.abstractmethod
    def flush(self, fsync: bool = True) -> None: ...

    @abc.abstractmethod
    def flush_topic(self, topic: str, fsync: bool = True) -> None: ...

    # -- consumer --------------------------------------------------------------
    @abc.abstractmethod
    def read(self, topic: str, partition: int, offset: int,
             max_records: int = 512) -> list[LogRecord]: ...

    @abc.abstractmethod
    def begin_offset(self, topic: str, partition: int) -> int: ...

    @abc.abstractmethod
    def end_offset(self, topic: str, partition: int) -> int: ...

    # -- retention -------------------------------------------------------------
    @abc.abstractmethod
    def enforce_retention(self, topic: str, retention_bytes: int) -> int: ...

    @abc.abstractmethod
    def drop_segments_below(self, topic: str, partition: int,
                            offset: int) -> int: ...

    @abc.abstractmethod
    def close(self) -> None: ...

    # -- derived helpers (shared by every implementation) ----------------------
    def end_offsets(self, topic: str) -> list[int]:
        return [self.end_offset(topic, p)
                for p in range(self.num_partitions(topic))]

    def iter_records(self, topic: str, partition: int | None = None,
                     batch_records: int = 512) -> Iterator[LogRecord]:
        """Scan every retained record of a topic (one partition, or all in
        partition order), yielding ``LogRecord``s from each partition's
        ``begin_offset`` to its end. The canonical full-scan loop — tests,
        benches, and DLQ replay share it instead of hand-rolling offsets."""
        parts = (range(self.num_partitions(topic))
                 if partition is None else (partition,))
        for p in parts:
            off = self.begin_offset(topic, p)
            while True:
                recs = self.read(topic, p, off, max_records=batch_records)
                if not recs:
                    break
                yield from recs
                off = recs[-1].offset + 1
