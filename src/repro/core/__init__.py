"""repro.core — the paper's contribution: a scalable, robust dataflow
management framework for data-stream ingestion (Isah & Zulkernine, 2018),
re-implemented as a JAX-cluster-native library.

Layers (paper Fig. 1):
  acquisition   — Source processors over replayable generators (sources.py),
                  or live: SourceConnector poll loops with reconnect backoff,
                  checkpointed cursors and event-time watermarks
                  (acquisition.py + watermark.py); wire-real connectors —
                  HTTP/RSS cursor-feed long-poller + RFC 6455 WebSocket
                  client — in net_connectors.py
  extract/enrich/integrate — processors.py (dedup, filter, route, enrich,
                  merge) + watermark-driven event-time windows (windows.py)
  distribution  — LogStore (pluggable durable pub-sub: single-host
                  PartitionedLog or N-replica ReplicatedLog) + ConsumerGroup
cross-cutting: Connection backpressure, ProvenanceRepository lineage, and
telemetry — per-stage latency histograms, sampled record traces, and a
metrics registry with Prometheus-style export (metrics.py + telemetry.py).

Failure-handling model (paper: "robustness in handling failures")
-----------------------------------------------------------------
Three opt-in layers, all defaulting to the seed's fail-fast behaviour:

1. **Supervision** — ``graph.add(proc, restart_policy=RestartPolicy(
   max_restarts=5))`` restarts a crashed processor with exponential backoff
   (``backoff_base_sec * backoff_factor**k``, capped). The in-flight batch is
   re-queued before the restart and a source restart fast-forwards its
   replayable generator, so supervision is at-least-once: duplicates are
   possible, loss is not. Once the budget is spent the node turns ``FAILED``
   (visible in ``graph.status()``) and ``join()`` raises ``FlowError``.

2. **Retry + dead-letter routing** — ``graph.connect(..., max_retries=3)``
   arms record-level retry on a connection: a failing batch is re-triggered
   record-at-a-time to isolate the poison record, which is penalized
   (``retry_penalty_sec * 2**k``) and re-queued with a ``retry.count``
   attribute. After ``max_retries`` the record goes to the graph's
   quarantine — ``dlq = graph.add(DeadLetterQueue("dlq", log, "dead-letters"));
   graph.route_dead_letters_to(dlq)`` — which persists it to a log topic
   keyed by provenance lineage id; ``DeadLetterQueue.replay(log)`` yields the
   quarantined FlowFiles for re-ingestion once the poison is fixed.

3. **WAL-backed connections** — ``graph.connect(..., durable=log)`` journals
   every accepted FlowFile through the durable log and the consumer's acked
   frontier through a ``<topic>.__acks__`` topic. Rebuilding the same graph
   over the same log replays the un-acked suffix into the queue: a hard
   process crash resumes from the last acked frontier, at-least-once.

Deterministic fault injection (faults.py) drives the tests and
``benchmarks/bench_recovery.py``::

    from repro.core.faults import INJECTOR, raise_every_records
    INJECTOR.arm("proc.enrich", raise_every_records(500), every=1)  # crash ~every 500 records
    INJECTOR.arm("log.segment.append_batch", "crash", nth=3)        # hard-kill mid-write
    ...
    INJECTOR.reset()

Sites built into the runtime: ``proc.<name>`` (every trigger, ctx carries the
batch), ``log.segment.append_batch`` (before each chunk ``write``),
``delivery.producer.drain``, ``delivery.consumer.poll``, the replication
sites ``replica.leader`` / ``replica.ship`` (before each leader-store append
/ follower range-ship — arm them to exercise deterministic failover), and
the acquisition sites ``acquire.connect`` / ``acquire.poll`` (before each
connector session open / poll — arm them to flap live sources and exercise
reconnect, redelivery, and checkpointed resume).
Actions: ``"raise"`` / ``"delay"`` / ``"crash"`` (``os._exit``) or any
callable, on an ``nth``/``every`` call schedule.

Storage (the distribution layer) is pluggable: every component above
programs against the :class:`LogStore` interface (logstore.py).
``PartitionedLog`` is the single-host implementation; ``ReplicatedLog``
(replicated.py) adds N-replica partitions with a deterministic leader,
follower segment shipping, ``acks="leader"|"all"`` durability levels, and
epoch-fenced failover.
"""
from .acquisition import (AcquisitionError, AcquisitionRuntime,
                          ConnectorError, ConnectorPolicy, EndOfStream,
                          SimulatedEndpoint, SourceConnector,
                          default_event_ts, emission_order)
from .connection import (BackpressureTimeout, Connection, DurableConnection,
                         RateThrottle,
                         DEFAULT_OBJECT_THRESHOLD, DEFAULT_SIZE_THRESHOLD)
from .delivery import (Consumer, ConsumerGroup, OffsetStore, Producer,
                       StaleGeneration, range_assign)
from .fabric import FabricError, IngestionFabric, LeaseTable
from .faults import FaultInjector, InjectedFault, INJECTOR
from .flow import FlowError, FlowGraph
from .flowfile import FlowFile, make_flowfile
from .log import CorruptRecord, PartitionedLog, route_partition
from .logstore import LogRecord, LogStore
from .processor import (Processor, RestartPolicy, Source, REL_DROP,
                        REL_FAILURE, REL_SUCCESS)
from .replicated import ReplicatedLog, ReplicationError, StaleEpoch
from .processors import (BloomFilter, CollectSink, ContentFilter,
                         DeadLetterQueue, DetectDuplicate, ExecuteScript,
                         FileSink, LookupEnrich, MergeContent,
                         PartitionRecords, PublishToLog, RouteOnAttribute,
                         Throttle)
from .net_connectors import HttpPollConnector, WebSocketConnector
from .provenance import ProvenanceEvent, ProvenanceRepository
from .sources import (FirehoseSource, RssAggregatorSource, WebSocketSource,
                      corpus_documents, synth_article)
from .telemetry import (FlightRecorder, LatencyHistogram, MetricsRegistry,
                        ScrapeServer, serve_scrape)
from .transport import (FencedError, FenceTable, FrameTooLarge,
                        LogServer, RemoteLogStore, TransportError)
from .watermark import LowWatermarkClock, WatermarkTracker
from .windows import WindowedAggregate

__all__ = [
    "AcquisitionError", "AcquisitionRuntime",
    "BackpressureTimeout", "BloomFilter", "CollectSink", "Connection",
    "ConnectorError", "ConnectorPolicy",
    "ConsumerGroup", "Consumer", "ContentFilter", "CorruptRecord",
    "DEFAULT_OBJECT_THRESHOLD", "DEFAULT_SIZE_THRESHOLD", "DeadLetterQueue",
    "DetectDuplicate", "DurableConnection", "EndOfStream",
    "ExecuteScript", "FabricError", "FaultInjector", "FenceTable",
    "FencedError", "FileSink", "FirehoseSource", "FlightRecorder",
    "FrameTooLarge", "FlowError", "FlowFile",
    "FlowGraph", "HttpPollConnector", "INJECTOR", "IngestionFabric",
    "InjectedFault", "LatencyHistogram", "LeaseTable", "LogRecord",
    "LogServer", "LogStore",
    "LookupEnrich", "LowWatermarkClock",
    "MergeContent", "MetricsRegistry", "OffsetStore",
    "PartitionRecords", "PartitionedLog", "Processor", "Producer",
    "ProvenanceEvent",
    "ProvenanceRepository", "PublishToLog", "RateThrottle", "REL_DROP",
    "REL_FAILURE", "REL_SUCCESS", "ReplicatedLog", "ReplicationError",
    "RestartPolicy", "RouteOnAttribute",
    "RssAggregatorSource", "ScrapeServer", "SimulatedEndpoint", "Source",
    "SourceConnector",
    "RemoteLogStore", "StaleEpoch", "StaleGeneration", "Throttle",
    "TransportError", "WatermarkTracker",
    "WebSocketConnector", "WebSocketSource", "WindowedAggregate",
    "corpus_documents", "default_event_ts", "emission_order",
    "make_flowfile", "range_assign", "route_partition", "serve_scrape",
    "synth_article",
]
