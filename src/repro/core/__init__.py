"""repro.core — the paper's contribution: a scalable, robust dataflow
management framework for data-stream ingestion (Isah & Zulkernine, 2018),
re-implemented as a JAX-cluster-native library.

Layers (paper Fig. 1):
  acquisition   — Source processors over replayable generators (sources.py)
  extract/enrich/integrate — processors.py (dedup, filter, route, enrich, merge)
  distribution  — PartitionedLog (durable pub-sub) + ConsumerGroup (delivery.py)
cross-cutting: Connection backpressure, ProvenanceRepository lineage, metrics.
"""
from .connection import (BackpressureTimeout, Connection, RateThrottle,
                         DEFAULT_OBJECT_THRESHOLD, DEFAULT_SIZE_THRESHOLD)
from .delivery import (Consumer, ConsumerGroup, OffsetStore, Producer,
                       StaleGeneration, range_assign)
from .flow import FlowError, FlowGraph
from .flowfile import FlowFile, make_flowfile
from .log import CorruptRecord, LogRecord, PartitionedLog
from .processor import Processor, Source, REL_DROP, REL_FAILURE, REL_SUCCESS
from .processors import (BloomFilter, CollectSink, ContentFilter,
                         DetectDuplicate, ExecuteScript, FileSink,
                         LookupEnrich, MergeContent, PartitionRecords,
                         PublishToLog, RouteOnAttribute, Throttle)
from .provenance import ProvenanceEvent, ProvenanceRepository
from .sources import (FirehoseSource, RssAggregatorSource, WebSocketSource,
                      corpus_documents, synth_article)

__all__ = [
    "BackpressureTimeout", "BloomFilter", "CollectSink", "Connection",
    "ConsumerGroup", "Consumer", "ContentFilter", "CorruptRecord",
    "DEFAULT_OBJECT_THRESHOLD", "DEFAULT_SIZE_THRESHOLD", "DetectDuplicate",
    "ExecuteScript", "FileSink", "FirehoseSource", "FlowError", "FlowFile",
    "FlowGraph", "LogRecord", "LookupEnrich", "MergeContent", "OffsetStore",
    "PartitionRecords", "PartitionedLog", "Processor", "Producer",
    "ProvenanceEvent",
    "ProvenanceRepository", "PublishToLog", "RateThrottle", "REL_DROP",
    "REL_FAILURE", "REL_SUCCESS", "RouteOnAttribute", "RssAggregatorSource",
    "Source", "StaleGeneration", "Throttle", "WebSocketSource",
    "corpus_documents", "make_flowfile", "range_assign", "synth_article",
]
