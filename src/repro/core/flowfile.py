"""FlowFile: the unit of data moving through the ingestion fabric.

Mirrors NiFi's FlowFile (paper §III.A): an immutable content payload plus a
mutable attribute map, identified by a UUID, carrying lineage information so
the provenance repository can reconstruct the full path of every record
(paper Fig. 4).
"""
from __future__ import annotations

import itertools
import json
import os
import time
import zlib
from dataclasses import dataclass, field, replace
from typing import Any, Mapping

# FlowFile ids must be unique across the fabric (they key provenance), but
# uuid4() reads os.urandom per call — ~100µs in sandboxed containers, and the
# hot path mints 2-3 ids per record. A random 64-bit process prefix plus a
# monotonic counter gives the same 32-hex-char shape and uniqueness at ~50ns.
_UUID_PREFIX = os.urandom(8).hex()
_uuid_counter = itertools.count()


def _new_uuid() -> str:
    return f"{_UUID_PREFIX}{next(_uuid_counter):016x}"


@dataclass(frozen=True, slots=True)
class FlowFile:
    """An immutable record in the dataflow.

    Attributes
    ----------
    content:   raw payload bytes (zero-copy passed between processors).
    attributes:string->string metadata (source, timestamps, routing keys...).
    uuid:      unique id of this FlowFile *version* (a transform creates a new
               version with a new uuid, linked by ``parent_uuid``).
    lineage_id:stable id of the logical record across transforms — the id the
               provenance UI groups on.
    parent_uuid: uuid of the FlowFile this one was derived from (None at
               CREATE).
    entry_ts:  wall-clock seconds when the record entered the fabric.
    """

    content: bytes
    attributes: Mapping[str, str] = field(default_factory=dict)
    uuid: str = field(default_factory=_new_uuid)
    lineage_id: str = ""
    parent_uuid: str | None = None
    entry_ts: float = field(default_factory=time.time)

    def __post_init__(self) -> None:
        if not self.lineage_id:
            object.__setattr__(self, "lineage_id", self.uuid)

    # -- size accounting (used by Connection's data-size threshold) ---------
    @property
    def size(self) -> int:
        return len(self.content)

    # -- derivation ----------------------------------------------------------
    def derive(self, *, content: bytes | None = None,
               attributes: Mapping[str, str] | None = None) -> "FlowFile":
        """Create a child version (TRANSFORM provenance edge)."""
        new_attrs = dict(self.attributes)
        if attributes:
            new_attrs.update(attributes)
        return FlowFile(
            content=self.content if content is None else content,
            attributes=new_attrs,
            uuid=_new_uuid(),
            lineage_id=self.lineage_id,
            parent_uuid=self.uuid,
            entry_ts=self.entry_ts,
        )

    def with_attributes(self, **attrs: str) -> "FlowFile":
        return self.derive(attributes={k: str(v) for k, v in attrs.items()})

    # -- content helpers -----------------------------------------------------
    def text(self, encoding: str = "utf-8") -> str:
        return self.content.decode(encoding, errors="replace")

    def json(self) -> Any:
        return json.loads(self.content)

    def content_hash(self) -> int:
        """Cheap stable content fingerprint (crc32) for dedup fast-path."""
        return zlib.crc32(self.content)

    # -- (de)serialization for the durable log ------------------------------
    def to_record(self) -> tuple[bytes, bytes]:
        """(key, value) for PartitionedLog.append. Attributes+ids go in the
        key header; content is the value (kept zero-copy)."""
        header = json.dumps({
            "uuid": self.uuid,
            "lineage_id": self.lineage_id,
            "parent_uuid": self.parent_uuid,
            "entry_ts": self.entry_ts,
            "attributes": dict(self.attributes),
        }, separators=(",", ":")).encode()
        return header, self.content

    @staticmethod
    def from_record(key: bytes, value: bytes) -> "FlowFile":
        meta = json.loads(key)
        return FlowFile(
            content=value,
            attributes=meta.get("attributes", {}),
            uuid=meta.get("uuid", _new_uuid()),
            lineage_id=meta.get("lineage_id", ""),
            parent_uuid=meta.get("parent_uuid"),
            entry_ts=meta.get("entry_ts", 0.0),
        )


def make_flowfile(content: bytes | str, **attributes: str) -> FlowFile:
    if isinstance(content, str):
        content = content.encode()
    return FlowFile(content=content,
                    attributes={k: str(v) for k, v in attributes.items()})
