"""Consumer groups, offsets, and delivery guarantees (paper §II.B, §III.C).

Consumers attach to topics through a ``ConsumerGroup``: partitions are
range-assigned across members and *rebalanced* when members join or leave —
the paper's elasticity requirement ("add and remove consumers at any time
without changing the data ingestion pipeline").

Delivery guarantees:

  * at-least-once — poll → process → ``commit()``; a crash between process
    and commit re-delivers from the last committed offset.
  * exactly-once  — the consumer's position participates in the *consumer's
    own* atomic state commit: ``positions()``/``restore()`` let the training
    checkpoint embed stream offsets, so optimizer state and stream position
    move in lock-step (offsets-in-checkpoint).

Batched hot path
----------------
``Producer`` is the write-side batching front end: a size/time-bounded
accumulator (knobs: ``max_batch_records``, ``max_batch_bytes``,
``linger_sec``) that drains whole batches through the ``LogStore``'s
``append_batch`` — one lock/pack/write per partition per
drain instead of per record. Producers, consumer groups, and the offset
store are store-agnostic: they run unchanged over the single-host
``PartitionedLog`` or the fault-tolerant ``ReplicatedLog``. ``Consumer.poll`` keeps a cached end offset per
partition and skips the log read (and therefore the partition flush)
entirely while the cache says the reader is caught up, so an idle poll loop
costs no I/O.
"""
from __future__ import annotations

import json
import threading
import time
from pathlib import Path
from typing import Callable, Iterable

from . import faults
from .log import route_partition
from .logstore import LogRecord, LogStore, atomic_write_bytes


class Producer:
    """Size/time-bounded batching producer over any ``LogStore``.

    Records accumulate in memory and drain through ``append_batch`` when any
    bound trips: ``max_batch_records`` records, ``max_batch_bytes`` payload
    bytes, or ``linger_sec`` since the oldest buffered record (checked on
    every ``send``; call ``flush()`` at quiesce points — there is no timer
    thread). Thread-safe; record order is preserved per partition.

    ``producer_id`` makes delivery **idempotent**: the producer resolves
    each record's partition itself (the same key-hash rule the stores use),
    numbers records per partition with a dense sequence, and stamps every
    drained batch with ``(producer_id, base_seq)`` so the store dedups
    retried batches — a drain whose failure was ambiguous (socket drop
    after the server applied it; fenced leader re-append) lands exactly
    once. The id must be unique per live producer: two producers sharing
    one id corrupt each other's sequence window."""

    def __init__(self, log: LogStore, topic: str, *,
                 max_batch_records: int = 512,
                 max_batch_bytes: int = 1 << 20,
                 linger_sec: float = 0.05,
                 producer_id: str | None = None,
                 clock: Callable[[], float] | None = None) -> None:
        if max_batch_records <= 0 or max_batch_bytes <= 0:
            raise ValueError("batch bounds must be positive")
        #: monotonic source for the linger bound (injectable)
        self._clock: Callable[[], float] = \
            clock if clock is not None else time.monotonic
        self.log = log
        self.topic = topic
        self.max_batch_records = max_batch_records
        self.max_batch_bytes = max_batch_bytes
        self.linger_sec = linger_sec
        self.producer_id = producer_id
        self._seqs: dict[int, int] = {}     # partition -> next base_seq
        self._nparts: int | None = None     # lazy (topic may not exist yet)
        # runs whose append failed ambiguously, frozen with their reserved
        # sequence range: the retry must resend them byte-identical for the
        # store's dedup to recognize them (new sends must not extend them)
        self._inflight: list[tuple[list[tuple[bytes, bytes]], int, int]] = []
        self._lock = threading.Lock()
        # parallel buffers: records grouped as (key, value), partition per rec
        self._buf: list[tuple[bytes, bytes]] = []
        self._buf_parts: list[int | None] = []
        self._buf_bytes = 0
        self._oldest = 0.0
        self.sent = 0          # records accepted by send()
        self.delivered = 0     # records drained into the log

    def send(self, key: bytes, value: bytes,
             partition: int | None = None) -> None:
        """Buffer one record; drains automatically when a bound trips."""
        self.send_many(((key, value, partition),))

    def send_many(self, items) -> None:
        """Buffer many ``(key, value, partition)`` records with one lock
        acquisition and one bounds check per call — pair with batch-oriented
        callers (e.g. a whole processor trigger)."""
        with self._lock:
            if not self._buf:
                self._oldest = self._clock()
            n = 0
            for key, value, partition in items:
                self._buf.append((key, value))
                self._buf_parts.append(partition)
                self._buf_bytes += len(key) + len(value)
                n += 1
            self.sent += n
            if (len(self._buf) >= self.max_batch_records
                    or self._buf_bytes >= self.max_batch_bytes
                    or self._clock() - self._oldest >= self.linger_sec):
                self._drain_locked()

    def _drain_locked(self) -> None:
        records, parts = self._buf, self._buf_parts
        n = len(records)
        if not n:
            return
        # fault site: crash/raise between accumulation and the log append —
        # the producer's at-least-once retry contract is exercised here
        faults.fire("delivery.producer.drain", records=records)
        # group by partition (first-appearance order) so a drain issues one
        # append per distinct partition, however the partitions interleave —
        # key-routed workloads (crc32 per record) otherwise degenerate to
        # one-record runs and one RPC each. Per-partition record order is
        # preserved; cross-partition order is not a log guarantee.
        # None-partition records are key-routed by append_batch itself
        # (resolved eagerly with the same rule when idempotence needs
        # per-partition sequences). Only records whose append landed leave
        # the buffer, so a failure (disk full, bad partition) keeps the
        # unsent groups for retry — the at-least-once producer contract;
        # with a producer_id the retried run dedups store-side.
        if self.producer_id is not None:
            # resend frozen runs first (identical composition, same
            # base_seq: a run that DID land before its failure surfaced is
            # recognized and acked without a second append)
            while self._inflight:
                recs, p, seq = self._inflight[0]
                self.log.append_batch(self.topic, recs, partition=p,
                                      producer_id=self.producer_id,
                                      base_seq=seq)
                self.delivered += len(recs)
                self._inflight.pop(0)
            if self._nparts is None:
                self._nparts = self.log.num_partitions(self.topic)
            for i, p in enumerate(parts):
                if p is None:
                    parts[i] = route_partition(records[i][0], self._nparts)
        groups: dict[int | None, list[int]] = {}
        order: list[int | None] = []
        for i, p in enumerate(parts):
            g = groups.get(p)
            if g is None:
                groups[p] = g = []
                order.append(p)
            g.append(i)
        landed = bytearray(n)
        try:
            for p in order:
                idxs = groups[p]
                run = [records[i] for i in idxs]
                if self.producer_id is None:
                    self.log.append_batch(self.topic, run, partition=p)
                else:
                    seq = self._seqs.get(p, 0)
                    try:
                        self.log.append_batch(
                            self.topic, run, partition=p,
                            producer_id=self.producer_id, base_seq=seq)
                    except Exception:
                        # ambiguous: the server may have applied it. Freeze
                        # the run with its reserved sequence range; the
                        # buffer moves on so later sends can't extend it
                        self._seqs[p] = seq + len(run)
                        self._inflight.append((run, p, seq))
                        for i in idxs:
                            landed[i] = 1
                        raise
                    self._seqs[p] = seq + len(run)
                self.delivered += len(run)
                for i in idxs:
                    landed[i] = 1
        finally:
            if any(landed):
                self._buf = [records[i] for i in range(n) if not landed[i]]
                self._buf_parts = [parts[i] for i in range(n)
                                   if not landed[i]]
                self._buf_bytes = sum(len(k) + len(v) for k, v in self._buf)

    def flush(self, fsync: bool = False) -> None:
        """Drain the accumulator; optionally fsync the topic's partitions."""
        with self._lock:
            self._drain_locked()
        if fsync:
            self.log.flush_topic(self.topic, fsync=True)

    def pending(self) -> int:
        with self._lock:
            return len(self._buf) + sum(len(r) for r, _, _ in self._inflight)

    def __enter__(self) -> "Producer":
        return self

    def __exit__(self, *exc) -> None:
        self.flush()


class OffsetStore:
    """Durable committed offsets: {group: {topic: {partition: offset}}}.
    Writes are atomic AND machine-crash-safe: tmp + fsync + rename + parent
    dir fsync (see :func:`~repro.core.logstore.atomic_write_bytes` — a bare
    ``write + rename`` can leave a torn rename target after a power loss,
    losing every group's committed offsets at once). ``fsync=False`` keeps
    the atomicity but downgrades to process-crash durability for callers
    that commit on a hot path."""

    def __init__(self, path: str | Path, *, fsync: bool = True) -> None:
        self.path = Path(path)
        self.fsync = fsync
        self._lock = threading.Lock()
        self._data: dict[str, dict[str, dict[str, int]]] = {}
        if self.path.exists():
            try:
                self._data = json.loads(self.path.read_text())
            except (ValueError, OSError):
                # torn write of the tmp rename target is impossible; a torn
                # *initial* file means nothing was ever committed
                self._data = {}

    def get(self, group: str, topic: str, partition: int) -> int:
        with self._lock:
            return int(self._data.get(group, {}).get(topic, {})
                       .get(str(partition), 0))

    def commit(self, group: str, topic: str,
               offsets: dict[int, int]) -> None:
        with self._lock:
            g = self._data.setdefault(group, {}).setdefault(topic, {})
            for p, off in offsets.items():
                g[str(p)] = int(off)
            atomic_write_bytes(self.path, json.dumps(self._data).encode(),
                               fsync=self.fsync)

    def snapshot(self) -> dict:
        with self._lock:
            return json.loads(json.dumps(self._data))


def range_assign(partitions: int, members: list[str]) -> dict[str, list[int]]:
    """Deterministic range assignment (Kafka's range assignor)."""
    members = sorted(members)
    if not members:
        return {}
    per = partitions // len(members)
    extra = partitions % len(members)
    out: dict[str, list[int]] = {}
    start = 0
    for i, m in enumerate(members):
        n = per + (1 if i < extra else 0)
        out[m] = list(range(start, start + n))
        start += n
    return out


class Consumer:
    """A single group member. Not thread-safe across poll/commit (one owner
    thread per consumer, like Kafka's threading contract)."""

    def __init__(self, group: "ConsumerGroup", member_id: str) -> None:
        self._group = group
        self.member_id = member_id
        self.assignment: list[int] = []
        self._positions: dict[int, int] = {}
        # cached per-partition end offsets: while position < cached end there
        # is provably data to read; refreshed only when the cache says the
        # reader caught up (keeps idle polls free of log locks and flushes)
        self._cached_end: dict[int, int] = {}
        self.generation = -1

    # -- group protocol -------------------------------------------------------
    def _on_assign(self, partitions: list[int], generation: int) -> None:
        self.assignment = list(partitions)
        self.generation = generation
        self._cached_end = {}
        store, log = self._group.offsets, self._group.log
        self._positions = {
            p: max(store.get(self._group.group_id, self._group.topic, p),
                   log.begin_offset(self._group.topic, p))
            for p in partitions}

    # -- data path --------------------------------------------------------------
    def poll(self, max_records: int = 256) -> list[LogRecord]:
        """Deterministic in (positions, log state): two sweeps over the
        assigned partitions in order — first a fair per-partition share, then
        fill remaining budget. Determinism makes exactly-once replay after
        ``restore()`` byte-identical (the training loader relies on this)."""
        self._group.check_generation(self)
        # fault site: kill/raise a member between poll and commit to exercise
        # at-least-once redelivery after rebalance
        faults.fire("delivery.consumer.poll", consumer=self)
        out: list[LogRecord] = []
        n = len(self.assignment)
        if n == 0:
            return out
        share = max(1, max_records // n)
        for cap in (share, max_records):
            for p in sorted(self.assignment):
                budget = min(cap, max_records - len(out))
                if budget <= 0:
                    break
                recs = self._read(p, budget)
                if recs:
                    self._positions[p] = recs[-1].offset + 1
                    out.extend(recs)
        return out

    def _read(self, p: int, budget: int) -> list[LogRecord]:
        """Read from one partition, gated by the cached end offset so a
        caught-up partition costs neither a log read nor a flush. The gate is
        exact: the cache is refreshed from the log the moment the position
        reaches it, so the result only depends on (position, log state) and
        replay determinism is preserved."""
        pos = self._positions[p]
        if pos >= self._cached_end.get(p, 0):
            end = self._group.log.end_offset(self._group.topic, p)
            self._cached_end[p] = end
            if pos >= end:
                return []
        return self._group.log.read(self._group.topic, p, pos, budget)

    def commit(self) -> None:
        """At-least-once boundary: persist current positions."""
        self._group.offsets.commit(self._group.group_id, self._group.topic,
                                   dict(self._positions))

    # -- exactly-once hooks (offsets-in-checkpoint) ------------------------------
    def positions(self) -> dict[int, int]:
        return dict(self._positions)

    def restore(self, positions: dict[int, int],
                on_unassigned: str = "raise") -> None:
        """Exactly-once resume: make ``positions`` (captured by
        :meth:`positions` inside the consumer's own atomic state commit) the
        current read positions.

        A checkpoint can name partitions this member no longer owns — a
        rebalance happened between capture and restore. Silently dropping
        them would quietly replay those partitions from the *committed*
        store instead of the checkpoint, losing the loader's position
        without any signal, so:

        * ``on_unassigned="raise"`` (default) — refuse the restore loudly;
          the caller re-captures after the rebalance settles.
        * ``on_unassigned="commit"`` — route the orphaned offsets through
          the group's offset store, so the member that now owns those
          partitions resumes from the checkpoint (at-least-once: that
          member may already have polled past the store read in its own
          ``_on_assign``; it re-syncs on the next rebalance)."""
        if on_unassigned not in ("raise", "commit"):
            raise ValueError(f"unknown on_unassigned={on_unassigned!r}")
        positions = {int(p): int(off) for p, off in positions.items()}
        orphans = {p: off for p, off in positions.items()
                   if p not in self._positions}
        if orphans:
            if on_unassigned == "raise":
                raise ValueError(
                    f"{self.member_id}: restore() positions cover "
                    f"partitions {sorted(orphans)} not in this member's "
                    f"assignment {sorted(self._positions)} (rebalanced?); "
                    "pass on_unassigned='commit' to hand them to the "
                    "offset store instead")
            self._group.offsets.commit(self._group.group_id,
                                       self._group.topic, orphans)
        for p, off in positions.items():
            if p in self._positions:
                self._positions[p] = off

    def seek(self, partition: int, offset: int) -> None:
        self._positions[partition] = offset

    def lag(self) -> int:
        return sum(self._group.log.end_offset(self._group.topic, p)
                   - self._positions.get(p, 0) for p in self.assignment)


class StaleGeneration(Exception):
    """Raised when a consumer polls after a rebalance it hasn't joined."""


class ConsumerGroup:
    """Tracks membership and rebalances partition assignment on change."""

    def __init__(self, log: LogStore, topic: str, group_id: str,
                 offset_store: OffsetStore | None = None) -> None:
        self.log = log
        self.topic = topic
        self.group_id = group_id
        self.offsets = offset_store or OffsetStore(
            Path(log.root) / f".offsets-{group_id}.json")
        self._members: dict[str, Consumer] = {}
        self._generation = 0
        self._lock = threading.Lock()

    def add_member(self, member_id: str) -> Consumer:
        with self._lock:
            if member_id in self._members:
                raise ValueError(f"member {member_id!r} already in group")
            c = Consumer(self, member_id)
            self._members[member_id] = c
            self._rebalance()
            return c

    def remove_member(self, member_id: str) -> None:
        with self._lock:
            self._members.pop(member_id, None)
            self._rebalance()

    def _rebalance(self) -> None:
        self._generation += 1
        assignment = range_assign(self.log.num_partitions(self.topic),
                                  list(self._members))
        for mid, consumer in self._members.items():
            consumer._on_assign(assignment.get(mid, []), self._generation)

    def check_generation(self, consumer: Consumer) -> None:
        if consumer.generation != self._generation:
            raise StaleGeneration(
                f"{consumer.member_id}: generation {consumer.generation} "
                f"!= group {self._generation}")

    def members(self) -> list[str]:
        with self._lock:
            return sorted(self._members)

    def total_lag(self) -> int:
        with self._lock:
            return sum(c.lag() for c in self._members.values())
