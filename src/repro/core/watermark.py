"""Event-time watermarks for the acquisition runtime (paper §II/III: multi-
source acquisition must absorb out-of-order, late-arriving data instead of
silently merging it — the AlertMix observation).

A :class:`WatermarkTracker` follows one connector's event-time stream under a
*bounded out-of-orderness* assumption: after seeing a record with event time
``t``, no record older than ``t - lateness`` is expected. The watermark is
``max_event_ts - lateness`` and is **monotonic** — it never regresses, even
when an at-least-once endpoint redelivers an old suffix after a reconnect.
Records that arrive behind the watermark are *late*; the acquisition runtime
routes them to a dedicated late destination (NiFi would route to a ``late``
relationship) rather than merging them into the on-time stream.

A :class:`LowWatermarkClock` aggregates several trackers into the fabric-wide
event-time clock: the minimum watermark across all *active* connectors. The
aggregate is conservative — it stays unknown (``None``) until every active
connector has reported at least one record, and a finished connector leaves
the minimum (its stream can produce nothing older). Both properties keep the
aggregate monotonic, which is what downstream consumers rely on — the first
one is :class:`~repro.core.windows.WindowedAggregate`, whose window closes
fire off this clock's advancement.

Both classes are thread-safe: each tracker is written by one poll loop but
read by status/aggregation calls on other threads.
"""
from __future__ import annotations

import threading

__all__ = ["WatermarkTracker", "LowWatermarkClock"]


class WatermarkTracker:
    """Monotonic bounded-out-of-orderness watermark for one event-time
    stream. ``observe(ts)`` returns ``True`` when the record is *late*
    (behind the watermark as of before the observation)."""

    def __init__(self, lateness: float = 0.0,
                 initial: float | None = None) -> None:
        if lateness < 0:
            raise ValueError("lateness must be non-negative")
        self.lateness = lateness
        self._lock = threading.Lock()
        self._max_ts: float | None = None
        # seeding (from a checkpoint) keeps the watermark monotonic across a
        # crash/restart: redelivered records are judged against the pre-crash
        # clock instead of resetting it
        self._watermark: float | None = initial
        self.observed = 0
        self.late = 0

    def observe(self, ts: float) -> bool:
        with self._lock:
            self.observed += 1
            late = self._watermark is not None and ts < self._watermark
            if late:
                self.late += 1
            else:
                if self._max_ts is None or ts > self._max_ts:
                    self._max_ts = ts
                    wm = ts - self.lateness
                    if self._watermark is None or wm > self._watermark:
                        self._watermark = wm
            return late

    @property
    def watermark(self) -> float | None:
        with self._lock:
            return self._watermark

    @property
    def max_event_ts(self) -> float | None:
        with self._lock:
            return self._max_ts


class LowWatermarkClock:
    """Fabric-wide event-time clock: the minimum watermark over all active
    (registered, unfinished) trackers. ``None`` until every active tracker
    has a watermark — a conservative unknown, never a regression."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._trackers: dict[str, WatermarkTracker] = {}
        self._finished: set[str] = set()

    def register(self, name: str, lateness: float = 0.0,
                 initial: float | None = None) -> WatermarkTracker:
        with self._lock:
            if name in self._trackers:
                raise ValueError(f"tracker {name!r} already registered")
            t = WatermarkTracker(lateness, initial=initial)
            self._trackers[name] = t
            return t

    def mark_finished(self, name: str) -> None:
        """A finished stream can emit nothing more: it leaves the minimum
        (equivalently, its watermark jumps to +inf)."""
        with self._lock:
            self._finished.add(name)

    def _aggregate_locked(self) -> tuple[float | None, dict[str, float | None]]:
        """One consistent view, built under the clock lock: every tracker's
        watermark is read exactly once and the aggregate is computed from
        those same values. (Reading the tracker list after releasing the
        lock could miss a concurrent ``register()`` mid-aggregation, and
        re-reading live watermarks per field let ``snapshot()`` report a low
        watermark inconsistent with its own ``per_source``.) Lock order is
        clock → tracker; trackers never take the clock lock."""
        per_source = {n: t.watermark for n, t in self._trackers.items()}
        active = [per_source[n] for n in self._trackers
                  if n not in self._finished]
        if not active:
            # every stream finished: the clock is the largest final
            # watermark (nothing older can ever arrive)
            finals = [w for w in per_source.values() if w is not None]
            return (max(finals) if finals else None), per_source
        if any(w is None for w in active):
            return None, per_source
        return min(active), per_source

    def current(self) -> float | None:
        with self._lock:
            return self._aggregate_locked()[0]

    def snapshot(self) -> dict:
        with self._lock:
            low, per_source = self._aggregate_locked()
            return {
                "low_watermark": low,
                "per_source": per_source,
                "finished": sorted(self._finished),
            }
