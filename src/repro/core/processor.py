"""Processor model + threaded flow engine (NiFi analogue, paper §III.A).

A ``Processor`` consumes FlowFiles from its single input connection and emits
FlowFiles onto named *relationships* (e.g. ``unique``/``duplicate`` for
DetectDuplicate). Relationships are wired to downstream connections by the
``FlowGraph``. Sources are processors without an input that pull records from
a (replayable) generator.

Scheduling: each processor runs on its own thread; blocking ``offer`` on a
full downstream connection stalls the thread, which in turn stops it from
draining *its* input — NiFi's transitive backpressure, for free.

Termination: a source finishes when its generator is exhausted; an interior
processor finishes when every upstream is finished and its input is drained.
``FlowGraph.run_to_completion`` joins the whole DAG.
"""
from __future__ import annotations

import threading
import time
import traceback
from typing import Callable, Iterable, Iterator, Mapping

from .connection import Connection
from .flowfile import FlowFile
from .metrics import ComponentStats
from .provenance import ProvenanceRepository

REL_SUCCESS = "success"
REL_FAILURE = "failure"

#: Relationship name whose FlowFiles are dropped (with DROP provenance).
REL_DROP = "__drop__"


class Processor:
    """Base class. Subclasses implement ``process`` (record-at-a-time) or
    override ``on_trigger`` (batch)."""

    #: relationships this processor may emit on (used for wiring validation)
    relationships: tuple[str, ...] = (REL_SUCCESS,)
    #: max records pulled per trigger (batching amortizes queue locks)
    batch_size: int = 256
    #: source batching window, evaluated at each arrival: records yielded
    #: back-to-back within this window batch up; a record that arrives after
    #: a slower pull is delivered immediately. (A burst followed by a total
    #: stall leaves the burst's tail buffered until the next yield or
    #: end-of-stream — bounding that would need a flush timer thread.)
    source_linger_sec: float = 0.05

    def __init__(self, name: str) -> None:
        self.name = name
        self.stats = ComponentStats(name)

    # -- to be implemented by subclasses -------------------------------------
    def process(self, ff: FlowFile) -> Iterable[tuple[str, FlowFile]]:
        raise NotImplementedError

    def on_trigger(self, batch: list[FlowFile]
                   ) -> Iterable[tuple[str, FlowFile]]:
        for ff in batch:
            yield from self.process(ff)

    # -- lifecycle hooks -------------------------------------------------------
    def on_start(self) -> None: ...
    def on_stop(self) -> None:
        """Called at shutdown; may emit nothing. Batch processors flush here
        via ``final_flush``."""

    def final_flush(self) -> Iterable[tuple[str, FlowFile]]:
        return ()


class Source(Processor):
    """A processor with no input; wraps a replayable record generator."""

    def __init__(self, name: str,
                 generator: Callable[[], Iterator[FlowFile]]) -> None:
        super().__init__(name)
        self._generator_fn = generator

    def records(self) -> Iterator[FlowFile]:
        return self._generator_fn()

    def process(self, ff: FlowFile) -> Iterable[tuple[str, FlowFile]]:
        yield REL_SUCCESS, ff


class _Worker(threading.Thread):
    def __init__(self, node: "FlowNode", graph: "FlowGraph") -> None:
        super().__init__(name=f"flow-{node.processor.name}", daemon=True)
        self.node = node
        self.graph = graph
        self.error: BaseException | None = None

    def run(self) -> None:
        try:
            if isinstance(self.node.processor, Source):
                self._run_source()
            else:
                self._run_interior()
        except BaseException as e:         # surfaced by FlowGraph.join
            self.error = e
            self.graph._record_error(self.node.processor.name, e)
        finally:
            self.node.done.set()

    # ------------------------------------------------------------------
    def _emit(self, rel: str, ff: FlowFile) -> None:
        self._emit_batch(rel, [ff])

    def _emit_batch(self, rel: str, ffs: list[FlowFile]) -> None:
        """Route a same-relationship batch downstream: provenance per record,
        but one ``offer_batch`` (single lock/notify) per connection."""
        node = self.node
        proc = node.processor
        prov = self.graph.provenance
        if rel == REL_DROP:
            prov.record_batch("DROP", ffs, proc.name)
            proc.stats.dropped += len(ffs)
            return
        conns = node.outputs.get(rel)
        if not conns:
            # unwired relationship == auto-terminated (NiFi semantics)
            prov.record_batch("DROP", ffs, proc.name,
                              details=f"auto-terminated:{rel}")
            proc.stats.dropped += len(ffs)
            return
        prov.record_batch("ROUTE", ffs, proc.name, details=rel)
        delivered = len(ffs)
        for conn in conns:
            offered = 0
            while offered < len(ffs) and not self.graph.stopping.is_set():
                offered += conn.offer_batch(ffs[offered:], block=True,
                                            timeout=0.25)
            delivered = min(delivered, offered)
        proc.stats.out_records += delivered
        proc.stats.out_bytes += sum(ff.size for ff in ffs[:delivered])

    def _emit_all(self, outputs: Iterable[tuple[str, FlowFile]]) -> None:
        """Group a trigger's outputs by relationship (order preserved within
        each relationship) and emit each group as one batch."""
        by_rel: dict[str, list[FlowFile]] = {}
        for rel, ff in outputs:
            by_rel.setdefault(rel, []).append(ff)
        for rel, ffs in by_rel.items():
            self._emit_batch(rel, ffs)

    def _run_source(self) -> None:
        node = self.node
        proc = node.processor
        proc.on_start()
        assert isinstance(proc, Source)
        batch: list[FlowFile] = []

        def trigger(batch: list[FlowFile]) -> None:
            self.graph.provenance.record_batch("CREATE", batch, proc.name)
            proc.stats.in_records += len(batch)
            proc.stats.in_bytes += sum(ff.size for ff in batch)
            self._emit_all(proc.on_trigger(batch))

        batch_t0 = 0.0
        it = iter(proc.records())
        pull_was_slow = True     # deliver the first record immediately
        while True:
            t_pull = time.monotonic()
            try:
                ff = next(it)
            except StopIteration:
                break
            now = time.monotonic()
            # a live source (yields separated by real time) degrades to
            # per-record delivery; only back-to-back yields batch up. The
            # residual worst case is a fast burst followed by a long stall:
            # the burst's tail waits for the next yield or end-of-stream.
            pull_was_slow = (pull_was_slow
                             or now - t_pull >= proc.source_linger_sec)
            if self.graph.stopping.is_set():
                batch.clear()
                break
            if not batch:
                batch_t0 = now
            batch.append(ff)
            if (len(batch) >= proc.batch_size
                    or pull_was_slow
                    or now - batch_t0 >= proc.source_linger_sec):
                trigger(batch)
                batch = []
                pull_was_slow = False
        if batch:
            trigger(batch)
        self._emit_all(proc.final_flush())
        proc.on_stop()

    def _run_interior(self) -> None:
        node = self.node
        proc = node.processor
        proc.on_start()
        conn = node.input
        assert conn is not None
        while True:
            batch = conn.poll_batch(proc.batch_size, timeout=0.05)
            if not batch:
                upstream_done = all(u.done.is_set() for u in node.upstreams)
                if (upstream_done and len(conn) == 0) or self.graph.stopping.is_set():
                    break
                continue
            proc.stats.in_records += len(batch)
            proc.stats.in_bytes += sum(ff.size for ff in batch)
            self._emit_all(proc.on_trigger(batch))
        self._emit_all(proc.final_flush())
        proc.on_stop()


class FlowNode:
    def __init__(self, processor: Processor) -> None:
        self.processor = processor
        self.input: Connection | None = None
        self.outputs: dict[str, list[Connection]] = {}
        self.upstreams: list[FlowNode] = []
        self.done = threading.Event()
