"""Processor model + threaded flow engine (NiFi analogue, paper §III.A).

A ``Processor`` consumes FlowFiles from its single input connection and emits
FlowFiles onto named *relationships* (e.g. ``unique``/``duplicate`` for
DetectDuplicate). Relationships are wired to downstream connections by the
``FlowGraph``. Sources are processors without an input that pull records from
a (replayable) generator.

Scheduling: each processor runs on its own thread; blocking ``offer`` on a
full downstream connection stalls the thread, which in turn stops it from
draining *its* input — NiFi's transitive backpressure, for free.

Termination: a source finishes when its generator is exhausted; an interior
processor finishes when every upstream is finished and its input is drained.
``FlowGraph.run_to_completion`` joins the whole DAG.

Fault tolerance (supervision, retry, dead-lettering)
----------------------------------------------------
Each worker runs under a supervisor loop governed by its node's
:class:`RestartPolicy`. A processor-level failure (an exception escaping the
trigger path) restarts the processor with exponential backoff up to
``max_restarts``; the in-flight batch is re-queued first so no record is
lost (at-least-once), and a source restart fast-forwards its replayable
generator past the records it already emitted. When the restart budget is
exhausted the node enters the terminal ``FAILED`` state and the graph
surfaces a ``FlowError``.

Record-level (data) failures take the retry path instead when the input
connection opted in with ``max_retries > 0``: a failing batch is
reprocessed record-at-a-time to isolate the poison record,
which is penalized (``retry_penalty_sec * 2**k``) and re-queued with a
``retry.count`` attribute; once the count exceeds ``max_retries`` the record
is routed to the graph's dead-letter connection (or dropped with DROP
provenance if none is wired). Innocent records in a failing batch may be
re-emitted — duplicates are allowed, loss is not.

Elastic worker pools (congestion response, paper §I "highly irregular
data rates")
------------------------------------------------------------------------
``graph.add(proc, max_workers=N)`` (or the class attrs
``min_workers``/``max_workers``) lets a processor's input be drained by up
to N threads. The node's primary worker stays the supervised one — it owns
restarts, penalized-retry redelivery, idle triggers and the final flush —
and doubles as the pool governor: when the input connection's depth sits
at/above ``scale_up_utilization`` of its object threshold for
``scale_up_polls`` consecutive polls, it spawns a helper drainer
(``scale_ups`` counter, ``workers`` gauge); a helper retires itself after
``scale_down_idle_polls`` consecutive empty polls (``scale_downs``). A
helper that hits a processor-level failure hands its in-flight batch back
to the queue and exits, so the failure re-surfaces on the primary's fully
supervised path. Pools require a thread-safe ``process``/``on_trigger`` and
forfeit cross-record ordering; they are refused for durable inputs (the
acked frontier is a count prefix — concurrent out-of-order acks would cover
unsettled records), for ``buffers_across_triggers`` processors, and for
idle-triggered ones (single-threaded state machines).
"""
from __future__ import annotations

import itertools
import threading
import time
import traceback
from dataclasses import dataclass
from typing import Callable, Iterable, Iterator, Mapping

from . import faults
from .connection import Connection, DurableConnection
from .flowfile import FlowFile
from .metrics import ComponentStats
from .provenance import ProvenanceRepository

REL_SUCCESS = "success"
REL_FAILURE = "failure"

#: Relationship name whose FlowFiles are dropped (with DROP provenance).
REL_DROP = "__drop__"

#: FlowFile attribute marking a record sampled for end-to-end tracing (the
#: value is the trace id == lineage_id). Stamped by ``FlowGraph.sample_trace``
#: at admission; every hop then records a timed ``span`` provenance event.
ATTR_TRACE_ID = "trace.id"

#: FlowFile attributes stamped by the retry / dead-letter machinery.
ATTR_RETRY_COUNT = "retry.count"
ATTR_LAST_ERROR = "retry.last.error"
ATTR_RETRY_NOT_BEFORE = "retry.not.before"
ATTR_DEAD_LETTER_SOURCE = "dead.letter.source"
ATTR_DEAD_LETTER_REASON = "dead.letter.reason"

#: ceiling on any single penalization wait (also guards against a stale
#: ``retry.not.before`` replayed from a previous boot's monotonic clock)
_MAX_PENALTY_WAIT = 2.0


@dataclass(frozen=True)
class RestartPolicy:
    """Per-processor supervision policy (exponential backoff).

    The default (``max_restarts=0``) preserves fail-fast semantics: the
    first escaped exception marks the node ``FAILED`` and stops the graph.
    Restart ``k`` (1-based) sleeps
    ``min(backoff_cap_sec, backoff_base_sec * backoff_factor**(k-1))``.
    """

    max_restarts: int = 0
    backoff_base_sec: float = 0.05
    backoff_factor: float = 2.0
    backoff_cap_sec: float = 2.0

    def backoff_for(self, restart_no: int) -> float:
        return min(self.backoff_cap_sec,
                   self.backoff_base_sec * self.backoff_factor ** (restart_no - 1))


class Processor:
    """Base class. Subclasses implement ``process`` (record-at-a-time) or
    override ``on_trigger`` (batch)."""

    #: relationships this processor may emit on (used for wiring validation)
    relationships: tuple[str, ...] = (REL_SUCCESS,)
    #: max records pulled per trigger (batching amortizes queue locks)
    batch_size: int = 256
    #: source batching window, evaluated at each arrival: records yielded
    #: back-to-back within this window batch up; a record that arrives after
    #: a slower pull is delivered immediately. (A burst followed by a total
    #: stall leaves the burst's tail buffered until the next yield or
    #: end-of-stream — bounding that would need a flush timer thread.)
    source_linger_sec: float = 0.05
    #: processors that absorb records into internal state across triggers
    #: (e.g. MergeContent) set this: a durable input connection then defers
    #: its acks to the final flush, so a crash replays the whole buffered
    #: window instead of losing it (at-least-once for buffering stages).
    buffers_across_triggers: bool = False
    #: opt-in idle triggering: when set, the worker calls ``on_trigger([])``
    #: at most every this-many seconds while the input queue is empty, so a
    #: processor whose output depends on state *outside* its input stream
    #: (e.g. WindowedAggregate closing windows off the fabric-wide low
    #: watermark) can fire without waiting for the next record. ``None``
    #: (default) keeps the engine's poll loop unchanged.
    idle_trigger_sec: float | None = None
    #: elastic worker pool bounds (see module docstring). ``max_workers=1``
    #: (default) keeps the engine single-threaded per node; raising it
    #: asserts the processor's trigger path is thread-safe. Overridable per
    #: node via ``FlowGraph.add(proc, min_workers=, max_workers=)``.
    min_workers: int = 1
    max_workers: int = 1
    #: input-depth fraction (vs the object threshold) that counts as
    #: congested for scale-up purposes
    scale_up_utilization: float = 0.75
    #: consecutive congested polls before the primary adds a helper
    scale_up_polls: int = 3
    #: consecutive empty polls before a surplus helper retires
    scale_down_idle_polls: int = 20

    def __init__(self, name: str) -> None:
        self.name = name
        self.stats = ComponentStats(name)

    # -- to be implemented by subclasses -------------------------------------
    def process(self, ff: FlowFile) -> Iterable[tuple[str, FlowFile]]:
        raise NotImplementedError

    def on_trigger(self, batch: list[FlowFile]
                   ) -> Iterable[tuple[str, FlowFile]]:
        for ff in batch:
            yield from self.process(ff)

    # -- lifecycle hooks -------------------------------------------------------
    def on_start(self) -> None: ...
    def on_stop(self) -> None:
        """Called at shutdown; may emit nothing. Batch processors flush here
        via ``final_flush``."""

    def final_flush(self) -> Iterable[tuple[str, FlowFile]]:
        return ()


class Source(Processor):
    """A processor with no input; wraps a replayable record generator."""

    def __init__(self, name: str,
                 generator: Callable[[], Iterator[FlowFile]]) -> None:
        super().__init__(name)
        self._generator_fn = generator

    def records(self) -> Iterator[FlowFile]:
        return self._generator_fn()

    def process(self, ff: FlowFile) -> Iterable[tuple[str, FlowFile]]:
        yield REL_SUCCESS, ff


class _Worker(threading.Thread):
    def __init__(self, node: "FlowNode", graph: "FlowGraph") -> None:
        super().__init__(name=f"flow-{node.processor.name}", daemon=True)
        self.node = node
        self.graph = graph
        self.error: BaseException | None = None

    def run(self) -> None:
        node, graph = self.node, self.graph
        proc = node.processor
        policy = node.restart_policy
        try:
            while True:
                try:
                    node.state = "RUNNING"
                    if isinstance(proc, Source):
                        self._run_source()
                    else:
                        self._run_interior()
                    # a worker that bailed out because the graph is being
                    # torn down did not finish its stream — say so
                    node.state = ("STOPPED" if graph.stopping.is_set()
                                  else "COMPLETED")
                    return
                except BaseException as e:   # supervised (paper: robustness)
                    if (graph.stopping.is_set()
                            or node.restarts >= policy.max_restarts):
                        node.state = "FAILED"
                        self.error = e       # surfaced by FlowGraph.join
                        graph._record_error(proc.name, e)
                        return
                    node.restarts += 1
                    proc.stats.add(restarts=1)
                    delay = policy.backoff_for(node.restarts)
                    node.backoff_history.append(delay)
                    node.state = "RESTARTING"
                    node.last_error = e
                    if graph.stopping.wait(delay):
                        node.state = "STOPPED"
                        return
        finally:
            node.done.set()

    # ------------------------------------------------------------------
    def _emit(self, rel: str, ff: FlowFile) -> None:
        self._emit_batch(rel, [ff])

    def _emit_batch(self, rel: str, ffs: list[FlowFile]) -> bool:
        """Route a same-relationship batch downstream: provenance per record,
        but one ``offer_batch`` (single lock/notify) per connection. Returns
        False when a shutdown (``graph.stopping``) truncated delivery — the
        caller must not ack a durable input for a partially-emitted batch."""
        node = self.node
        proc = node.processor
        prov = self.graph.provenance
        if rel == REL_DROP:
            prov.record_batch("DROP", ffs, proc.name)
            proc.stats.add(dropped=len(ffs))
            return True
        conns = node.outputs.get(rel)
        if not conns:
            # unwired relationship == auto-terminated (NiFi semantics)
            prov.record_batch("DROP", ffs, proc.name,
                              details=f"auto-terminated:{rel}")
            proc.stats.add(dropped=len(ffs))
            return True
        prov.record_batch("ROUTE", ffs, proc.name, details=rel)
        delivered = len(ffs)
        for conn in conns:
            offered = 0
            while offered < len(ffs) and not self.graph.stopping.is_set():
                offered += conn.offer_batch(ffs[offered:], block=True,
                                            timeout=0.25)
            delivered = min(delivered, offered)
        proc.stats.add(out_records=delivered,
                       out_bytes=sum(ff.size for ff in ffs[:delivered]))
        return delivered == len(ffs)

    def _emit_all(self, outputs: Iterable[tuple[str, FlowFile]]) -> bool:
        """Group a trigger's outputs by relationship (order preserved within
        each relationship) and emit each group as one batch. Returns True
        only if every record was fully delivered downstream."""
        by_rel: dict[str, list[FlowFile]] = {}
        for rel, ff in outputs:
            by_rel.setdefault(rel, []).append(ff)
        complete = True
        for rel, ffs in by_rel.items():
            complete &= self._emit_batch(rel, ffs)
        return complete

    def _run_source(self) -> None:
        node = self.node
        proc = node.processor
        proc.on_start()
        assert isinstance(proc, Source)
        site = "proc." + proc.name
        batch: list[FlowFile] = []

        def trigger(batch: list[FlowFile]) -> None:
            hist = node.proc_hist
            t0 = time.perf_counter() if hist is not None else 0.0
            faults.fire(site, batch=batch)
            batch = self.graph.sample_trace(batch)
            self.graph.provenance.record_batch("CREATE", batch, proc.name)
            proc.stats.add(in_records=len(batch),
                           in_bytes=sum(ff.size for ff in batch))
            self._emit_all(proc.on_trigger(batch))
            if hist is not None and batch:
                # one perf_counter pair per batch; includes downstream offer
                # time, so a backpressured source shows up here, not nowhere
                hist.record(time.perf_counter() - t0, len(batch))
            # counted only after a full emit: a supervisor restart replays
            # the replayable generator from here (at-least-once — a crash
            # mid-emit re-emits the whole batch, duplicates allowed)
            node.source_emitted += len(batch)

        batch_t0 = 0.0
        it = iter(proc.records())
        if node.source_emitted:      # restart: fast-forward the replay
            next(itertools.islice(it, node.source_emitted,
                                  node.source_emitted), None)
        pull_was_slow = True     # deliver the first record immediately
        while True:
            t_pull = self.graph._clock()
            try:
                ff = next(it)
            except StopIteration:
                break
            now = self.graph._clock()
            # a live source (yields separated by real time) degrades to
            # per-record delivery; only back-to-back yields batch up. The
            # residual worst case is a fast burst followed by a long stall:
            # the burst's tail waits for the next yield or end-of-stream.
            pull_was_slow = (pull_was_slow
                             or now - t_pull >= proc.source_linger_sec)
            if self.graph.stopping.is_set():
                batch.clear()
                break
            if not batch:
                batch_t0 = now
            batch.append(ff)
            if (len(batch) >= proc.batch_size
                    or pull_was_slow
                    or now - batch_t0 >= proc.source_linger_sec):
                trigger(batch)
                batch = []
                pull_was_slow = False
        if batch:
            trigger(batch)
        self._emit_all(proc.final_flush())
        proc.on_stop()

    def _run_interior(self) -> None:
        node = self.node
        proc = node.processor
        proc.on_start()
        conn = node.input
        assert conn is not None
        site = "proc." + proc.name
        durable = isinstance(conn, DurableConnection)
        # buffering processors ack only at final flush: an ack at trigger
        # boundaries would cover records still sitting in internal state,
        # which a crash would then silently lose
        defer_acks = durable and proc.buffers_across_triggers
        deferred = 0
        idle_every = proc.idle_trigger_sec
        last_trigger = self.graph._clock()
        # -- elastic pool governor state (primary worker only) ---------------
        for _ in range(max(0, node.min_workers - 1)):
            self._spawn_helper(governor=False)
        congested_polls = 0
        try:
            while True:
                if node.pending_retries:
                    self._requeue_due_retries(conn)
                if self.graph.stopping.is_set():
                    # abandon the backlog on shutdown. This also closes a WAL
                    # frontier hole: the count-based frontier tolerates at
                    # most one unsettled (un-acked) batch, and unsettlement
                    # only happens when stopping truncates an emit — so no
                    # batch may be processed (and acked) after stopping lands.
                    break
                if node.max_workers > 1:
                    # scale up on sustained congestion: depth at/over the
                    # high-water fraction of the object threshold for K
                    # consecutive polls (the gauges FlowGraph.status() shows)
                    if len(conn) >= proc.scale_up_utilization \
                            * conn.object_threshold:
                        congested_polls += 1
                        if congested_polls >= proc.scale_up_polls \
                                and node.pool_size < node.max_workers:
                            self._spawn_helper()
                            congested_polls = 0
                    else:
                        congested_polls = 0
                batch = conn.poll_batch(proc.batch_size, timeout=0.05)
                if not batch:
                    if self.graph.stopping.is_set():
                        break
                    if node.pending_retries:
                        continue      # penalized records still owed to us
                    upstream_done = all(u.done.is_set()
                                        for u in node.upstreams)
                    # pool_size == 1 gate: a helper may still hold an
                    # in-flight batch that a failure would hand back to the
                    # queue — the primary must outlive every helper so that
                    # replay lands on its supervised path
                    if upstream_done and len(conn) == 0 \
                            and node.pool_size == 1:
                        break
                    if (idle_every is not None
                            and self.graph._clock() - last_trigger >= idle_every):
                        # opt-in empty trigger: lets state-driven processors
                        # (watermark window closes) fire while the queue is
                        # quiet. Nothing to ack — the batch is empty.
                        last_trigger = self.graph._clock()
                        self._process_batch(conn, [], site)
                    continue
                if durable and conn.max_retries > 0:
                    self._wait_for_penalties(batch)
                last_trigger = self.graph._clock()
                proc.stats.add(in_records=len(batch),
                               in_bytes=sum(ff.size for ff in batch))
                settled = self._process_batch(conn, batch, site)
                if durable and settled:
                    # every record emitted / re-journaled / dead-lettered:
                    # the WAL frontier may advance past this batch
                    if defer_acks:
                        deferred += len(batch)
                    else:
                        conn.ack(len(batch))
        finally:
            # helpers must drain their in-flight batches before the final
            # flush / on_stop — and before node.done releases downstreams
            self._join_helpers()
        flushed = self._emit_all(proc.final_flush())
        if defer_acks and deferred and flushed \
                and not self.graph.stopping.is_set():
            conn.ack(deferred)
        proc.on_stop()

    # -- elastic pool (see module docstring) -----------------------------------
    def _spawn_helper(self, governor: bool = True) -> None:
        node = self.node
        with node.pool_lock:
            if node.pool_size >= node.max_workers:
                return
            node.pool_size += 1
            idx = node.helpers_spawned = node.helpers_spawned + 1
            t = threading.Thread(
                target=self._run_helper,
                name=f"flow-{node.processor.name}-w{idx}", daemon=True)
            node.helpers.append(t)
        node.processor.stats.set(workers=node.pool_size)
        if governor:     # the initial min_workers fill is not a scale event
            node.processor.stats.add(scale_ups=1)
        t.start()

    def _run_helper(self) -> None:
        """Surplus drainer for one node: poll → trigger → emit, no
        supervision duties. Exits on shutdown, end of stream, sustained
        idleness (scale-down), or a processor-level failure — in that last
        case ``_process_batch``'s escalation path has already handed the
        in-flight batch back to the queue, so the failure replays on the
        primary's supervised path instead of being lost."""
        node = self.node
        proc = node.processor
        conn = node.input
        site = "proc." + proc.name
        idle_polls = 0
        departed = False

        def depart() -> None:
            nonlocal departed
            with node.pool_lock:
                node.pool_size -= 1
                node.helpers.remove(threading.current_thread())
            departed = True
            proc.stats.set(workers=node.pool_size)

        try:
            while not self.graph.stopping.is_set():
                batch = conn.poll_batch(proc.batch_size, timeout=0.05)
                if not batch:
                    upstream_done = all(u.done.is_set()
                                        for u in node.upstreams)
                    if upstream_done and len(conn) == 0:
                        return
                    idle_polls += 1
                    if idle_polls >= proc.scale_down_idle_polls:
                        # check-and-leave under the pool lock: two idle
                        # helpers racing here must not both retire past
                        # min_workers
                        with node.pool_lock:
                            retire = node.pool_size > node.min_workers
                            if retire:
                                node.pool_size -= 1
                                node.helpers.remove(
                                    threading.current_thread())
                        if retire:
                            departed = True
                            proc.stats.set(workers=node.pool_size)
                            proc.stats.add(scale_downs=1)
                            return
                        idle_polls = 0
                    continue
                idle_polls = 0
                proc.stats.add(in_records=len(batch),
                               in_bytes=sum(ff.size for ff in batch))
                try:
                    self._process_batch(conn, batch, site)
                except Exception as e:   # noqa: BLE001 — replays on primary
                    node.last_error = e
                    return
        finally:
            if not departed:
                depart()

    def _join_helpers(self) -> None:
        while True:
            with self.node.pool_lock:
                helpers = list(self.node.helpers)
            if not helpers:
                return
            for t in helpers:
                t.join()

    def _wait_for_penalties(self, batch: list[FlowFile]) -> None:
        """Durable-connection penalization: retried records are re-queued
        immediately (the WAL frontier must stay a strict prefix, so their
        delayed copies cannot live outside the journal), carrying a
        ``retry.not.before`` stamp instead. Honor it at delivery time —
        head-of-line, like NiFi's penalized FlowFiles."""
        now = self.graph._clock()
        wait = 0.0
        for ff in batch:
            nb = ff.attributes.get(ATTR_RETRY_NOT_BEFORE)
            if nb is not None:
                wait = max(wait, float(nb) - now)
        if wait > 0:
            self.graph.stopping.wait(min(wait, _MAX_PENALTY_WAIT))

    # -- failure handling ------------------------------------------------------
    def _requeue_due_retries(self, conn: Connection) -> None:
        """Move penalized records whose penalty expired back into the input
        queue (on a DurableConnection they were already re-journaled and
        re-queued at failure time, so this list stays empty there)."""
        node = self.node
        now = self.graph._clock()
        # the filter-and-swap below races with pool helpers appending via
        # _retry_or_dead_letter — an unguarded swap would drop their records
        with node.retry_lock:
            due = [ff for t, ff in node.pending_retries if t <= now]
            if not due:
                return
            node.pending_retries = [(t, ff) for t, ff in node.pending_retries
                                    if t > now]
        # requeue() bypasses backpressure: this worker is the queue's only
        # drainer, so a blocking offer against a full queue would deadlock
        conn.requeue(due)

    def _process_batch(self, conn: Connection, batch: list[FlowFile],
                       site: str, top: bool = True) -> bool:
        """Trigger the processor on ``batch``; on failure either escalate to
        the supervisor (re-queueing the in-flight batch first so nothing is
        lost) or, when retry/dead-letter routing is configured, isolate the
        poison record. Returns True when every record is settled (emitted,
        re-queued, or dead-lettered)."""
        node = self.node
        proc = node.processor
        graph = self.graph
        # time only top-level triggers: the poison-isolation recursion below
        # re-runs the same records record-at-a-time (top=False) and must not
        # double-count them. One perf_counter pair per batch, the batch size
        # folded in as the bucket weight — per-record cost ~amortized to zero.
        hist = node.proc_hist if top else None
        t0 = time.perf_counter() if hist is not None else 0.0
        try:
            faults.fire(site, batch=batch)
            settled = self._emit_all(proc.on_trigger(batch))
        except Exception as e:
            # retry only when the connection opted in; a wired DLQ alone must
            # not turn every transient failure into an instant quarantine
            # (and the quarantine itself failing must escalate, not
            # re-dead-letter into its own input forever). An EMPTY batch (an
            # idle trigger) has no record to isolate — record-at-a-time
            # reprocessing would run zero times and silently swallow the
            # error, so it must escalate to the supervisor instead
            retryable = (conn.max_retries > 0 and bool(batch)
                         and self.node is not graph._dlq_node)
            if not retryable:
                # escalate to the supervisor — but first hand the in-flight
                # batch back to the queue so a restart cannot lose it.
                # requeue() bypasses backpressure: blocking here would
                # deadlock (this worker is the queue's only drainer).
                conn.requeue(batch)
                # never ack for an ack-deferring processor: the frontier is
                # a count-prefix, so this ack would cover the OLDEST unacked
                # records — the ones still buffered inside the processor —
                # not the batch just requeued
                if (top and isinstance(conn, DurableConnection)
                        and not proc.buffers_across_triggers):
                    conn.ack(len(batch))
                raise
            if len(batch) == 1:
                return self._retry_or_dead_letter(conn, batch[0], e)
            # reprocess record-at-a-time: innocents pass, poison isolates
            settled = True
            for ff in batch:
                settled &= self._process_batch(conn, [ff], site, top=False)
            return settled
        # telemetry — reached only on the non-exception path
        if hist is not None and batch:
            elapsed = time.perf_counter() - t0
            hist.record(elapsed, len(batch))
            if node.e2e_hist is not None:
                # terminal hop: ingest→land latency off the admission stamp
                # (entry_ts survives log round-trips — fabric workers report
                # the record's true fabric-entry time). One wall-clock read
                # per batch.
                now = time.time()
                node.e2e_hist.record_many(
                    max(0.0, now - ff.entry_ts) for ff in batch)
            if graph._trace_every:
                traced = [ff for ff in batch
                          if ATTR_TRACE_ID in ff.attributes]
                if traced:
                    graph.provenance.record_batch(
                        "TRANSFORM", traced, proc.name,
                        details=f"span elapsed_us={int(elapsed * 1e6)} "
                                f"batch={len(batch)}")
        return settled

    def _retry_or_dead_letter(self, conn: Connection, ff: FlowFile,
                              err: Exception) -> bool:
        """Penalize-and-retry a failing record; quarantine it once the
        connection's retry budget is spent."""
        node = self.node
        proc = node.processor
        rc = int(ff.attributes.get(ATTR_RETRY_COUNT, "0"))
        if rc >= conn.max_retries:
            return self._dead_letter([ff], err)
        due = self.graph._clock() + conn.retry_penalty_sec * (2 ** rc)
        penalized = ff.with_attributes(**{
            ATTR_RETRY_COUNT: str(rc + 1),
            ATTR_LAST_ERROR: type(err).__name__,
            ATTR_RETRY_NOT_BEFORE: f"{due:.6f}"})
        proc.stats.add(retries=1)
        self.graph.provenance.record_batch("ROUTE", [penalized], proc.name,
                                           details=f"retry:{rc + 1}")
        if isinstance(conn, DurableConnection):
            # re-journal immediately so the acked frontier stays a prefix;
            # the penalty is honored at delivery time (_wait_for_penalties)
            conn.requeue([penalized])
            return True
        with node.retry_lock:
            node.pending_retries.append((due, penalized))
        return True

    def _dead_letter(self, ffs: list[FlowFile], err: Exception) -> bool:
        """Route exhausted/poison records to the graph's dead-letter
        connection (or drop-with-provenance when none is wired)."""
        proc = self.node.processor
        graph = self.graph
        tagged = [ff.with_attributes(**{
            ATTR_DEAD_LETTER_SOURCE: proc.name,
            ATTR_DEAD_LETTER_REASON: f"{type(err).__name__}: {err}"})
            for ff in ffs]
        proc.stats.add(dead_lettered=len(ffs))
        dlq = graph._dlq_conn
        if dlq is None:
            graph.provenance.record_batch("DROP", tagged, proc.name,
                                          details="dead-letter:unrouted")
            proc.stats.add(dropped=len(ffs))
            return True
        graph.provenance.record_batch("ROUTE", tagged, proc.name,
                                      details="dead-letter")
        offered = 0
        while offered < len(tagged) and not graph.stopping.is_set():
            offered += dlq.offer_batch(tagged[offered:], block=True,
                                       timeout=0.25)
        return offered == len(tagged)


class FlowNode:
    def __init__(self, processor: Processor,
                 restart_policy: RestartPolicy | None = None,
                 min_workers: int | None = None,
                 max_workers: int | None = None) -> None:
        self.processor = processor
        self.input: Connection | None = None
        self.outputs: dict[str, list[Connection]] = {}
        self.upstreams: list[FlowNode] = []
        self.done = threading.Event()
        # -- telemetry (set by FlowGraph when telemetry is on) ----------------
        #: process-time histogram for this node's triggers (None == off)
        self.proc_hist = None
        #: ingest→land latency histogram; set at start() on terminal nodes
        self.e2e_hist = None
        # -- supervision state (see module docstring) -------------------------
        self.restart_policy = restart_policy or RestartPolicy()
        self.state = "PENDING"   # RUNNING|RESTARTING|COMPLETED|STOPPED|FAILED
        self.restarts = 0
        self.backoff_history: list[float] = []
        self.last_error: BaseException | None = None
        self.pending_retries: list[tuple[float, FlowFile]] = []
        self.retry_lock = threading.Lock()
        self.source_emitted = 0
        # -- elastic pool state (see module docstring) ------------------------
        self.min_workers = (processor.min_workers if min_workers is None
                            else min_workers)
        self.max_workers = (processor.max_workers if max_workers is None
                            else max_workers)
        if not 1 <= self.min_workers <= self.max_workers:
            raise ValueError(
                f"{processor.name}: need 1 <= min_workers "
                f"({self.min_workers}) <= max_workers ({self.max_workers})")
        self.pool_lock = threading.Lock()
        self.pool_size = 1           # the supervised primary worker
        self.helpers: list[threading.Thread] = []
        self.helpers_spawned = 0
