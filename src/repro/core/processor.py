"""Processor model + threaded flow engine (NiFi analogue, paper §III.A).

A ``Processor`` consumes FlowFiles from its single input connection and emits
FlowFiles onto named *relationships* (e.g. ``unique``/``duplicate`` for
DetectDuplicate). Relationships are wired to downstream connections by the
``FlowGraph``. Sources are processors without an input that pull records from
a (replayable) generator.

Scheduling: each processor runs on its own thread; blocking ``offer`` on a
full downstream connection stalls the thread, which in turn stops it from
draining *its* input — NiFi's transitive backpressure, for free.

Termination: a source finishes when its generator is exhausted; an interior
processor finishes when every upstream is finished and its input is drained.
``FlowGraph.run_to_completion`` joins the whole DAG.
"""
from __future__ import annotations

import threading
import time
import traceback
from typing import Callable, Iterable, Iterator, Mapping

from .connection import Connection
from .flowfile import FlowFile
from .metrics import ComponentStats
from .provenance import ProvenanceRepository

REL_SUCCESS = "success"
REL_FAILURE = "failure"

#: Relationship name whose FlowFiles are dropped (with DROP provenance).
REL_DROP = "__drop__"


class Processor:
    """Base class. Subclasses implement ``process`` (record-at-a-time) or
    override ``on_trigger`` (batch)."""

    #: relationships this processor may emit on (used for wiring validation)
    relationships: tuple[str, ...] = (REL_SUCCESS,)
    #: max records pulled per trigger (batching amortizes queue locks)
    batch_size: int = 256

    def __init__(self, name: str) -> None:
        self.name = name
        self.stats = ComponentStats(name)

    # -- to be implemented by subclasses -------------------------------------
    def process(self, ff: FlowFile) -> Iterable[tuple[str, FlowFile]]:
        raise NotImplementedError

    def on_trigger(self, batch: list[FlowFile]
                   ) -> Iterable[tuple[str, FlowFile]]:
        for ff in batch:
            yield from self.process(ff)

    # -- lifecycle hooks -------------------------------------------------------
    def on_start(self) -> None: ...
    def on_stop(self) -> None:
        """Called at shutdown; may emit nothing. Batch processors flush here
        via ``final_flush``."""

    def final_flush(self) -> Iterable[tuple[str, FlowFile]]:
        return ()


class Source(Processor):
    """A processor with no input; wraps a replayable record generator."""

    def __init__(self, name: str,
                 generator: Callable[[], Iterator[FlowFile]]) -> None:
        super().__init__(name)
        self._generator_fn = generator

    def records(self) -> Iterator[FlowFile]:
        return self._generator_fn()

    def process(self, ff: FlowFile) -> Iterable[tuple[str, FlowFile]]:
        yield REL_SUCCESS, ff


class _Worker(threading.Thread):
    def __init__(self, node: "FlowNode", graph: "FlowGraph") -> None:
        super().__init__(name=f"flow-{node.processor.name}", daemon=True)
        self.node = node
        self.graph = graph
        self.error: BaseException | None = None

    def run(self) -> None:
        try:
            if isinstance(self.node.processor, Source):
                self._run_source()
            else:
                self._run_interior()
        except BaseException as e:         # surfaced by FlowGraph.join
            self.error = e
            self.graph._record_error(self.node.processor.name, e)
        finally:
            self.node.done.set()

    # ------------------------------------------------------------------
    def _emit(self, rel: str, ff: FlowFile) -> None:
        node = self.node
        proc = node.processor
        if rel == REL_DROP:
            self.graph.provenance.record("DROP", ff, proc.name)
            proc.stats.dropped += 1
            return
        conns = node.outputs.get(rel)
        if not conns:
            # unwired relationship == auto-terminated (NiFi semantics)
            self.graph.provenance.record("DROP", ff, proc.name,
                                         details=f"auto-terminated:{rel}")
            proc.stats.dropped += 1
            return
        self.graph.provenance.record("ROUTE", ff, proc.name, details=rel)
        for conn in conns:
            while not self.graph.stopping.is_set():
                try:
                    if conn.offer(ff, block=True, timeout=0.25):
                        break
                except Exception:
                    raise
            else:
                return
        proc.stats.out_records += 1
        proc.stats.out_bytes += ff.size

    def _run_source(self) -> None:
        node = self.node
        proc = node.processor
        proc.on_start()
        assert isinstance(proc, Source)
        for ff in proc.records():
            if self.graph.stopping.is_set():
                break
            self.graph.provenance.record("CREATE", ff, proc.name)
            proc.stats.in_records += 1
            proc.stats.in_bytes += ff.size
            for rel, out in proc.on_trigger([ff]):
                self._emit(rel, out)
        for rel, out in proc.final_flush():
            self._emit(rel, out)
        proc.on_stop()

    def _run_interior(self) -> None:
        node = self.node
        proc = node.processor
        proc.on_start()
        conn = node.input
        assert conn is not None
        while True:
            batch = conn.poll_batch(proc.batch_size, timeout=0.05)
            if not batch:
                upstream_done = all(u.done.is_set() for u in node.upstreams)
                if (upstream_done and len(conn) == 0) or self.graph.stopping.is_set():
                    break
                continue
            for ff in batch:
                proc.stats.in_records += 1
                proc.stats.in_bytes += ff.size
            for rel, out in proc.on_trigger(batch):
                self._emit(rel, out)
        for rel, out in proc.final_flush():
            self._emit(rel, out)
        proc.on_stop()


class FlowNode:
    def __init__(self, processor: Processor) -> None:
        self.processor = processor
        self.input: Connection | None = None
        self.outputs: dict[str, list[Connection]] = {}
        self.upstreams: list[FlowNode] = []
        self.done = threading.Event()
