"""ReplicatedLog — N-replica durable log with leader-coordinated ingestion.

The Kafka half of the paper's case study (§III.C) finally gets its
replication story: a :class:`~repro.core.logstore.LogStore` built from N
in-process :class:`~repro.core.log.PartitionedLog` replica stores
(``root/replica-<i>``), coordinated per ``(topic, partition)`` by a replica
set with a **deterministic leader** and an **epoch** that fences zombies —
the same generation-fencing scheme consumer groups use against stale
members (:class:`~repro.core.delivery.StaleGeneration`).

Data path
---------
Appends go to the partition's leader replica (assigning the authoritative
offsets) and are *shipped* to followers as contiguous offset ranges read
back from the leader with the existing batched machinery — one
``pread``-range read, one ``append_batch`` per ship — so a follower's
segment files are byte-identical to the leader's. Reads are served by the
leader.

Durability levels
-----------------
``acks="all"``     every in-sync follower is shipped synchronously before an
                   append returns: the record set survives the loss of any
                   replica's data directory (the acceptance scenario).
``acks="leader"``  followers are shipped lazily, once they trail by
                   ``ship_batch_records`` (and fully on ``flush``/``close``):
                   one store write per append on the hot path, bounded
                   follower lag — a machine loss may drop the unshipped
                   suffix (at-most-``ship_batch_records`` records per
                   partition).

Per-replica ``fsync_every`` (an int per replica, or one int for all) sets
each store's group-fsync cadence, so e.g. the leader can run memory-speed
while one follower fsyncs every batch.

Failover
--------
Any replica-store failure observed on the append/read path (or injected via
the fault sites below, or declared by :meth:`ReplicatedLog.kill_replica`)
removes the replica from the partition's in-sync set and bumps the epoch;
the next replica in preference order (``(partition + k) % N``) is promoted.
A writer that captured the old leadership re-validates the epoch after its
store write and, when fenced, retries against the new leader — the write
that landed on the demoted replica is abandoned there (duplicates allowed,
loss is not: at-least-once). **Idempotent producers close that duplicate
window**: when the batch carries ``(producer_id, base_seq)``, the fenced
retry first checks whether the batch already reached the new leader through
a racing ship (ships are byte-identical contiguous prefixes, so the batch's
first and last records are compared at the recorded offsets) and skips the
re-append when it did — the regression the PR 3 docs left open. ``restore_replica`` rebuilds a returning
replica by full per-partition resync (reset to the leader's
``begin_offset``, then range shipping) before it rejoins the in-sync set.

On re-open over existing directories, replicas are reconciled per
partition against persisted metadata (``replication-meta.json``: the last
recorded (leader, epoch) per partition, rewritten on every leadership
change, plus a clean-shutdown marker): the last leader is authoritative —
under ``acks="all"`` its log holds every acked record, so a zombie's
equal-or-longer log must not outvote it — and the others are resynced from
it. A recorded leader whose directory was lost (the topic is gone from its
store) yields to the longest surviving replica, which is exactly why
``acks="all"`` survives deleting the leader's directory. After an unclean
shutdown every non-authority replica is rebuilt unconditionally, since
equal-length divergence at the same offsets is possible after a fenced
failover. (Residual window, documented not solved: a crash between an
in-memory demotion and the metadata write can still crown the old leader
at reopen — closing it needs per-record epochs, Kafka's leader-epoch
checkpoint protocol.)

Deterministic fault sites (:mod:`repro.core.faults`):

  ``replica.leader``  before each leader-store append
                      (ctx: ``topic, partition, replica, epoch``)
  ``replica.fence``   after the leader-store append, before the epoch
                      re-validation (ctx: ``topic, partition, replica,
                      epoch``) — arm a callable that ships + demotes to
                      reproduce the fenced zombie re-append deterministically
  ``replica.ship``    before each follower range-ship
                      (ctx: ``topic, partition, replica, offset``)

A single-replica ``ReplicatedLog`` bypasses coordination entirely and
delegates straight to its one store — the PR-2 hot path, unchanged.
"""
from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path
from typing import Sequence

from . import faults
from .log import DEFAULT_SEGMENT_BYTES, PartitionedLog, route_partition
from .logstore import (LogRecord, LogStore, ProducerDedupTable,
                       atomic_write_bytes)

__all__ = ["ReplicatedLog", "ReplicationError", "StaleEpoch"]


class ReplicationError(RuntimeError):
    """No in-sync replica can serve the request (all replicas failed)."""


class StaleEpoch(ReplicationError):
    """A write raced a leadership change: the captured epoch is no longer
    current, so the store write may sit on a demoted (zombie) leader and
    must be retried against the new one."""


class _LeaderReadFailed(ReplicationError):
    """A ship's *source-side* read failed: the leader store is the broken
    party, not the follower being shipped to — demote the leader, never the
    follower (raised and handled inside this module only)."""


class _ReplicaSet:
    """Per-(topic, partition) coordination state.

    ``epoch`` is the **leader epoch** (Kafka's fencing token): it advances
    exactly when leadership changes, so a writer that captured ``(leader,
    epoch)`` knows after its store write whether that write landed on the
    authoritative replica. Removing a *follower* from the in-sync set does
    not bump it — concurrent appends to the surviving leader stay valid.

    Leadership is sticky: the preference order ``(partition + k) % n``
    seeds the initial leader (spreading leadership across replicas), and on
    failure the next alive replica in that order is promoted; a restored
    replica rejoins as a follower only (no fail-back), which kills the
    ABA hazard of a wiped-and-resynced replica regaining leadership inside
    a racing writer's capture window.

    ``lock`` guards membership/epoch only — store I/O happens outside it so
    a slow disk cannot convoy leadership changes. ``ship_lock`` serializes
    all follower writes of the partition (shipping and resync), keeping
    each follower single-writer and its offsets aligned with the leader's.
    """

    __slots__ = ("preference", "alive", "leader", "epoch", "lock",
                 "ship_lock")

    def __init__(self, partition: int, n: int, dead: set[int]) -> None:
        self.preference = tuple((partition + k) % n for k in range(n))
        self.alive: set[int] = set(range(n)) - dead
        self.leader: int | None = next(
            (r for r in self.preference if r in self.alive), None)
        self.epoch = 0
        self.lock = threading.Lock()
        self.ship_lock = threading.Lock()

    def snapshot(self) -> tuple[int, int]:
        """(leader, epoch) under the lock — the unit a writer captures."""
        with self.lock:
            if self.leader is None:
                raise ReplicationError("no in-sync replica")
            return self.leader, self.epoch

    def remove(self, replica: int, epoch: int | None = None) -> bool:
        """Drop ``replica`` from the in-sync set, promoting the next
        preferred follower (and bumping the epoch) when it led. With
        ``epoch`` given, the removal is itself fenced: two writers
        observing the same dead leader demote it once — the loser's view
        is stale and it simply re-snapshots. Returns True when leadership
        changed (the caller persists the new epoch)."""
        with self.lock:
            if epoch is not None and epoch != self.epoch:
                return False
            if replica not in self.alive:
                return False
            self.alive.discard(replica)
            if self.leader == replica:
                self.leader = next(
                    (r for r in self.preference if r in self.alive), None)
                self.epoch += 1
                return True
            return False

    def add(self, replica: int) -> bool:
        """Rejoin as a follower (leadership never fails back); revives a
        fully-dead set by making the restored replica its leader. Returns
        True when leadership changed."""
        with self.lock:
            self.alive.add(replica)
            if self.leader is None:
                self.leader = replica
                self.epoch += 1
                return True
            return False


class ReplicatedLog(LogStore):
    """Replicated :class:`LogStore` over N ``PartitionedLog`` replica stores.

    See the module docstring for the coordination model. Thread-safe; the
    producer-visible contract (dense offsets per partition, at-least-once
    appends, replayable reads) is identical to ``PartitionedLog``.
    """

    def __init__(self, root: str | Path, *, replicas: int = 2,
                 acks: str = "all",
                 segment_bytes: int = DEFAULT_SEGMENT_BYTES,
                 fsync_every: int | Sequence[int] = 0,
                 ship_batch_records: int = 512) -> None:
        if replicas < 1:
            raise ValueError("replicas must be >= 1")
        if acks not in ("leader", "all"):
            raise ValueError(f"unknown acks level {acks!r}")
        if ship_batch_records < 1:
            raise ValueError("ship_batch_records must be >= 1")
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.acks = acks
        self.ship_batch_records = ship_batch_records
        if isinstance(fsync_every, int):
            fsync_every = [fsync_every] * replicas
        if len(fsync_every) != replicas:
            raise ValueError("need one fsync_every per replica")
        self._stores: list[PartitionedLog] = [
            PartitionedLog(self.root / f"replica-{i}", segment_bytes,
                           fsync_every[i])
            for i in range(replicas)]
        self.n_replicas = replicas
        #: idempotent-producer sequence table, consulted on the fenced
        #: re-append path (single-replica delegates to the store's own)
        self._dedup = ProducerDedupTable()
        #: replicas whose store is closed/unusable for every partition
        self._dead: set[int] = set()
        self._sets: dict[tuple[str, int], _ReplicaSet] = {}
        self._admin_lock = threading.Lock()
        # single-replica fast path: no coordination, no shipping — every
        # call delegates to the one store (the non-replicated hot path)
        self._single = self._stores[0] if replicas == 1 else None
        #: persisted replica-set metadata: per-partition (leader, epoch)
        #: rewritten on every leadership change, plus a clean-shutdown
        #: marker — reopen trusts the last recorded leader as the
        #: authority (its log holds every acked record even when a zombie
        #: replica's is as long or longer) and resyncs unconditionally
        #: after an unclean shutdown (equal-length divergence detection)
        self._meta_path = self.root / "replication-meta.json"
        self._meta_partitions: dict[str, dict] = {}
        if self._single is None:
            self._reconcile_open()
            self._write_meta(clean=False)   # crash from here on is unclean

    # -- replica-set plumbing -------------------------------------------------
    def _rset(self, topic: str, partition: int) -> _ReplicaSet:
        key = (topic, partition)
        rs = self._sets.get(key)
        if rs is None:
            with self._admin_lock:
                rs = self._sets.get(key)
                if rs is None:
                    rs = _ReplicaSet(partition, self.n_replicas, self._dead)
                    self._sets[key] = rs
        return rs

    # -- persisted replica-set metadata ---------------------------------------
    def _load_meta(self) -> dict:
        if self._meta_path.exists():
            try:
                return json.loads(self._meta_path.read_text())
            except (ValueError, OSError):
                pass                     # torn initial write: treat unclean
        return {"clean": True, "partitions": {}}

    def _write_meta(self, clean: bool) -> None:
        """Atomically persist per-partition (leader, epoch) + the clean
        marker. Called on every leadership change (rare) and at close.
        Never call while holding a replica-set lock. Machine-crash-safe
        (fsync'd tmp + rename + dir fsync): reopen-time authority decisions
        hang off this file, so a torn rename target after a power loss
        would let an equal-length zombie outvote acked data."""
        with self._admin_lock:
            parts = dict(self._meta_partitions)
            for (t, p), rset in self._sets.items():
                with rset.lock:
                    if rset.leader is not None:
                        parts[f"{t}/{p}"] = {"leader": rset.leader,
                                             "epoch": rset.epoch}
            self._meta_partitions = parts
            atomic_write_bytes(
                self._meta_path,
                json.dumps({"clean": clean, "partitions": parts}).encode())

    def _demote(self, rset: _ReplicaSet, replica: int,
                epoch: int | None = None) -> None:
        if rset.remove(replica, epoch):
            self._write_meta(clean=False)

    # -- open-time reconciliation --------------------------------------------
    def _reconcile_open(self) -> None:
        """Union the replicas' topics, then make every replica a verbatim
        copy of the per-partition authority.

        The authority is the **last recorded leader** (from the persisted
        metadata): under ``acks="all"`` its log contains every acked
        record, while a longer log elsewhere can only carry an unacked
        zombie suffix — so length must not outvote leadership. Fallbacks:
        a recorded leader whose directory was lost (it no longer has the
        topic on disk) — or no record at all — yields to the longest
        replica in preference order. After an unclean shutdown every
        non-authority replica is resynced unconditionally: equal-length
        divergence at the same offsets is real after a fenced failover,
        and rebuilds are the only sound answer."""
        meta = self._load_meta()
        self._meta_partitions = dict(meta.get("partitions", {}))
        unclean = not meta.get("clean", True)
        topic_parts: dict[str, int] = {}
        had_topic: dict[str, set[int]] = {}
        for i, store in enumerate(self._stores):
            for t in store.topics():
                n = store.num_partitions(t)
                if topic_parts.setdefault(t, n) != n:
                    raise ReplicationError(
                        f"replicas disagree on partition count of {t!r}")
                had_topic.setdefault(t, set()).add(i)
        for t, nparts in topic_parts.items():
            for store in self._stores:
                store.create_topic(t, nparts)
            for p in range(nparts):
                ends = [s.end_offset(t, p) for s in self._stores]
                rset = self._rset(t, p)
                rec = self._meta_partitions.get(f"{t}/{p}")
                auth = None
                if rec is not None:
                    rl = int(rec["leader"])
                    rset.epoch = int(rec["epoch"])
                    if 0 <= rl < self.n_replicas and rl in had_topic[t]:
                        auth = rl
                if auth is None:
                    auth = max(rset.preference, key=lambda r: ends[r])
                    if rec is not None:     # leadership moved off the record
                        rset.epoch += 1
                with rset.lock:
                    rset.leader = auth
                for r in range(self.n_replicas):
                    if r != auth and (unclean or ends[r] != ends[auth]):
                        self._resync_partition(rset, t, p, auth, r)

    def _resync_partition(self, rset: _ReplicaSet, topic: str, p: int,
                          source: int, target: int) -> None:
        """Full per-partition rebuild of ``target`` from ``source``: reset
        to the source's begin_offset, then contiguous range shipping. Used
        at re-open and by ``restore_replica`` — after an unclean leadership
        history the target's suffix may diverge at the same offsets, so
        incremental catch-up would be unsound; a rebuild never is."""
        with rset.ship_lock:
            src = self._stores[source]
            dst = self._stores[target]
            dst.reset_partition(topic, p, src.begin_offset(topic, p))
            self._ship_range_locked(topic, p, source, target)

    def _ship_range_locked(self, topic: str, p: int, source: int,
                           target: int) -> None:
        """Ship ``[target_end, source_end)`` as batched range reads — the
        one replication data path (lazy catch-up, synchronous acks=all
        shipping, and resync all funnel through here). Caller holds the
        partition's ``ship_lock`` (followers are single-writer)."""
        src, dst = self._stores[source], self._stores[target]
        try:
            end = src.end_offset(topic, p)
        except Exception as e:
            raise _LeaderReadFailed(f"{topic}/{p}: replica {source}") from e
        pos = dst.end_offset(topic, p)
        while pos < end:
            faults.fire("replica.ship", topic=topic, partition=p,
                        replica=target, offset=pos)
            try:
                recs = src.read(topic, p, pos, self.ship_batch_records)
            except Exception as e:
                raise _LeaderReadFailed(
                    f"{topic}/{p}: replica {source}") from e
            if not recs:
                break
            if recs[0].offset != pos:
                raise ReplicationError(
                    f"{topic}/{p}: follower {target} at {pos} trails the "
                    f"leader's retained range (begins {recs[0].offset}); "
                    "restore_replica() to rebuild it")
            dst.append_batch(topic, [(r.key, r.value) for r in recs],
                             partition=p)
            pos = recs[-1].offset + 1

    def _replicate(self, rset: _ReplicaSet, topic: str, p: int, leader: int,
                   epoch: int, lazy: bool) -> None:
        """Fence, then ship followers up to the leader's end. ``lazy``
        (acks=leader) only ships a follower once it trails by
        >= ship_batch_records. A ship failure demotes the follower (the
        in-sync set shrinks, Kafka-style) — the append itself still
        succeeds on the survivors."""
        with rset.lock:
            if rset.epoch != epoch:
                # leadership changed while the caller wrote: its records
                # may sit on a demoted zombie — it must re-append
                raise StaleEpoch(f"{topic}/{p}: epoch moved past {epoch}")
            followers = [r for r in rset.preference
                         if r in rset.alive and r != leader]
        if not followers:
            return
        try:
            lend = self._stores[leader].end_offset(topic, p)
        except Exception:
            # the leader died between the append and replication (e.g. a
            # racing kill_replica closed its store): fail over, caller
            # re-appends on the promoted replica
            self._demote(rset, leader, epoch)
            raise StaleEpoch(f"{topic}/{p}: leader {leader} lost "
                             "before ship") from None
        for f in followers:
            if lazy:
                try:
                    if lend - self._stores[f].end_offset(topic, p) \
                            < self.ship_batch_records:
                        continue
                except Exception:
                    self._demote(rset, f)   # follower died: ISR shrink
                    continue
            try:
                with rset.ship_lock:
                    self._ship_range_locked(topic, p, leader, f)
            except _LeaderReadFailed:
                # the leader died under the ship — fail over and make the
                # caller re-append on the promoted replica
                self._demote(rset, leader, epoch)
                raise StaleEpoch(f"{topic}/{p}: leader {leader} lost "
                                 "mid-ship") from None
            except Exception:
                self._demote(rset, f)   # follower-side failure: ISR shrink

    # -- leader-routed operations ---------------------------------------------
    def _batch_present(self, store: PartitionedLog, topic: str, p: int,
                       entry, records: Sequence[tuple[bytes, bytes]]) -> bool:
        """Is the recorded batch already in ``store`` (the current leader)?
        Ships are byte-identical contiguous prefixes of the old leader's
        log, so the batch is present iff its *last* record made it — the
        first is checked too so an unrelated write that happens to occupy
        those offsets (the old leader never shipped; other producers' later
        appends reused them) isn't mistaken for ours. Content equality at
        both ends is a proxy, not proof (per-record producer metadata in
        the log — Kafka's full protocol — would make it exact); any doubt
        re-appends, erring toward the documented at-least-once."""
        last_off = entry.first_offset + entry.count - 1
        try:
            firsts = store.read(topic, p, entry.first_offset, 1)
            lasts = store.read(topic, p, last_off, 1)
        except Exception:
            return False
        return (bool(firsts) and bool(lasts)
                and firsts[0].offset == entry.first_offset
                and lasts[0].offset == last_off
                and (firsts[0].key, firsts[0].value) == tuple(records[0])
                and (lasts[0].key, lasts[0].value) == tuple(records[-1]))

    def _append_partition(self, topic: str, p: int,
                          records: Sequence[tuple[bytes, bytes]],
                          producer_id: str | None = None,
                          base_seq: int | None = None) -> int:
        """Append one partition's batch through its leader, fence, ship.
        Returns the first assigned offset."""
        rset = self._rset(topic, p)
        if producer_id is not None and base_seq is None:
            raise ValueError("idempotent appends need a base_seq")
        while True:
            leader, epoch = rset.snapshot()
            if producer_id is not None:
                verdict, entry = self._dedup.classify(
                    topic, p, producer_id, base_seq, len(records))
                # a fenced retry (or a caller-level resend): skip the
                # re-append iff the batch already reached the current
                # leader — a racing lazy ship can have copied it over
                # before the old leader was fenced
                if verdict == "retry" and self._batch_present(
                        self._stores[leader], topic, p, entry, records):
                    return entry.first_offset
            try:
                faults.fire("replica.leader", topic=topic, partition=p,
                            replica=leader, epoch=epoch)
                first = self._stores[leader].append_batch(
                    topic, records, partition=p)[0][1]
            except (KeyError, TypeError, ValueError):
                # a killed store raises these too (cleared topic table /
                # closed file handles) — but from a LIVE store they are the
                # caller's bug (unknown topic, non-bytes records) and must
                # not demote healthy replicas one by one
                if leader not in self._dead:
                    raise
                self._demote(rset, leader, epoch)
                continue
            except Exception:
                # the leader store failed (disk death / injected fault):
                # demote it and retry on the promoted follower
                self._demote(rset, leader, epoch)
                continue
            # the zombie window: the store write is durable on `leader` but
            # the epoch has not been re-validated yet — a leadership change
            # in exactly this gap is what fencing (and idempotent-producer
            # dedup) exists for; the armed callable gets to cause one
            faults.fire("replica.fence", topic=topic, partition=p,
                        replica=leader, epoch=epoch)
            if producer_id is not None:
                self._dedup.record(topic, p, producer_id, base_seq,
                                   len(records), first)
            try:
                self._replicate(rset, topic, p, leader, epoch,
                                lazy=self.acks == "leader")
            except StaleEpoch:
                # fenced: leadership changed while we wrote — the write may
                # sit on a demoted zombie; re-append on the current leader
                # (a duplicate on the zombie's disk is the at-least-once
                # price; it is discarded when that replica resyncs)
                continue
            return first

    def _leader_call(self, topic: str, p: int, fn):
        """Run a read-side store call against the current leader, demoting
        and retrying on store failure (epoch-fenced like the write path). A
        ``KeyError`` from a *live* store means the topic genuinely doesn't
        exist and propagates; from a killed store (its topic table is
        cleared on close) it is a replica failure like any other."""
        rset = self._rset(topic, p)
        while True:
            leader, epoch = rset.snapshot()
            try:
                return fn(self._stores[leader])
            except (KeyError, TypeError, ValueError):
                # same guard as the write path: a killed store raises these
                # (cleared topic table / closed fds), but from a LIVE store
                # they are the caller's bug and must not demote healthy
                # replicas one by one until the set is empty
                if leader not in self._dead:
                    raise
                self._demote(rset, leader, epoch)
            except Exception:
                self._demote(rset, leader, epoch)

    def _alive_stores(self) -> list[PartitionedLog]:
        with self._admin_lock:
            return [s for i, s in enumerate(self._stores)
                    if i not in self._dead]

    # -- LogStore: topic admin ------------------------------------------------
    def create_topic(self, topic: str, partitions: int = 1) -> None:
        if self._single is not None:
            return self._single.create_topic(topic, partitions)
        for store in self._alive_stores():
            store.create_topic(topic, partitions)

    def topics(self) -> list[str]:
        if self._single is not None:
            return self._single.topics()
        out: set[str] = set()
        for store in self._alive_stores():
            out.update(store.topics())
        return sorted(out)

    def num_partitions(self, topic: str) -> int:
        if self._single is not None:
            return self._single.num_partitions(topic)
        for store in self._alive_stores():
            try:
                return store.num_partitions(topic)
            except KeyError:
                continue
        raise KeyError(f"unknown topic {topic!r}")

    # -- LogStore: producer ---------------------------------------------------
    def append(self, topic: str, key: bytes, value: bytes,
               partition: int | None = None) -> tuple[int, int]:
        if self._single is not None:
            return self._single.append(topic, key, value, partition)
        if partition is None:
            partition = route_partition(key, self.num_partitions(topic))
        off = self._append_partition(topic, partition, [(key, value)])
        return partition, off

    def append_batch(self, topic: str,
                     records: Sequence[tuple[bytes, bytes]],
                     partition: int | None = None, *,
                     producer_id: str | None = None,
                     base_seq: int | None = None
                     ) -> list[tuple[int, int]]:
        if self._single is not None:
            return self._single.append_batch(topic, records, partition,
                                             producer_id=producer_id,
                                             base_seq=base_seq)
        if not records:
            return []
        if producer_id is not None and partition is None:
            raise ValueError("idempotent appends require an explicit "
                             "partition (the producer resolves routing)")
        if partition is not None:
            first = self._append_partition(topic, partition, records,
                                           producer_id, base_seq)
            return [(partition, first + i) for i in range(len(records))]
        nparts = self.num_partitions(topic)
        groups: dict[int, list[tuple[bytes, bytes]]] = {}
        indices: dict[int, list[int]] = {}
        for i, rec in enumerate(records):
            p = route_partition(rec[0], nparts)
            groups.setdefault(p, []).append(rec)
            indices.setdefault(p, []).append(i)
        out: list[tuple[int, int] | None] = [None] * len(records)
        for p, recs in groups.items():
            first = self._append_partition(topic, p, recs)
            for j, i in enumerate(indices[p]):
                out[i] = (p, first + j)
        return out  # type: ignore[return-value]

    def flush(self, fsync: bool = True) -> None:
        if self._single is not None:
            return self._single.flush(fsync)
        for topic in self.topics():
            self._catch_up_topic(topic)
        for store in self._alive_stores():
            store.flush(fsync)

    def flush_topic(self, topic: str, fsync: bool = True) -> None:
        if self._single is not None:
            return self._single.flush_topic(topic, fsync)
        self._catch_up_topic(topic)
        for store in self._alive_stores():
            try:
                store.flush_topic(topic, fsync)
            except KeyError:
                continue

    def _catch_up_topic(self, topic: str) -> None:
        """Ship every follower fully (quiesce point: flush/close/rejoin —
        the lazy acks=leader lag is paid down here)."""
        for p in range(self.num_partitions(topic)):
            rset = self._rset(topic, p)
            # each StaleEpoch implies a leadership change, which at most
            # n_replicas failures can cause — the retry loop terminates
            for _ in range(self.n_replicas + 1):
                try:
                    leader, epoch = rset.snapshot()
                    self._replicate(rset, topic, p, leader, epoch,
                                    lazy=False)
                    break
                except StaleEpoch:
                    continue
                except ReplicationError:
                    break       # no in-sync replica left: nothing to ship

    # -- LogStore: consumer ---------------------------------------------------
    def read(self, topic: str, partition: int, offset: int,
             max_records: int = 512) -> list[LogRecord]:
        if self._single is not None:
            return self._single.read(topic, partition, offset, max_records)
        return self._leader_call(
            topic, partition,
            lambda s: s.read(topic, partition, offset, max_records))

    def begin_offset(self, topic: str, partition: int) -> int:
        if self._single is not None:
            return self._single.begin_offset(topic, partition)
        return self._leader_call(topic, partition,
                                 lambda s: s.begin_offset(topic, partition))

    def end_offset(self, topic: str, partition: int) -> int:
        if self._single is not None:
            return self._single.end_offset(topic, partition)
        return self._leader_call(topic, partition,
                                 lambda s: s.end_offset(topic, partition))

    # -- LogStore: retention --------------------------------------------------
    def enforce_retention(self, topic: str, retention_bytes: int) -> int:
        if self._single is not None:
            return self._single.enforce_retention(topic, retention_bytes)
        dropped = 0
        for store in self._alive_stores():
            dropped = max(dropped,
                          store.enforce_retention(topic, retention_bytes))
        return dropped

    def drop_segments_below(self, topic: str, partition: int,
                            offset: int) -> int:
        if self._single is not None:
            return self._single.drop_segments_below(topic, partition, offset)
        dropped = 0
        for store in self._alive_stores():
            dropped = max(dropped,
                          store.drop_segments_below(topic, partition, offset))
        return dropped

    def close(self) -> None:
        if self._single is not None:
            return self._single.close()
        try:
            for topic in self.topics():
                self._catch_up_topic(topic)
            self._write_meta(clean=True)    # replicas converged: clean mark
        finally:
            for store in self._alive_stores():
                store.close()

    # -- replica administration (failure detector / operator API) -------------
    def kill_replica(self, replica: int) -> None:
        """Declare a replica lost: drop it from every partition's in-sync
        set (bumping epochs — promoting followers where it led) and close
        its store. In-flight writers fence on their next epoch check."""
        if not 0 <= replica < self.n_replicas:
            raise ValueError(f"no replica {replica}")
        with self._admin_lock:
            if replica in self._dead:
                return
            if len(self._dead) + 1 >= self.n_replicas:
                raise ReplicationError("cannot kill the last alive replica")
            self._dead.add(replica)
            rsets = list(self._sets.values())
        changed = False
        for rset in rsets:
            changed |= rset.remove(replica)
        if changed:
            self._write_meta(clean=False)
        self._stores[replica].close()

    def restore_replica(self, replica: int) -> None:
        """Bring a killed replica back: wipe its directory, rebuild every
        partition from the current leaders (full resync — after an unclean
        history its old content may diverge), then rejoin the in-sync
        sets."""
        if replica not in self._dead:
            raise ReplicationError(f"replica {replica} is not dead")
        path = self.root / f"replica-{replica}"
        shutil.rmtree(path, ignore_errors=True)
        store = PartitionedLog(path, self._stores[replica].segment_bytes,
                               self._stores[replica].fsync_every)
        self._stores[replica] = store
        for topic in self.topics():
            nparts = self.num_partitions(topic)
            store.create_topic(topic, nparts)
            for p in range(nparts):
                rset = self._rset(topic, p)
                leader, _ = rset.snapshot()
                self._resync_partition(rset, topic, p, leader, replica)
        with self._admin_lock:
            self._dead.discard(replica)
        changed = False
        for rset in self._sets.values():
            changed |= rset.add(replica)
        if changed:
            self._write_meta(clean=False)
        # close the resync→rejoin gap: appends that raced the resync saw the
        # replica outside the in-sync set and skipped it; one more catch-up
        # ship restores the acks=all invariant (now that it IS in-sync, new
        # appends ship to it synchronously)
        for topic in self.topics():
            self._catch_up_topic(topic)

    # -- observability --------------------------------------------------------
    def describe(self, topic: str) -> list[dict]:
        """Per-partition replica-set status (leader, epoch, in-sync set,
        per-replica end offsets) — the status-history view for replication."""
        out = []
        for p in range(self.num_partitions(topic)):
            if self._single is not None:
                out.append({"partition": p, "leader": 0, "epoch": 0,
                            "in_sync": [0],
                            "ends": [self._single.end_offset(topic, p)]})
                continue
            rset = self._rset(topic, p)
            with rset.lock:
                leader = rset.leader
                epoch = rset.epoch
                alive = sorted(rset.alive)
            ends = []
            for i, s in enumerate(self._stores):
                try:
                    ends.append(s.end_offset(topic, p)
                                if i not in self._dead else None)
                except KeyError:
                    ends.append(None)
            out.append({"partition": p, "leader": leader, "epoch": epoch,
                        "in_sync": alive, "ends": ends})
        return out

    def leader(self, topic: str, partition: int) -> int:
        if self._single is not None:
            return 0
        leader, _ = self._rset(topic, partition).snapshot()
        return leader

    def epoch(self, topic: str, partition: int) -> int:
        if self._single is not None:
            return 0
        _, epoch = self._rset(topic, partition).snapshot()
        return epoch
