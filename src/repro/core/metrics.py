"""Component statistics — NiFi's status-history view (paper §IV.C:
"number of bytes read, written, in, and out in 5 minutes")."""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field


@dataclass
class ComponentStats:
    name: str
    in_records: int = 0
    in_bytes: int = 0
    out_records: int = 0
    out_bytes: int = 0
    dropped: int = 0
    # fault-tolerance counters (supervisor / retry / dead-letter paths)
    restarts: int = 0
    retries: int = 0
    dead_lettered: int = 0
    # acquisition gauges/counters (live connectors; see core/acquisition.py).
    # ``lag`` is records the endpoint still holds beyond our cursor (None
    # when the endpoint cannot say); ``watermark`` is the connector's current
    # event-time watermark (None before the first record).
    reconnects: int = 0
    late_records: int = 0
    duplicates: int = 0
    lag: int | None = None
    watermark: float | None = None

    def snapshot(self) -> dict:
        return {
            "name": self.name,
            "in_records": self.in_records, "in_bytes": self.in_bytes,
            "out_records": self.out_records, "out_bytes": self.out_bytes,
            "dropped": self.dropped,
            "restarts": self.restarts, "retries": self.retries,
            "dead_lettered": self.dead_lettered,
            "reconnects": self.reconnects, "late_records": self.late_records,
            "duplicates": self.duplicates,
            "lag": self.lag, "watermark": self.watermark,
        }


class WindowedCounter:
    """Rolling-window rate counter (default 5-minute window, 1 s buckets)."""

    def __init__(self, window_sec: float = 300.0, bucket_sec: float = 1.0) -> None:
        self.window_sec = window_sec
        self.bucket_sec = bucket_sec
        self._buckets: deque[tuple[int, float]] = deque()
        self._lock = threading.Lock()

    def add(self, n: float = 1.0) -> None:
        now = time.monotonic()
        bucket = int(now / self.bucket_sec)
        with self._lock:
            if self._buckets and self._buckets[-1][0] == bucket:
                b, v = self._buckets[-1]
                self._buckets[-1] = (b, v + n)
            else:
                self._buckets.append((bucket, n))
            self._evict(now)

    def _evict(self, now: float) -> None:
        horizon = int((now - self.window_sec) / self.bucket_sec)
        while self._buckets and self._buckets[0][0] < horizon:
            self._buckets.popleft()

    def total(self) -> float:
        with self._lock:
            self._evict(time.monotonic())
            return sum(v for _, v in self._buckets)

    def rate_per_sec(self) -> float:
        with self._lock:
            now = time.monotonic()
            self._evict(now)
            if not self._buckets:
                return 0.0
            span = max(self.bucket_sec,
                       (self._buckets[-1][0] - self._buckets[0][0] + 1)
                       * self.bucket_sec)
            return sum(v for _, v in self._buckets) / span
