"""Component statistics — NiFi's status-history view (paper §IV.C:
"number of bytes read, written, in, and out in 5 minutes").

``ComponentStats`` is mutated from several threads at once (the node's
worker pool, acquisition poll loops, the supervisor) — all updates go
through the locked :meth:`ComponentStats.add` / :meth:`ComponentStats.set`
helpers so counters never lose increments and :meth:`snapshot` returns one
consistent view (no torn in/out pairs). Direct attribute reads stay cheap
and are fine for monotone single-writer gauges.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field, fields
from typing import Callable, Optional


@dataclass
class ComponentStats:
    name: str
    in_records: int = 0
    in_bytes: int = 0
    out_records: int = 0
    out_bytes: int = 0
    dropped: int = 0
    # fault-tolerance counters (supervisor / retry / dead-letter paths)
    restarts: int = 0
    retries: int = 0
    dead_lettered: int = 0
    # acquisition gauges/counters (live connectors; see core/acquisition.py).
    # ``lag`` is records the endpoint still holds beyond our cursor (None
    # when the endpoint cannot say); ``watermark`` is the connector's current
    # event-time watermark (None before the first record).
    reconnects: int = 0
    late_records: int = 0
    duplicates: int = 0
    lag: int | None = None
    watermark: float | None = None
    # congestion-response counters (ConnectorPolicy.congestion_mode):
    # records dropped by priority-aware load shedding, records diverted to /
    # replayed from the durable spill topic, poll-throttle engagements,
    # catch-up boosts (throttle released below the base interval because the
    # endpoint's own lag is deep), and spill segments reclaimed by GC
    shed: int = 0
    spilled: int = 0
    spill_replayed: int = 0
    throttle_engagements: int = 0
    throttle_boosts: int = 0
    spill_gc: int = 0
    # elastic worker-pool gauges (flow engine; see core/processor.py)
    workers: int = 1
    scale_ups: int = 0
    scale_downs: int = 0
    _lock: threading.Lock = field(default_factory=threading.Lock,
                                  init=False, repr=False, compare=False)

    def add(self, **deltas: int) -> None:
        """Atomically increment counters (``+=`` from several threads loses
        updates: the read-modify-write is three bytecodes)."""
        with self._lock:
            for k, v in deltas.items():
                setattr(self, k, getattr(self, k) + v)

    def set(self, **values) -> None:
        """Atomically assign gauges (paired gauges set in one call are seen
        together by ``snapshot()``)."""
        with self._lock:
            for k, v in values.items():
                setattr(self, k, v)

    def snapshot(self) -> dict:
        """One consistent view of every declared field. Derived from
        ``dataclasses.fields()`` so a counter added to the dataclass can
        never silently vanish from ``FlowGraph.status()`` (the hand-written
        literal this replaced had to be edited in lockstep)."""
        with self._lock:
            return {f.name: getattr(self, f.name)
                    for f in fields(self) if f.name != "_lock"}


class WindowedCounter:
    """Rolling-window rate counter (default 5-minute window, 1 s buckets).

    ``clock`` (a zero-arg seconds callable) makes decay/eviction tests
    deterministic — no sleeping against real ``time.monotonic()`` on a
    load-spiky host. When omitted, the monotonic clock is looked up at
    call time, not captured at construction.
    """

    def __init__(self, window_sec: float = 300.0, bucket_sec: float = 1.0,
                 clock: Optional[Callable[[], float]] = None) -> None:
        self.window_sec = window_sec
        self.bucket_sec = bucket_sec
        self._clock = clock
        self._buckets: deque[tuple[int, float]] = deque()
        self._lock = threading.Lock()

    def _now(self) -> float:
        return self._clock() if self._clock is not None else time.monotonic()

    def add(self, n: float = 1.0) -> None:
        now = self._now()
        bucket = int(now / self.bucket_sec)
        with self._lock:
            if self._buckets and self._buckets[-1][0] == bucket:
                b, v = self._buckets[-1]
                self._buckets[-1] = (b, v + n)
            else:
                self._buckets.append((bucket, n))
            self._evict(now)

    def _evict(self, now: float) -> None:
        horizon = int((now - self.window_sec) / self.bucket_sec)
        while self._buckets and self._buckets[0][0] < horizon:
            self._buckets.popleft()

    def total(self) -> float:
        with self._lock:
            self._evict(self._now())
            return sum(v for _, v in self._buckets)

    def rate_per_sec(self) -> float:
        """Observed rate over the elapsed time from the oldest surviving
        bucket to *now* (clamped to ``window_sec``). Dividing by occupied-
        bucket span instead would freeze a burst's peak rate for the whole
        window after the burst ends — the rate must decay as idle time
        accumulates, reaching 0 only when the window fully evicts."""
        with self._lock:
            now = self._now()
            self._evict(now)
            if not self._buckets:
                return 0.0
            span = min(self.window_sec,
                       max(self.bucket_sec,
                           now - self._buckets[0][0] * self.bucket_sec))
            return sum(v for _, v in self._buckets) / span
