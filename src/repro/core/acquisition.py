"""Live acquisition runtime — the "acquire" half of the paper's ingestion
fabric (§III.A): connectors over network-like endpoints, driven by
reconnecting poll loops with checkpointed resume and event-time watermarks.

The paper's case study acquires high-velocity news from live RSS / firehose /
WebSocket endpoints through NiFi source processors (GetHTTP, GetTwitter,
ListenWebSocket). The seed reproduction replaced those with synchronous
in-process generators; this module restores the live layer, following the
shape AsterixDB's data feeds give it (Grover & Carey 2014: an
*adapter/connector* contract plus pluggable *ingestion policies* for
disconnects and congestion):

``SourceConnector`` (paper §III.A "data acquisition", NiFi: a source
processor + its controller service)
    The adapter contract: ``connect(cursor)`` opens a session resuming after
    an opaque *cursor token*, ``poll(n)`` returns the next records (or raises
    :class:`EndOfStream` / a connection error), ``ack(cursor)`` tells the
    endpoint everything up to the token is durably admitted (it may trim its
    redelivery buffer), ``close()`` drops the session.

``ConnectorPolicy`` (AsterixDB: ingestion policy; NiFi: scheduling +
penalization settings)
    What to do when the endpoint misbehaves: reconnect backoff reuses the
    supervisor's :class:`~repro.core.processor.RestartPolicy` machinery,
    plus poll sizing, checkpoint cadence, and the bounded-out-of-orderness
    ``lateness_sec`` for the connector's watermark.

``AcquisitionRuntime`` (NiFi: the flow controller scheduling source
processors)
    Drives N connectors on concurrent poll loops. Each loop: ensure
    connected (exponential backoff per policy; fault site
    ``acquire.connect``), poll (site ``acquire.poll``), split the batch
    against the connector's event-time watermark, and admit it into the
    destination ``FlowGraph`` queue via ``offer_batch`` — blocking there IS
    backpressure (NiFi: "source no longer scheduled"), felt by the endpoint
    as a slow client. Late records are routed to a dedicated late
    destination (NiFi: a ``late`` relationship) instead of silently merged;
    with no late destination wired they are stamped ``wm.late=1`` and
    admitted in-band. After a batch is fully admitted the connector's cursor
    is acked and periodically *checkpointed* through the existing
    ``LogStore`` (topic ``__acq__.<name>``), so a crashed process reopens
    the same store and resumes every connector from its last checkpointed
    cursor — at-least-once: records admitted since the last checkpoint (and
    the endpoint's reconnect redelivery window) may be re-acquired, loss may
    not. Pair with ``FlowGraph.add_ingress(..., durable=log)`` to make
    admission itself crash-durable end to end.

``SimulatedEndpoint``
    A deterministic network-like endpoint wrapping the replayable generators
    in ``sources.py``, so the whole runtime is testable without sockets:
    disconnects and stalls are injected via the ``acquire.*`` fault sites,
    reconnects redeliver a bounded already-delivered suffix (at-least-once
    endpoints), and a seeded block permutation emits bounded out-of-order
    bursts with deterministic per-record event times. The *wire-real*
    counterparts — an HTTP/RSS cursor-feed long-poller and an RFC 6455
    WebSocket client speaking the same connector contract over real
    sockets — live in ``net_connectors.py`` and are driven by this runtime
    unchanged.

Watermarks aggregate across connectors into the fabric-wide low watermark
(``core/watermark.py``); per-connector lag, watermark, reconnects, late and
duplicate counts surface as gauges in ``ComponentStats`` via
``FlowGraph.status()["acquisition"]``.
"""
from __future__ import annotations

import abc
import itertools
import json
import random
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Iterator, Optional, TYPE_CHECKING

from . import faults
from .flowfile import FlowFile
from .metrics import ComponentStats
from .processor import Processor, RestartPolicy
from .watermark import LowWatermarkClock, WatermarkTracker

if TYPE_CHECKING:
    from .connection import Connection
    from .flow import FlowGraph, IngressHandle
    from .logstore import LogStore

__all__ = ["AcquisitionError", "AcquisitionRuntime", "CONGESTION_MODES",
           "ConnectorError", "ConnectorPolicy", "EndOfStream",
           "SimulatedEndpoint", "SourceConnector", "default_event_ts",
           "emission_order"]


class ConnectorError(RuntimeError):
    """Transient acquisition failure — the session is considered dropped and
    the runtime reconnects with backoff."""


class EndOfStream(Exception):
    """Raised by ``poll`` when the stream is exhausted (finite endpoints)."""


class AcquisitionError(RuntimeError):
    """A connector exhausted its reconnect budget (or crashed)."""


class SourceConnector(abc.ABC):
    """Adapter contract between one external endpoint and the runtime.

    Cursor tokens are opaque strings owned by the connector; the runtime
    only stores and replays them. The contract is at-least-once: after
    ``connect(cursor)`` the connector must deliver every record *after*
    ``cursor`` at least once (it may redeliver earlier ones)."""

    name: str

    @abc.abstractmethod
    def connect(self, cursor: str | None) -> None:
        """Open a session resuming after ``cursor`` (None = the beginning)."""

    @abc.abstractmethod
    def poll(self, max_records: int) -> list[FlowFile]:
        """Return up to ``max_records`` new records ([] = nothing right
        now). Raises :class:`EndOfStream` when the stream is complete, any
        other exception on connection failure."""

    @abc.abstractmethod
    def cursor(self) -> str | None:
        """Resume token covering every record returned by ``poll`` so far."""

    @abc.abstractmethod
    def ack(self, cursor: str) -> None:
        """All records up to ``cursor`` are durably admitted downstream."""

    @abc.abstractmethod
    def close(self) -> None: ...

    # -- optional observability ------------------------------------------------
    def lag(self) -> int | None:
        """Records the endpoint still holds beyond our cursor, if it can
        say (None = unknown)."""
        return None

    def redelivered(self) -> int:
        """Cumulative count of records re-delivered by reconnects."""
        return 0


#: Congestion responses a connector may choose (ConnectorPolicy). ``block``
#: is the seed behavior: a full downstream queue stalls the poll loop.
CONGESTION_MODES = ("block", "throttle", "shed", "spill")


@dataclass(frozen=True)
class ConnectorPolicy:
    """Per-connector ingestion policy (AsterixDB's term): how hard to try to
    stay connected, how much to pull per poll, how often to checkpoint the
    resume cursor, the watermark's out-of-orderness bound — and what to do
    when the downstream queue congests (``congestion_mode``):

    * ``block`` — blocking admission; backpressure stalls the poll loop
      (correct, but a 10× burst is indistinguishable from a hang).
    * ``throttle`` — adaptive poll-interval backoff: the effective interval
      doubles (capped at ``throttle_max_interval_sec``) while downstream
      depth sits at/above ``congestion_high_water`` of its thresholds, and
      halves back once it falls to ``congestion_low_water``. Release is
      lag-aware: when the connector's own endpoint ``lag()`` is at least
      ``throttle_catchup_lag`` records and depth is below the low-water
      mark, the interval snaps to ``throttle_catchup_interval_sec``
      (*faster than base*) instead of decaying toward the base interval —
      a connector that fell behind while throttled catches up at full
      tilt the moment downstream has headroom.
    * ``shed`` — priority-aware load shedding: past the high-water depth,
      records whose priority class buys no headroom are dropped with a
      ``shed`` counter and a ``congestion.shed`` DROP provenance event.
      A record of priority ``p`` survives until depth reaches
      ``min(1, congestion_high_water + p * shed_headroom_per_priority)`` —
      the lowest class sheds first.
    * ``spill`` — divert the overflow to a durable side topic
      (``__spill__.<runtime>.<connector>`` in the runtime's LogStore) and
      re-ingest it from a drain loop once depth recovers below the
      low-water mark; nothing is lost, order is deferred.
    """

    restart: RestartPolicy = RestartPolicy(
        max_restarts=16, backoff_base_sec=0.01, backoff_cap_sec=0.5)
    max_poll_records: int = 256
    poll_interval_sec: float = 0.002
    checkpoint_every_records: int = 512
    lateness_sec: float = 30.0
    congestion_mode: str = "block"
    #: downstream depth (fraction of either threshold) where the congestion
    #: response engages / releases
    congestion_high_water: float = 0.75
    congestion_low_water: float = 0.5
    throttle_max_interval_sec: float = 0.5
    #: endpoint lag (records behind) at which a released throttle boosts to
    #: catch-up polling instead of decaying to base (None disables)
    throttle_catchup_lag: int | None = 1024
    #: poll interval while catching up (0.0 = poll flat-out)
    throttle_catchup_interval_sec: float = 0.0
    #: extra depth headroom each priority class buys before being shed
    shed_headroom_per_priority: float = 0.10

    def __post_init__(self) -> None:
        if self.congestion_mode not in CONGESTION_MODES:
            raise ValueError(
                f"congestion_mode must be one of {CONGESTION_MODES}, "
                f"got {self.congestion_mode!r}")
        if not 0.0 < self.congestion_low_water <= self.congestion_high_water:
            raise ValueError("need 0 < congestion_low_water <= "
                             "congestion_high_water")
        if self.throttle_catchup_lag is not None \
                and self.throttle_catchup_lag <= 0:
            raise ValueError("throttle_catchup_lag must be positive or None")
        if self.throttle_catchup_interval_sec < 0:
            raise ValueError("throttle_catchup_interval_sec must be >= 0")


def default_event_ts(ff: FlowFile) -> float:
    """Event time of a record: the ``event.ts`` attribute (stamped by
    :class:`SimulatedEndpoint`), falling back to fabric entry time."""
    ts = ff.attributes.get("event.ts")
    return float(ts) if ts is not None else ff.entry_ts


def emission_order(generator_fn: Callable[[], Iterator[FlowFile]],
                   start: int = 0, *, ooo_window: int = 0,
                   seed: int = 0) -> Iterator[tuple[int, FlowFile]]:
    """The canonical endpoint emission order: yield ``(canonical_index,
    record)`` pairs from a replayable generator, starting at *emission*
    index ``start``, with blocks of ``ooo_window`` records deterministically
    permuted (seeded per block) to model bounded out-of-order delivery.

    This is the deterministic stream behind every test endpoint —
    :class:`SimulatedEndpoint` stamps event times on it in-process, and the
    localhost HTTP/WebSocket feed servers (``tests/net_fixtures.py``) serve
    the very same order over real sockets, so wire-real connectors are
    checked against byte-identical expectations."""
    it = generator_fn()
    w = max(1, ooo_window)
    block_idx, skip = divmod(start, w)
    if block_idx:            # fast-forward whole blocks (replayable gen)
        n = block_idx * w
        next(itertools.islice(it, n, n), None)
    while True:
        block = list(itertools.islice(it, w))
        if not block:
            return
        order = list(range(len(block)))
        if w > 1 and len(block) > 1:
            # permutation depends only on (seed, block index, length):
            # a resumed session re-derives the identical emission order
            random.Random(seed * 1_000_003 + block_idx).shuffle(order)
        for j in order[skip:]:
            yield block_idx * w + j, block[j]
        skip = 0
        block_idx += 1


# ---------------------------------------------------------------------------
# Deterministic simulated endpoint
# ---------------------------------------------------------------------------
class SimulatedEndpoint(SourceConnector):
    """A network-like endpoint over a replayable generator factory.

    * **Cursor** — the emission index (count of records delivered in
      emission order), encoded as a decimal string.
    * **Redelivery** — ``connect(cursor)`` resumes up to ``redelivery``
      records *before* the cursor (never before the server-side acked
      index), modelling an at-least-once endpoint that re-sends its unacked
      tail on reconnect. ``ack`` advances the server-side index.
    * **Out-of-order bursts** — with ``ooo_window >= 2`` the canonical
      stream is emitted in blocks of that size, each block deterministically
      permuted (seeded per block index), so event-time disorder is bounded
      by ``(ooo_window - 1) * ts_step``.
    * **Event time** — every record is stamped with an ``event.ts``
      attribute derived from its *canonical* stream index
      (``base_ts + index * ts_step``), so disorder and lateness are exact.

    Disconnects and stalls are injected from outside via the runtime's
    ``acquire.connect`` / ``acquire.poll`` fault sites — the endpoint itself
    stays deterministic.
    """

    def __init__(self, name: str,
                 generator_fn: Callable[[], Iterator[FlowFile]], *,
                 total: int | None = None,
                 base_ts: float = 1_534_660_000.0, ts_step: float = 1.0,
                 ooo_window: int = 0, ooo_seed: int = 0,
                 redelivery: int = 0) -> None:
        if ooo_window < 0 or redelivery < 0:
            raise ValueError("ooo_window/redelivery must be non-negative")
        self.name = name
        self._generator_fn = generator_fn
        self.total = total
        self.base_ts = base_ts
        self.ts_step = ts_step
        self.ooo_window = ooo_window
        self.ooo_seed = ooo_seed
        self.redelivery = redelivery
        self._session: Iterator[FlowFile] | None = None
        self._pos = 0            # emission index of the next record
        self._acked = 0          # server-side acked emission index
        self.redelivered_total = 0
        self.connects = 0

    # -- emission order ------------------------------------------------------
    def _emission_iter(self, start: int) -> Iterator[FlowFile]:
        for idx, ff in emission_order(self._generator_fn, start,
                                      ooo_window=self.ooo_window,
                                      seed=self.ooo_seed):
            yield ff.with_attributes(**{
                "event.ts": f"{self.base_ts + idx * self.ts_step:.6f}"})

    # -- SourceConnector -----------------------------------------------------
    def connect(self, cursor: str | None) -> None:
        k = int(cursor) if cursor else 0
        start = max(self._acked, k - self.redelivery) if k else 0
        start = min(start, k)
        self.redelivered_total += k - start
        self._session = self._emission_iter(start)
        self._pos = start
        self.connects += 1

    def poll(self, max_records: int) -> list[FlowFile]:
        if self._session is None:
            raise ConnectorError(f"{self.name}: not connected")
        out = list(itertools.islice(self._session, max_records))
        if not out:
            self._session = None
            raise EndOfStream(self.name)
        self._pos += len(out)
        return out

    def cursor(self) -> str | None:
        return str(self._pos)

    def ack(self, cursor: str) -> None:
        self._acked = max(self._acked, min(int(cursor), self._pos))

    def close(self) -> None:
        self._session = None

    def lag(self) -> int | None:
        return max(0, self.total - self._pos) if self.total is not None \
            else None

    def redelivered(self) -> int:
        return self.redelivered_total


# ---------------------------------------------------------------------------
# The runtime
# ---------------------------------------------------------------------------
@dataclass
class _ConnectorEntry:
    connector: SourceConnector
    policy: ConnectorPolicy
    dest: "IngressHandle"
    late_dest: "IngressHandle | None"
    tracker: WatermarkTracker
    event_ts_fn: Callable[[FlowFile], float]
    stats: ComponentStats
    cursor: str | None = None
    #: last payload the entry's OWN thread checkpointed (compaction rewrites
    #: this instead of re-reading live cursor/watermark state, which another
    #: thread could catch mid-update — a stale cursor paired with a newer
    #: watermark would mis-flag the resumed suffix as late)
    ckpt_payload: bytes | None = None
    since_ckpt: int = 0
    state: str = "PENDING"   # CONNECTED|RECONNECTING|COMPLETED|STOPPED|FAILED
    error: BaseException | None = None
    ever_connected: bool = False
    thread: threading.Thread | None = field(default=None, repr=False)
    # -- congestion state (ConnectorPolicy.congestion_mode) -------------------
    #: current adaptive poll interval (throttle mode; == policy interval
    #: while not engaged)
    throttle_interval: float = 0.0
    #: durable side topic for spill mode (None otherwise)
    spill_topic: str | None = None
    #: offset of the next spilled record to re-ingest (checkpointed)
    spill_drained: int = 0
    #: ``spill_drained`` as of the last durable checkpoint — spill segments
    #: wholly below this frontier can never be re-read (a crash-restart
    #: resumes the drain from the checkpoint), so the drain loop GCs them
    ckpt_spill_drained: int = 0
    #: highest frontier already handed to ``drop_segments_below`` (avoids
    #: re-issuing the GC RPC every drain pass)
    spill_gc_below: int = 0
    # -- telemetry (set by add_connector when the flow carries a registry) ----
    #: endpoint poll latency histogram (one sample per poll RPC)
    poll_hist: object = field(default=None, repr=False)
    #: endpoint ack latency histogram (one sample per cursor ack)
    ack_hist: object = field(default=None, repr=False)


class AcquisitionRuntime:
    """Drives N :class:`SourceConnector`\\ s into a :class:`FlowGraph`.

    Construction attaches the runtime to the flow (``flow.acquisition``) so
    ``flow.status()`` surfaces per-connector stats. Passing a ``log`` enables
    cursor checkpointing (topic ``__acq__.<name>``): a runtime rebuilt over
    the same store resumes every connector from its last checkpointed cursor
    with its watermark seeded from the checkpoint (so watermarks never
    regress across a crash)."""

    #: checkpoint appends between compaction sweeps (rewrite the newest
    #: cursor of every connector, then GC dead sealed segments)
    _COMPACT_EVERY = 64

    def __init__(self, flow: "FlowGraph", log: "Optional[LogStore]" = None,
                 *, name: str = "acq", checkpoint_fsync: bool = False) -> None:
        self.flow = flow
        flow.acquisition = self
        self.name = name
        self.log = log
        self.checkpoint_topic = f"__acq__.{name}"
        self.checkpoint_fsync = checkpoint_fsync
        self.clock = LowWatermarkClock()
        self._entries: dict[str, _ConnectorEntry] = {}
        self._stopping = threading.Event()
        self._abort = False
        self._started = False
        self._ckpt_lock = threading.Lock()
        self._ckpt_appends = 0
        self._saved: dict[str, dict] = {}
        if flow.telemetry is not None:
            flow.telemetry.register_source(
                "connector", lambda: self.status()["connectors"])
        if log is not None:
            log.create_topic(self.checkpoint_topic, partitions=1)
            for r in log.iter_records(self.checkpoint_topic, 0):
                self._saved[r.key.decode()] = json.loads(r.value)

    # -- assembly -------------------------------------------------------------
    def add_connector(self, connector: SourceConnector,
                      dest: "Processor | str", *,
                      policy: ConnectorPolicy | None = None,
                      priority: int = 0,
                      late_dest: "Processor | str | None" = None,
                      event_ts_fn: Callable[[FlowFile], float] | None = None,
                      object_threshold: int | None = None,
                      max_retries: int | None = None,
                      durable: "Optional[LogStore]" = None) -> None:
        """Register ``connector`` to feed ``dest``'s input queue. Queue
        kwargs apply when this ingress creates the connection (fan-in joins
        the existing one). ``late_dest`` receives records behind the
        connector's watermark; without it they are stamped ``wm.late`` and
        admitted in-band. ``priority`` is the connector's admission priority
        class (``FlowGraph.add_ingress(priority=)``): stamped on every
        admitted record, honored by the queue's prioritizer and by shed-mode
        congestion (higher classes shed last)."""
        name = connector.name
        if name in self._entries:
            raise ValueError(f"connector {name!r} already added")
        if self._started:
            raise RuntimeError("add_connector() after start()")
        pol = policy or ConnectorPolicy()
        if pol.congestion_mode == "spill" and self.log is None:
            raise ValueError(
                f"connector {name!r}: congestion_mode='spill' needs the "
                "runtime constructed with a LogStore (the spill topic is "
                "durable by contract)")
        handle = self.flow.add_ingress(
            dest, name=f"{name}-ingress", priority=priority,
            object_threshold=object_threshold,
            max_retries=max_retries, durable=durable)
        late_handle = None
        if late_dest is not None:
            late_handle = self.flow.add_ingress(
                late_dest, name=f"{name}-late-ingress", durable=durable)
        saved = self._saved.get(name, {})
        tracker = self.clock.register(name, lateness=pol.lateness_sec,
                                      initial=saved.get("watermark"))
        spill_topic = None
        if pol.congestion_mode == "spill":
            spill_topic = f"__spill__.{self.name}.{name}"
            self.log.create_topic(spill_topic, partitions=1)
        poll_hist = ack_hist = None
        if self.flow.telemetry is not None:
            poll_hist = self.flow.telemetry.histogram(
                "acquire_poll_seconds", connector=name)
            ack_hist = self.flow.telemetry.histogram(
                "acquire_ack_seconds", connector=name)
        self._entries[name] = _ConnectorEntry(
            connector=connector, policy=pol, dest=handle,
            late_dest=late_handle, tracker=tracker,
            event_ts_fn=event_ts_fn or default_event_ts,
            stats=ComponentStats(name), cursor=saved.get("cursor"),
            # until this incarnation checkpoints, compaction carries the
            # resumed state forward verbatim
            ckpt_payload=json.dumps(saved).encode() if saved else None,
            throttle_interval=pol.poll_interval_sec,
            poll_hist=poll_hist, ack_hist=ack_hist,
            spill_topic=spill_topic,
            spill_drained=int(saved.get("spill_drained", 0)),
            ckpt_spill_drained=int(saved.get("spill_drained", 0)))

    # -- lifecycle ------------------------------------------------------------
    def start(self) -> None:
        if self._started:
            raise RuntimeError("runtime already started")
        self._started = True
        for e in self._entries.values():
            t = threading.Thread(target=self._drive, args=(e,),
                                 name=f"acq-{e.connector.name}", daemon=True)
            e.thread = t
            t.start()

    def join(self, timeout: float | None = None,
             raise_errors: bool = True) -> None:
        """Wait for every poll loop to finish. Ingress handles are completed
        by each loop on its way out, so a subsequent ``flow.join()`` drains
        and terminates. Raises :class:`AcquisitionError` when any connector
        ended ``FAILED`` (after all loops are accounted for)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        for e in self._entries.values():
            if e.thread is None:
                continue
            remaining = None
            if deadline is not None:
                remaining = max(0.0, deadline - time.monotonic())
            e.thread.join(remaining)
        if raise_errors:
            failed = {n: e.error for n, e in self._entries.items()
                      if e.state == "FAILED"}
            if failed:
                raise AcquisitionError(
                    f"connectors failed: {failed}") from next(
                        iter(failed.values()))

    def stop(self, abort: bool = False) -> None:
        """Stop acquiring. Graceful (default): loops checkpoint their final
        cursor and complete their ingress handles so the flow can drain what
        was admitted. ``abort=True`` simulates a crash: no final checkpoint,
        no handle completion — only a rebuild over the same store resumes."""
        self._abort = abort or self._abort
        self._stopping.set()
        self.join(timeout=10.0, raise_errors=False)

    def run_with_flow(self, timeout: float = 300.0) -> None:
        """Start the flow and the runtime, wait for acquisition to finish,
        then for the graph to drain — the live analogue of
        ``FlowGraph.run_to_completion``, including its contract that an
        incomplete run raises instead of returning partial results."""
        self.flow.start()
        self.start()
        self.join(timeout=timeout, raise_errors=False)
        if self.running():
            stuck = sorted(n for n, e in self._entries.items()
                           if e.thread is not None and e.thread.is_alive())
            self._stopping.set()
            self.flow.stopping.set()
            raise AcquisitionError(
                f"acquisition did not complete within {timeout}s; "
                f"still polling: {stuck}")
        self.flow.join(timeout=timeout)
        alive = self.flow.alive_workers()
        if alive:
            self.flow.stopping.set()
            raise AcquisitionError(
                f"flow did not drain within {timeout}s; alive: {alive}")
        self.join(timeout=0.0)     # surface connector failures last

    # -- observability --------------------------------------------------------
    def running(self) -> bool:
        """True while any poll loop is still alive."""
        return any(e.thread is not None and e.thread.is_alive()
                   for e in self._entries.values())

    def low_watermark(self) -> float | None:
        return self.clock.current()

    def status(self) -> dict:
        conns = {}
        for n, e in self._entries.items():
            snap = e.stats.snapshot()
            snap["state"] = e.state
            snap["cursor"] = e.cursor
            conns[n] = snap
        return {"connectors": conns,
                "low_watermark": self.clock.current()}

    # -- poll loop ------------------------------------------------------------
    def _drive(self, e: _ConnectorEntry) -> None:
        c, pol = e.connector, e.policy
        failures = 0
        connected = False
        try:
            while not self._stopping.is_set():
                if not connected:
                    try:
                        faults.fire("acquire.connect", connector=c.name,
                                    cursor=e.cursor)
                        c.connect(e.cursor)
                    except Exception as err:
                        failures += 1
                        if not self._backoff(e, failures, err):
                            return
                        continue
                    connected = True
                    e.state = "CONNECTED"
                    if e.ever_connected:
                        e.stats.add(reconnects=1)
                    e.ever_connected = True
                    e.stats.set(duplicates=c.redelivered())
                t_poll = time.perf_counter()
                try:
                    faults.fire("acquire.poll", connector=c.name,
                                cursor=e.cursor)
                    batch = c.poll(pol.max_poll_records)
                except EndOfStream:
                    # the spill topic must drain before the ingress handle
                    # completes, or the overflow would strand durably parked
                    if self._drain_spill(e, full=True):
                        e.state = "COMPLETED"
                    return
                except Exception as err:
                    connected = False
                    e.state = "RECONNECTING"
                    self._close_quietly(c)
                    failures += 1
                    if not self._backoff(e, failures, err):
                        return
                    continue
                failures = 0
                if e.poll_hist is not None:     # one sample per poll RPC
                    e.poll_hist.record(time.perf_counter() - t_poll)
                if not batch:
                    if not self._drain_spill(e):
                        return
                    # a catch-up boost can drive throttle_interval to 0.0;
                    # an empty poll still paces at the base interval so the
                    # loop never busy-spins on a drained endpoint
                    if self._stopping.wait(e.throttle_interval
                                           or pol.poll_interval_sec):
                        return
                    continue
                if not self._admit(e, batch):
                    return       # stopping truncated admission: cursor stays
                e.cursor = c.cursor()
                e.stats.set(lag=c.lag())
                e.since_ckpt += len(batch)
                if e.since_ckpt >= pol.checkpoint_every_records:
                    e.since_ckpt = 0
                    t_ack = time.perf_counter()
                    try:
                        c.ack(e.cursor)
                        if e.ack_hist is not None:
                            e.ack_hist.record(time.perf_counter() - t_ack)
                    except Exception:
                        connected = False     # ack lost: reconnect, re-ack
                        e.state = "RECONNECTING"
                        self._close_quietly(c)
                    self._write_checkpoint(e)
                if not self._drain_spill(e):
                    return
                if pol.congestion_mode == "throttle":
                    self._adapt_throttle(e)
                    if e.throttle_interval > pol.poll_interval_sec:
                        # the backoff IS the congestion response: pause the
                        # poll loop so the drainer catches up
                        self._stopping.wait(e.throttle_interval)
        except BaseException as err:   # noqa: BLE001 — surfaced via join()
            e.state = "FAILED"
            e.error = err
        finally:
            if e.state not in ("COMPLETED", "FAILED"):
                e.state = "STOPPED"
            if not self._abort:
                if e.cursor is not None:
                    t_ack = time.perf_counter()
                    try:
                        c.ack(e.cursor)
                        if e.ack_hist is not None:
                            e.ack_hist.record(time.perf_counter() - t_ack)
                    except Exception:
                        pass
                    self._write_checkpoint(e)
                self._close_quietly(c)
                if e.state in ("COMPLETED", "FAILED"):
                    # a FAILED connector will never deliver again either:
                    # leaving it "active" would pin the fabric-wide low
                    # watermark at its last value forever, stalling every
                    # watermark-driven consumer (window closes) and growing
                    # their buffers without bound — degrade the clock
                    # instead; the failure itself is surfaced via join()
                    self.clock.mark_finished(c.name)
                # completing the handles lets the destination drain and
                # terminate — even for a FAILED connector, so the rest of
                # the graph still lands what was acquired
                e.dest.complete()
                if e.late_dest is not None:
                    e.late_dest.complete()

    def _backoff(self, e: _ConnectorEntry, failures: int,
                 err: BaseException) -> bool:
        """Sleep the policy's exponential backoff; False = budget exhausted
        (the entry turns FAILED)."""
        pol = e.policy.restart
        if failures > pol.max_restarts:
            e.state = "FAILED"
            e.error = err
            return False
        e.state = "RECONNECTING"
        self._stopping.wait(pol.backoff_for(failures))
        return True

    @staticmethod
    def _close_quietly(c: SourceConnector) -> None:
        try:
            c.close()
        except Exception:
            pass

    # -- congestion responses (ConnectorPolicy.congestion_mode) ----------------
    @staticmethod
    def _depth_fraction(conn: "Connection") -> float:
        """Downstream congestion gauge: queue depth as a fraction of
        whichever backpressure threshold is closer."""
        return max(len(conn) / conn.object_threshold,
                   conn.queued_bytes / conn.size_threshold)

    def _adapt_throttle(self, e: _ConnectorEntry) -> None:
        pol = e.policy
        depth = self._depth_fraction(e.dest.connection)
        if depth >= pol.congestion_high_water:
            prev = e.throttle_interval
            e.throttle_interval = min(
                pol.throttle_max_interval_sec,
                max(prev, pol.poll_interval_sec, 1e-4) * 2)
            if e.throttle_interval > prev:
                e.stats.add(throttle_engagements=1)
        elif depth <= pol.congestion_low_water:
            prev = e.throttle_interval
            lag = e.stats.lag
            if (pol.throttle_catchup_lag is not None and lag is not None
                    and lag >= pol.throttle_catchup_lag):
                # the endpoint ran ahead while we throttled: downstream has
                # headroom, so poll *faster than base* until lag recovers
                e.throttle_interval = pol.throttle_catchup_interval_sec
            else:
                e.throttle_interval = max(pol.poll_interval_sec, prev / 2)
            if e.throttle_interval < min(prev, pol.poll_interval_sec):
                e.stats.add(throttle_boosts=1)

    def _shed_split(self, e: _ConnectorEntry, batch: list[FlowFile]
                    ) -> tuple[list[FlowFile], list[FlowFile]]:
        """(kept, shed): a record of priority ``p`` is shed once downstream
        depth reaches ``high_water + p * headroom`` — lowest class first."""
        from .flow import ingress_priority
        pol = e.policy
        depth = self._depth_fraction(e.dest.connection)
        if depth < pol.congestion_high_water:
            return batch, []
        kept, shed = [], []
        for ff in batch:
            ceiling = min(1.0, pol.congestion_high_water
                          + ingress_priority(ff)
                          * pol.shed_headroom_per_priority)
            (shed if depth >= ceiling else kept).append(ff)
        return kept, shed

    def _spill(self, e: _ConnectorEntry, ffs: list[FlowFile]) -> None:
        """Park the overflow on the connector's durable side topic."""
        self.log.append_batch(e.spill_topic,
                              [ff.to_record() for ff in ffs], partition=0)
        self.log.flush_topic(e.spill_topic, fsync=False)
        e.stats.add(spilled=len(ffs))
        self.flow.provenance.record_batch("ROUTE", ffs, e.connector.name,
                                          details="congestion.spill")

    def _drain_spill(self, e: _ConnectorEntry, full: bool = False) -> bool:
        """Re-ingest spilled records once downstream depth recovered below
        the low-water mark (``full=True``: drain everything, end-of-stream).
        One slice per call keeps the poll loop live. Drained records were
        already watermark-split and stamped at spill time, so they are
        offered as-is — no re-observation. False = stopping truncated.

        Each pass also GCs spill segments wholly beneath the *checkpointed*
        drain frontier: a crash-restart resumes from the checkpoint, so
        nothing below it can ever be re-read — without this, spilled
        overflow persisted until runtime teardown."""
        if e.spill_topic is None:
            return True
        conn = e.dest.connection
        pol = e.policy
        if e.ckpt_spill_drained > e.spill_gc_below:
            try:
                dropped = self.log.drop_segments_below(
                    e.spill_topic, 0, e.ckpt_spill_drained)
                e.spill_gc_below = e.ckpt_spill_drained
                if dropped:
                    e.stats.add(spill_gc=int(dropped))
            except Exception:   # noqa: BLE001 — GC is best-effort
                pass
        while True:
            end = self.log.end_offset(e.spill_topic, 0)
            if e.spill_drained >= end:
                return True
            if not full \
                    and self._depth_fraction(conn) > pol.congestion_low_water:
                return True
            recs = self.log.read(e.spill_topic, 0, e.spill_drained,
                                 pol.max_poll_records)
            if not recs:
                return True
            ffs = [FlowFile.from_record(r.key, r.value) for r in recs]
            self.flow.provenance.record_batch(
                "REPLAY", ffs, e.connector.name, details="congestion.spill")
            if not self._offer(conn, ffs):
                return False
            e.spill_drained = recs[-1].offset + 1
            e.stats.add(spill_replayed=len(ffs), out_records=len(ffs),
                        out_bytes=sum(ff.size for ff in ffs))
            if not full:
                return True

    # -- admission ------------------------------------------------------------
    def _admit(self, e: _ConnectorEntry, batch: list[FlowFile]) -> bool:
        """Stamp priority, watermark-split ``batch``, apply the connector's
        congestion response, and offer the survivors downstream with
        backpressure. True only when every surviving record was admitted
        (shed and spilled records count as handled, not admitted)."""
        from .flow import ATTR_INGRESS_PRIORITY
        batch = self.flow.sample_trace(batch)   # stamp trace.id at admission
        if e.dest.priority:
            p = str(e.dest.priority)
            batch = [ff.with_attributes(**{ATTR_INGRESS_PRIORITY: p})
                     for ff in batch]
        tracker, ts_fn = e.tracker, e.event_ts_fn
        on_time: list[FlowFile] = []
        late: list[FlowFile] = []
        for ff in batch:
            if tracker.observe(ts_fn(ff)):
                late.append(ff.with_attributes(**{
                    "wm.late": "1",
                    "wm.watermark": f"{tracker.watermark:.6f}"}))
            else:
                on_time.append(ff)
        stats = e.stats
        stats.add(in_records=len(batch),
                  in_bytes=sum(ff.size for ff in batch))
        stats.set(late_records=tracker.late, watermark=tracker.watermark)
        pol = e.policy
        prov = self.flow.provenance
        ok = True
        admitted = 0
        admitted_bytes = 0
        if on_time:
            prov.record_batch("CREATE", on_time, e.connector.name)
            if pol.congestion_mode == "shed":
                on_time, shed = self._shed_split(e, on_time)
                if shed:
                    stats.add(shed=len(shed))
                    prov.record_batch("DROP", shed, e.connector.name,
                                      details="congestion.shed")
            elif pol.congestion_mode == "spill" \
                    and self._depth_fraction(e.dest.connection) \
                    >= pol.congestion_high_water:
                self._spill(e, on_time)
                on_time = []
            if on_time:
                ok &= self._offer(e.dest.connection, on_time)
                if ok:
                    admitted += len(on_time)
                    admitted_bytes += sum(ff.size for ff in on_time)
        if late:
            prov.record_batch("CREATE", late, e.connector.name,
                              details="late")
            target = e.late_dest or e.dest
            delivered = self._offer(target.connection, late)
            if delivered:
                admitted += len(late)
                admitted_bytes += sum(ff.size for ff in late)
            ok &= delivered
        if admitted:
            stats.add(out_records=admitted, out_bytes=admitted_bytes)
        return ok

    def _offer(self, conn: "Connection", ffs: list[FlowFile]) -> bool:
        offered = 0
        while offered < len(ffs):
            if self._stopping.is_set() or self.flow.stopping.is_set():
                return False
            offered += conn.offer_batch(ffs[offered:], block=True,
                                        timeout=0.25)
        return True

    # -- checkpointing ---------------------------------------------------------
    @staticmethod
    def _checkpoint_payload(e: _ConnectorEntry) -> bytes:
        return json.dumps({
            "cursor": e.cursor,
            "watermark": e.tracker.watermark,
            "acquired": e.stats.in_records,
            "spill_drained": e.spill_drained,
        }).encode()

    def _write_checkpoint(self, e: _ConnectorEntry) -> None:
        if self.log is None or e.cursor is None:
            return
        # built on the entry's own thread: cursor and watermark are a
        # consistent pair here (both post-_admit)
        payload = self._checkpoint_payload(e)
        e.ckpt_payload = payload
        # the payload's spill_drained is now durable: segments below it are
        # fair game for the drain loop's GC
        e.ckpt_spill_drained = json.loads(payload)["spill_drained"]
        with self._ckpt_lock:
            self.log.append(self.checkpoint_topic,
                            e.connector.name.encode(), payload, partition=0)
            self.log.flush_topic(self.checkpoint_topic,
                                 fsync=self.checkpoint_fsync)
            self._ckpt_appends += 1
            if self._ckpt_appends >= self._COMPACT_EVERY:
                self._compact_locked()

    def _compact_locked(self) -> None:
        """Rewrite the newest checkpoint of every connector, then drop the
        sealed segments below the rewrite — the checkpoint topic stays
        O(connectors), not O(run length). (A plain tail-drop could GC the
        only record of a quiet connector.) Saved cursors of connectors NOT
        registered in this incarnation (e.g. a temporarily disabled source)
        are carried forward verbatim, so compaction never forfeits a
        stranger's resume point. Only each entry's own-thread-written
        ``ckpt_payload`` is rewritten — never live cursor/watermark state,
        which the owning thread could be mid-update on."""
        first: int | None = None
        payloads = [(e.connector.name.encode(), e.ckpt_payload)
                    for e in self._entries.values()
                    if e.ckpt_payload is not None]
        payloads += [(name.encode(), json.dumps(saved).encode())
                     for name, saved in self._saved.items()
                     if name not in self._entries]
        for key, payload in payloads:
            _, off = self.log.append(self.checkpoint_topic, key, payload,
                                     partition=0)
            if first is None:
                first = off
        # always fsync the rewrite before GC'ing the segments below it —
        # even with checkpoint_fsync off: dropping the old segments while
        # the rewrite sits in the page cache would let a machine crash
        # delete every connector's only durable cursor (compaction is one
        # fsync per _COMPACT_EVERY appends, off the per-checkpoint path)
        self.log.flush_topic(self.checkpoint_topic, fsync=True)
        if first is not None:
            self.log.drop_segments_below(self.checkpoint_topic, 0, first)
        self._ckpt_appends = 0
