"""Deterministic fault injection for the flow runtime (robustness testing).

The paper's claim is as much *robustness* as scale; proving it needs faults
that strike at exactly the same place on every run. A ``FaultInjector`` is a
registry of named *sites*; runtime code calls ``fire(site, **ctx)`` at each
site, and tests / benchmarks *arm* a site with a schedule and an action.
Disarmed sites cost one dict lookup per call — nothing on the hot path.

Built-in sites (fired by the library itself):

  ``proc.<name>``              once per processor trigger, ``ctx: batch``
  ``log.segment.append_batch`` per contiguous chunk write, ``ctx: segment,
                               buf, records`` (before the ``write(2)``)
  ``delivery.producer.drain``  per ``Producer`` drain into the log
  ``delivery.consumer.poll``   per ``Consumer.poll``
  ``replica.leader``           before each leader-store append of a
                               ``ReplicatedLog`` partition, ``ctx: topic,
                               partition, replica, epoch`` — arm to kill a
                               leader mid-ingest and exercise failover
  ``replica.fence``            after a leader-store append, before the
                               epoch re-validation, ``ctx: topic,
                               partition, replica, epoch`` — arm a callable
                               that demotes the leader to land a write in
                               the zombie window deterministically
  ``replica.ship``             before each follower range-ship, ``ctx:
                               topic, partition, replica, offset``
  ``acquire.connect``          before each connector session open in the
                               acquisition runtime, ``ctx: connector,
                               cursor`` — arm ``"raise"`` to keep an
                               endpoint unreachable, ``"delay"`` to slow
                               connects
  ``acquire.poll``             before each connector poll, ``ctx:
                               connector, cursor`` — ``"raise"`` drops the
                               session mid-stream (reconnect + redelivery),
                               ``"delay"`` stalls the feed
  ``transport.server.recv``    in the LogServer after a request frame is
                               decoded, before dispatch, ``ctx: op, corr``
                               — a raised fault drops the connection with
                               the request *unapplied* (lost request)
  ``transport.server.respond`` after dispatch, before the response frame,
                               ``ctx: op, corr`` — a raised fault drops the
                               connection with the op *applied but unacked*
                               (the ambiguous window; tears a
                               partially-acked client pipeline
                               deterministically)

Every built-in site above is *declared* in :data:`SITES`; ``arm()`` refuses
an undeclared site (:class:`UndeclaredFaultSite`), so a typo'd site name in
a test can never silently never-fire. New runtime fire-sites must be added
to the registry (one-line doc each) — the ``fault-site-registry`` lint rule
(``python -m repro.analysis``) checks the string literals at ``fire(...)``
call sites against the same registry statically.

Schedules: ``arm(site, action, nth=N)`` fires on the Nth call only;
``arm(site, action, nth=N, every=M)`` fires on call N, N+M, N+2M, ...

Actions: ``"raise"`` (raise :class:`InjectedFault` — the supervisor /
retry machinery sees an ordinary processor failure), ``"crash"``
(``os._exit`` — a hard process kill for subprocess crash-recovery tests),
``"delay"`` (sleep ``delay_sec``), or any callable taking the site's ``ctx``
dict (e.g. :func:`raise_on` to poison specific records, or a custom partial
write + ``os._exit`` to tear a log record mid-batch).

A process-wide default instance :data:`INJECTOR` backs the module-level
:func:`fire`; tests must ``INJECTOR.reset()`` on teardown (the repo's
conftest does this automatically).
"""
from __future__ import annotations

import os
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Mapping

__all__ = ["FaultInjector", "InjectedFault", "INJECTOR", "SITES",
           "UndeclaredFaultSite", "compose", "declared", "fire",
           "raise_on", "raise_every_records"]


#: Central registry of every legal fault site. A trailing ``.*`` declares a
#: dynamic family (the concrete name is only known at runtime). ``arm()``
#: validates against this at arming time; the ``fault-site-registry`` lint
#: rule validates ``fire("...")`` string literals against it statically.
SITES: dict[str, str] = {
    "proc.*":
        "once per processor trigger (site is 'proc.<processor name>')",
    "log.segment.append_batch":
        "per contiguous chunk write, before the write(2)",
    "delivery.producer.drain":
        "per Producer drain into the log",
    "delivery.consumer.poll":
        "per Consumer.poll",
    "replica.leader":
        "before each leader-store append of a ReplicatedLog partition",
    "replica.fence":
        "after a leader append, before the epoch re-validation (zombie window)",
    "replica.ship":
        "before each follower range-ship",
    "acquire.connect":
        "before each connector session open in the acquisition runtime",
    "acquire.poll":
        "before each connector poll",
    "transport.server.recv":
        "LogServer: request decoded, before dispatch (lost-request window)",
    "transport.server.respond":
        "LogServer: dispatched, before the response frame (applied-but-"
        "unacked ambiguous window)",
}

_SITE_PREFIXES = tuple(s[:-1] for s in SITES if s.endswith(".*"))


def declared(site: str) -> bool:
    """True iff ``site`` is in the registry (exact, or under a declared
    dynamic family like ``proc.*``)."""
    return site in SITES or site.startswith(_SITE_PREFIXES)


class UndeclaredFaultSite(ValueError):
    """Raised by ``arm()`` for a site name missing from :data:`SITES` — a
    typo'd site would otherwise arm successfully and simply never fire."""


class InjectedFault(RuntimeError):
    """The exception raised by the ``"raise"`` action (and the helpers)."""


@dataclass
class _Arming:
    action: str | Callable[[Mapping], None]
    nth: int = 1
    every: int | None = None
    delay_sec: float = 0.05
    exit_code: int = 17
    calls: int = 0
    fired: int = 0
    lock: threading.Lock = field(default_factory=threading.Lock)

    def due(self) -> bool:
        """Count one call and decide (thread-safe, deterministic)."""
        with self.lock:
            self.calls += 1
            if self.calls < self.nth:
                return False
            if self.every is None:
                hit = self.calls == self.nth
            else:
                hit = (self.calls - self.nth) % self.every == 0
            if hit:
                self.fired += 1
            return hit


class FaultInjector:
    """Armable registry of deterministic fault sites."""

    def __init__(self) -> None:
        self._sites: dict[str, _Arming] = {}

    # -- arming ---------------------------------------------------------------
    def arm(self, site: str, action: str | Callable[[Mapping], None] = "raise",
            *, nth: int = 1, every: int | None = None,
            delay_sec: float = 0.05, exit_code: int = 17) -> None:
        if isinstance(action, str) and action not in ("raise", "crash", "delay"):
            raise ValueError(f"unknown fault action {action!r}")
        if nth < 1 or (every is not None and every < 1):
            raise ValueError("nth/every must be >= 1")
        if not declared(site):
            raise UndeclaredFaultSite(
                f"fault site {site!r} is not declared in faults.SITES — "
                "a typo here would arm a site that never fires; declare "
                "new sites in the registry (one-line doc each)")
        self._sites[site] = _Arming(action=action, nth=nth, every=every,
                                    delay_sec=delay_sec, exit_code=exit_code)

    def disarm(self, site: str) -> None:
        self._sites.pop(site, None)

    def reset(self) -> None:
        self._sites = {}

    # -- introspection --------------------------------------------------------
    def calls(self, site: str) -> int:
        a = self._sites.get(site)
        return a.calls if a else 0

    def fired(self, site: str) -> int:
        a = self._sites.get(site)
        return a.fired if a else 0

    def armed(self) -> list[str]:
        return sorted(self._sites)

    # -- the call site --------------------------------------------------------
    def fire(self, site: str, **ctx) -> None:
        """Runtime hook: no-op unless ``site`` is armed and its schedule is
        due. May raise :class:`InjectedFault`, sleep, or kill the process."""
        if not self._sites:
            return
        arming = self._sites.get(site)
        if arming is None or not arming.due():
            return
        action = arming.action
        if callable(action):
            action(ctx)
            return
        if action == "raise":
            raise InjectedFault(f"{site} (call {arming.calls})")
        if action == "delay":
            time.sleep(arming.delay_sec)
            return
        # "crash": a hard kill — no cleanup, no atexit, no flush. Exactly
        # what a power loss looks like to the durable log.
        os._exit(arming.exit_code)


#: Process-wide default injector (the library's built-in sites fire on it).
INJECTOR = FaultInjector()
fire = INJECTOR.fire


# -- action helpers ----------------------------------------------------------
def raise_on(predicate: Callable[["object"], bool],
             message: str = "poison record") -> Callable[[Mapping], None]:
    """Action for ``proc.*`` sites: raise iff the trigger batch contains a
    FlowFile matching ``predicate``. Arm with ``every=1`` so every trigger is
    inspected; the retry machinery then isolates the poison record and
    quarantines it after ``max_retries``."""
    def _action(ctx: Mapping) -> None:
        for ff in ctx.get("batch") or ():
            if predicate(ff):
                raise InjectedFault(message)
    return _action


def compose(*actions: Callable[[Mapping], None]) -> Callable[[Mapping], None]:
    """Run several callable actions in order at one site (e.g. a poison
    predicate AND a periodic crash — the chaos mix the acceptance scenario
    arms on the enrich stage)."""
    def _action(ctx: Mapping) -> None:
        for a in actions:
            a(ctx)
    return _action


def raise_every_records(n: int) -> Callable[[Mapping], None]:
    """Action for ``proc.*`` sites: raise after roughly every ``n`` records
    have passed the site (triggers carry whole batches; the counter trips on
    the batch that crosses each multiple of ``n``). Arm with ``every=1``."""
    state = {"seen": 0, "next": n}

    def _action(ctx: Mapping) -> None:
        state["seen"] += len(ctx.get("batch") or ())
        if state["seen"] >= state["next"]:
            state["next"] = state["seen"] + n
            raise InjectedFault(f"injected after ~{state['seen']} records")
    return _action
