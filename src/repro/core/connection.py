"""Bounded connections with NiFi-style backpressure (paper §IV.C, Fig. 5).

A Connection is the queue between two processors. Backpressure triggers when
EITHER threshold is reached (NiFi defaults, kept here):

  * object threshold  — max queued FlowFiles       (default 10,000)
  * data-size threshold — max queued payload bytes (default 1 GB)

When a connection is full the *upstream* component is no longer scheduled
(``offer`` blocks or returns False), exactly like NiFi stops scheduling the
source processor. Queued data is never dropped — when the downstream recovers
(paper Fig. 5: Kafka outage) the queue drains and the producers resume.

Optional prioritizers reorder delivery (paper §II: "prioritization of data
sources"); a rate throttle implements the paper's rate-throttling example of
backpressure.

Hot path: when no prioritizer is installed (the overwhelmingly common case)
the queue is a plain ``deque`` — no heap sift, no priority-tuple allocation
per record. ``offer_batch``/``poll_batch`` move whole batches under a single
lock acquisition, pairing with the log's ``append_batch`` end to end.
"""
from __future__ import annotations

import heapq
import itertools
import threading
import time
from collections import deque
from typing import Callable, Optional, Sequence, TYPE_CHECKING

from .flowfile import FlowFile

if TYPE_CHECKING:   # annotation only — connection.py stays import-light
    from .logstore import LogStore

DEFAULT_OBJECT_THRESHOLD = 10_000          # NiFi default (paper §IV.C)
DEFAULT_SIZE_THRESHOLD = 1 << 30           # 1 GB  (paper §IV.C)

#: minimum sleep while waiting on the rate throttle (prevents busy-spin when
#: the token deficit rounds to a zero-length sleep)
_MIN_THROTTLE_SLEEP = 1e-4


class BackpressureTimeout(Exception):
    """Raised when a blocking offer exceeded its deadline."""


class Connection:
    """Thread-safe bounded FlowFile queue with dual backpressure thresholds.

    FIFO by default (deque fast path); installing a ``prioritizer`` switches
    to a heap ordered by ``(priority, arrival)``. Both paths expose identical
    threshold semantics and ``snapshot()`` stats.
    """

    def __init__(self, name: str,
                 object_threshold: int = DEFAULT_OBJECT_THRESHOLD,
                 size_threshold: int = DEFAULT_SIZE_THRESHOLD,
                 prioritizer: Optional[Callable[[FlowFile], float]] = None,
                 max_retries: int = 0,
                 retry_penalty_sec: float = 0.01,
                 clock: Optional[Callable[[], float]] = None) -> None:
        if object_threshold <= 0 or size_threshold <= 0:
            raise ValueError("backpressure thresholds must be positive")
        if max_retries < 0 or retry_penalty_sec < 0:
            raise ValueError("retry settings must be non-negative")
        self.name = name
        self.object_threshold = object_threshold
        self.size_threshold = size_threshold
        #: failed records pulled from this connection are re-queued up to
        #: ``max_retries`` times (with escalating penalization) before being
        #: routed to the graph's dead-letter queue; 0 == legacy fail-fast
        self.max_retries = max_retries
        #: base penalization delay; retry k waits ``retry_penalty_sec * 2**k``
        self.retry_penalty_sec = retry_penalty_sec
        self._prioritizer = prioritizer
        # FIFO deque unless a prioritizer demands heap ordering
        self._heap: list[tuple[float, int, FlowFile]] = []
        self._fifo: deque[FlowFile] = deque()
        self._fifo_counter = itertools.count()
        self._bytes = 0
        self._lock = threading.Lock()
        self._not_full = threading.Condition(self._lock)
        self._not_empty = threading.Condition(self._lock)
        # stats (paper: status-history view)
        self.total_in = 0
        self.total_out = 0
        self.backpressure_engagements = 0
        self._hwm_objects = 0
        # consumer-side redelivery bypasses the thresholds (see requeue());
        # ``requeue_overshoot`` counts the records pushed while the queue was
        # already at/over threshold — the documented bounded overshoot the
        # overload scenario's memory check must allow for
        self.requeued = 0
        self.requeue_overshoot = 0
        #: monotonic time source for offer/poll deadlines; injectable so
        #: tests can drive backpressure timeouts deterministically
        self._clock: Callable[[], float] = \
            clock if clock is not None else time.monotonic
        # queue-dwell telemetry (attach_dwell_histogram); None == off, and
        # the hot path pays nothing beyond one None check per batch
        self._dwell_hist = None
        self._dwell_log: deque[list] | None = None
        self._dwell_clock: Callable[[], float] = self._clock

    # -- queue-dwell telemetry ------------------------------------------------
    def attach_dwell_histogram(self, hist, clock: Callable[[], float]
                               | None = None) -> None:
        """Record how long records sit queued into ``hist`` (a
        :class:`~repro.core.telemetry.LatencyHistogram`). Batch-amortized:
        one clock read logs a whole ``(timestamp, count)`` chunk on offer,
        one more consumes chunks FIFO on poll. Under a prioritizer (or a
        durable replay that predates the attach) the pairing is
        *approximate* — mass is conserved, order is assumed FIFO."""
        with self._lock:
            self._dwell_hist = hist
            self._dwell_log = deque()
            if clock is not None:
                self._dwell_clock = clock

    def _log_enqueue_locked(self, n: int) -> None:
        if self._dwell_log is not None and n > 0:
            self._dwell_log.append([self._dwell_clock(), n])

    def _log_dequeue_locked(self, n: int) -> None:
        log = self._dwell_log
        if log is None or n <= 0:
            return
        now = self._dwell_clock()
        while n > 0 and log:
            ts, cnt = log[0]
            take = cnt if cnt <= n else n
            self._dwell_hist.record(max(0.0, now - ts), take)
            if take == cnt:
                log.popleft()
            else:
                log[0][1] = cnt - take
            n -= take

    # -- queue internals (call with lock held) --------------------------------
    def _count_locked(self) -> int:
        return len(self._heap) if self._prioritizer else len(self._fifo)

    def _push_locked(self, ff: FlowFile) -> None:
        if self._prioritizer:
            heapq.heappush(self._heap,
                           (self._prioritizer(ff), next(self._fifo_counter), ff))
        else:
            self._fifo.append(ff)
        self._bytes += ff.size
        self.total_in += 1
        n = self._count_locked()
        if n > self._hwm_objects:
            self._hwm_objects = n

    def _pop_locked(self) -> FlowFile:
        if self._prioritizer:
            _, _, ff = heapq.heappop(self._heap)
        else:
            ff = self._fifo.popleft()
        self._bytes -= ff.size
        self.total_out += 1
        return ff

    def install_prioritizer(
            self, prioritizer: Callable[[FlowFile], float]) -> None:
        """Switch an existing FIFO queue to heap ordering (a prioritized
        ingress fanning into a connection that was created FIFO). Queued
        records migrate in arrival order; no-op when a prioritizer is
        already installed."""
        with self._lock:
            if self._prioritizer is not None:
                return
            self._prioritizer = prioritizer
            while self._fifo:
                ff = self._fifo.popleft()
                heapq.heappush(
                    self._heap,
                    (prioritizer(ff), next(self._fifo_counter), ff))

    # -- state ---------------------------------------------------------------
    def __len__(self) -> int:
        with self._lock:
            return self._count_locked()

    @property
    def queued_bytes(self) -> int:
        with self._lock:
            return self._bytes

    @property
    def high_water_mark(self) -> int:
        with self._lock:
            return self._hwm_objects

    def _full_locked(self) -> bool:
        return (self._count_locked() >= self.object_threshold
                or self._bytes >= self.size_threshold)

    def is_full(self) -> bool:
        with self._lock:
            return self._full_locked()

    # -- producer side -------------------------------------------------------
    def offer(self, ff: FlowFile, block: bool = True,
              timeout: float | None = None) -> bool:
        """Enqueue. With ``block`` the caller (upstream processor) is stalled
        while backpressure is engaged — this is the NiFi 'source no longer
        scheduled' behaviour. Non-blocking offer returns False when full."""
        deadline = None if timeout is None else self._clock() + timeout
        with self._not_full:
            engaged = False
            while self._full_locked():
                if not engaged:
                    self.backpressure_engagements += 1
                    engaged = True
                if not block:
                    return False
                remaining = None
                if deadline is not None:
                    remaining = deadline - self._clock()
                    if remaining <= 0:
                        raise BackpressureTimeout(
                            f"connection {self.name!r} full "
                            f"({self._count_locked()} objects / {self._bytes} B)")
                self._not_full.wait(remaining)
            self._push_locked(ff)
            self._log_enqueue_locked(1)
            self._not_empty.notify()
            return True

    def offer_batch(self, ffs: Sequence[FlowFile], block: bool = True,
                    timeout: float | None = None) -> int:
        """Enqueue up to ``len(ffs)`` records under one lock acquisition.

        Returns the number accepted (always ``len(ffs)`` when ``block`` and
        no ``timeout``). Unlike ``offer`` this never raises on timeout — the
        caller retries the unaccepted suffix, so partial progress survives
        shutdown checks. Backpressure engages per stall, not per record."""
        deadline = None if timeout is None else self._clock() + timeout
        accepted = 0
        logged = 0          # dwell-log high-water mark; flushed before any
                            # point where a consumer could observe the pushes
        with self._not_full:
            engaged = False
            for ff in ffs:
                while self._full_locked():
                    if not engaged:
                        self.backpressure_engagements += 1
                        engaged = True
                    if not block:
                        if accepted:
                            self._log_enqueue_locked(accepted - logged)
                            logged = accepted
                            self._not_empty.notify_all()
                        return accepted
                    # wake consumers before sleeping: they drain the records
                    # already pushed and free space for the rest of the batch
                    if accepted:
                        self._log_enqueue_locked(accepted - logged)
                        logged = accepted
                        self._not_empty.notify_all()
                    remaining = None
                    if deadline is not None:
                        remaining = deadline - self._clock()
                        if remaining <= 0:
                            if accepted:
                                # records pushed since the last stall were
                                # never announced — a consumer blocked in
                                # poll() with no timeout would sleep forever
                                # over a non-empty queue
                                self._not_empty.notify_all()
                            return accepted
                    self._not_full.wait(remaining)
                self._push_locked(ff)
                accepted += 1
            if accepted:
                self._log_enqueue_locked(accepted - logged)
                self._not_empty.notify_all()
            return accepted

    def requeue(self, ffs: Sequence[FlowFile]) -> None:
        """Consumer-side redelivery: push records back in, *bypassing* the
        backpressure thresholds. The consuming worker is this queue's only
        drainer — a blocking re-offer against a full queue would deadlock it
        (nobody else frees space). The overshoot is bounded by one in-flight
        batch plus pending retries."""
        with self._lock:
            for ff in ffs:
                if self._full_locked():
                    self.requeue_overshoot += 1
                self._push_locked(ff)
            self.requeued += len(ffs)
            self._log_enqueue_locked(len(ffs))
            self._not_empty.notify_all()

    # -- consumer side -------------------------------------------------------
    def poll(self, block: bool = True, timeout: float | None = None
             ) -> FlowFile | None:
        deadline = None if timeout is None else self._clock() + timeout
        with self._not_empty:
            while not self._count_locked():
                if not block:
                    return None
                remaining = None
                if deadline is not None:
                    remaining = deadline - self._clock()
                    if remaining <= 0:
                        return None
                self._not_empty.wait(remaining)
            ff = self._pop_locked()
            self._log_dequeue_locked(1)
            self._not_full.notify()
            return ff

    def poll_batch(self, max_items: int, timeout: float = 0.0) -> list[FlowFile]:
        """Drain up to ``max_items`` (at least one if any arrive within
        ``timeout``). Batch drains amortize lock traffic on hot paths."""
        out: list[FlowFile] = []
        first = self.poll(block=timeout > 0, timeout=timeout or None)
        if first is None:
            return out
        out.append(first)
        with self._not_empty:
            more = 0
            while self._count_locked() and len(out) < max_items:
                out.append(self._pop_locked())
                more += 1
            self._log_dequeue_locked(more)
            if out:
                self._not_full.notify_all()
        return out

    def snapshot(self) -> dict:
        with self._lock:
            n = self._count_locked()
            return {
                "name": self.name,
                "queued_objects": n,
                "queued_bytes": self._bytes,
                "object_threshold": self.object_threshold,
                "size_threshold": self.size_threshold,
                # depth as a fraction of each threshold — what congestion
                # policies and elastic worker pools act on
                "utilization_objects": n / self.object_threshold,
                "utilization_bytes": self._bytes / self.size_threshold,
                "backpressure": self._full_locked(),
                "backpressure_engagements": self.backpressure_engagements,
                "high_water_mark": self._hwm_objects,
                "total_in": self.total_in,
                "total_out": self.total_out,
                "requeued": self.requeued,
                "requeue_overshoot": self.requeue_overshoot,
            }


class DurableConnection(Connection):
    """WAL-backed connection: an opt-in ``Connection`` that journals every
    accepted FlowFile through a durable :class:`~repro.core.logstore.LogStore`
    (``append_batch``) and tracks the consumer's acked frontier, so a
    crashed graph restarts from its last acked record with
    **at-least-once** delivery. Journaling through a replicated store
    (``ReplicatedLog`` with ``acks="all"``) upgrades the WAL from
    disk-loss-fragile to replica-loss-tolerant without touching this class.

    Contract
    --------
    * ``offer``/``offer_batch`` return only after the accepted records are
      journaled to ``topic`` (WAL order == queue order; one outer lock
      serializes enqueue+journal). A crash *after* an offer returns cannot
      lose the record; a crash *during* it means the producer never got its
      ack and must re-offer (its own at-least-once contract).
    * the consuming worker calls ``ack(n)`` once a polled batch is fully
      settled (emitted downstream / re-queued / dead-lettered); the frontier
      is journaled to ``<topic>.__acks__``.
    * on construction, the un-acked suffix ``[frontier, end)`` is replayed
      straight into the in-memory queue (bypassing backpressure thresholds:
      the suffix is bounded by what was queued at crash time). Records that
      were settled but whose ack never hit disk are replayed too — duplicates
      are the price of at-least-once.

    FIFO only (a prioritizer would break the frontier's prefix semantics).
    ``wal_fsync=True`` upgrades durability from process-crash to
    machine-crash at ~160 ms per journal append on this host — leave it off
    unless you mean it.
    """

    def __init__(self, name: str, log: "LogStore", *,
                 topic: str | None = None,
                 object_threshold: int = DEFAULT_OBJECT_THRESHOLD,
                 size_threshold: int = DEFAULT_SIZE_THRESHOLD,
                 max_retries: int = 0, retry_penalty_sec: float = 0.01,
                 wal_fsync: bool = False,
                 clock: Optional[Callable[[], float]] = None) -> None:
        super().__init__(name, object_threshold, size_threshold,
                         prioritizer=None, max_retries=max_retries,
                         retry_penalty_sec=retry_penalty_sec, clock=clock)
        self.log = log
        self.topic = topic or "__wal__." + name.replace("/", "_")
        self.ack_topic = self.topic + ".__acks__"
        self.wal_fsync = wal_fsync
        log.create_topic(self.topic, partitions=1)
        log.create_topic(self.ack_topic, partitions=1)
        # serializes enqueue+journal so WAL order matches queue order; never
        # taken by the consumer side (poll/ack), so a producer blocked on
        # backpressure inside it cannot deadlock the draining consumer
        self._wal_lock = threading.Lock()
        self._ack_lock = threading.Lock()
        self._acks_since_gc = 0
        self._acked = self._load_frontier()
        self.replayed = self._replay()

    def install_prioritizer(
            self, prioritizer: Callable[[FlowFile], float]) -> None:
        raise RuntimeError(
            f"{self.name}: durable connections are FIFO-only "
            "(the acked frontier is a count prefix)")

    def _load_frontier(self) -> int:
        end = self.log.end_offset(self.ack_topic, 0)
        if end == 0:
            return 0
        recs = self.log.read(self.ack_topic, 0, end - 1, 1)
        return int(recs[0].value) if recs else 0

    def _replay(self) -> int:
        off, n = self._acked, 0
        end = self.log.end_offset(self.topic, 0)
        while off < end:
            recs = self.log.read(self.topic, 0, off, 512)
            if not recs:
                break
            with self._lock:
                for r in recs:
                    self._push_locked(FlowFile.from_record(r.key, r.value))
                self._not_empty.notify_all()
            off = recs[-1].offset + 1
            n += len(recs)
        return n

    # -- producer side (journal-on-accept) -----------------------------------
    def offer(self, ff: FlowFile, block: bool = True,
              timeout: float | None = None) -> bool:
        n = self.offer_batch((ff,), block=block, timeout=timeout)
        if n == 0 and block and timeout is not None:
            raise BackpressureTimeout(f"connection {self.name!r} full")
        return n == 1

    def _journal_and_push_locked(self, ffs: Sequence[FlowFile]) -> None:
        """Journal-then-enqueue atomically (caller holds ``_wal_lock`` and
        ``_lock``). Journal FIRST: a record must never be pollable before it
        is durable, or a fast consumer could ack past the WAL end and a
        crash mid-append would lose the record on replay. flush() moves the
        journal out of userspace buffers so it survives a process kill
        (fsync only for machine-crash durability)."""
        self.log.append_batch(self.topic, [ff.to_record() for ff in ffs],
                              partition=0)
        self.log.flush_topic(self.topic, fsync=self.wal_fsync)
        for ff in ffs:
            self._push_locked(ff)
        self._log_enqueue_locked(len(ffs))
        self._not_empty.notify_all()

    def offer_batch(self, ffs: Sequence[FlowFile], block: bool = True,
                    timeout: float | None = None) -> int:
        # Journal+enqueue in non-blocking chunks under _wal_lock (keeps WAL
        # order == queue order), but wait for backpressure space with the
        # lock RELEASED — holding it across a stall would convoy every other
        # producer (and the consumer's requeue path) behind one full queue.
        deadline = None if timeout is None else self._clock() + timeout
        n = len(ffs)
        accepted = 0
        engaged = False
        while accepted < n:
            with self._wal_lock:
                with self._lock:
                    # how many fit right now, under the same growth rule as
                    # the base offer_batch (threshold checked before each)
                    count = self._count_locked()
                    size = self._bytes
                    k = 0
                    while (accepted + k < n
                           and count + k < self.object_threshold
                           and size < self.size_threshold):
                        size += ffs[accepted + k].size
                        k += 1
                    if k:
                        self._journal_and_push_locked(
                            ffs[accepted:accepted + k])
                        accepted += k
                        continue
                    if not engaged:
                        self.backpressure_engagements += 1
                        engaged = True
            if not block:
                break
            remaining = None
            if deadline is not None:
                remaining = deadline - self._clock()
                if remaining <= 0:
                    break
            with self._not_full:
                if self._full_locked():
                    self._not_full.wait(0.05 if remaining is None
                                        else min(remaining, 0.05))
        return accepted

    def requeue(self, ffs: Sequence[FlowFile]) -> None:
        """Consumer-side redelivery with journaling: the re-queued copies are
        appended to the WAL (so the acked frontier stays a strict prefix) and
        pushed past the thresholds — never blocks, so the sole drainer of
        this queue cannot deadlock itself."""
        with self._wal_lock:
            with self._lock:
                room = max(0, self.object_threshold - self._count_locked())
                self.requeue_overshoot += max(0, len(ffs) - room)
                self.requeued += len(ffs)
                self._journal_and_push_locked(ffs)

    # -- consumer side -------------------------------------------------------
    #: acks between WAL garbage-collection sweeps (dead segments below the
    #: frontier are dropped so the journal stays O(in-flight), not O(ever))
    _GC_EVERY_ACKS = 64

    def ack(self, n: int) -> None:
        """Advance the consumed frontier by ``n`` records and journal it."""
        if n <= 0:
            return
        with self._ack_lock:
            self._acked += n
            self._acks_since_gc += 1
            self.log.append(self.ack_topic, b"", str(self._acked).encode(),
                            partition=0)
            self.log.flush_topic(self.ack_topic, fsync=self.wal_fsync)
            if self._acks_since_gc >= self._GC_EVERY_ACKS:
                self._acks_since_gc = 0
                # everything below the frontier (and every ack record but
                # the last) is dead: drop whole sealed segments behind them
                self.log.drop_segments_below(self.topic, 0, self._acked)
                self.log.drop_segments_below(
                    self.ack_topic, 0, self.log.end_offset(self.ack_topic, 0) - 1)

    @property
    def acked(self) -> int:
        with self._ack_lock:
            return self._acked

    def snapshot(self) -> dict:
        snap = super().snapshot()
        snap["durable"] = True
        snap["wal_topic"] = self.topic
        snap["acked"] = self.acked
        snap["replayed"] = self.replayed
        return snap


class RateThrottle:
    """Token-bucket rate limiter — the paper's 'rate throttling' backpressure
    example (§II.E). Thread-safe; ``acquire`` blocks until a permit exists."""

    def __init__(self, rate_per_sec: float, burst: int | None = None,
                 clock: Optional[Callable[[], float]] = None) -> None:
        if rate_per_sec <= 0:
            raise ValueError("rate must be positive")
        self.rate = float(rate_per_sec)
        self.capacity = float(burst if burst is not None else max(1, int(rate_per_sec)))
        self._tokens = self.capacity
        self._clock: Callable[[], float] = \
            clock if clock is not None else time.monotonic
        self._last = self._clock()
        self._lock = threading.Lock()

    def _refill_locked(self) -> None:
        now = self._clock()
        self._tokens = min(self.capacity,
                           self._tokens + (now - self._last) * self.rate)
        self._last = now

    def try_acquire(self, n: int = 1) -> bool:
        with self._lock:
            self._refill_locked()
            if self._tokens >= n:
                self._tokens -= n
                return True
            return False

    def acquire(self, n: int = 1) -> None:
        while True:
            # one locked section: refill, take, or compute the exact deficit
            with self._lock:
                self._refill_locked()
                if self._tokens >= n:
                    self._tokens -= n
                    return
                deficit = n - self._tokens
            time.sleep(min(0.1, max(deficit / self.rate, _MIN_THROTTLE_SLEEP)))
