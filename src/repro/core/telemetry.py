"""Latency histograms, a process-local metrics registry, and fabric-wide
telemetry aggregation (paper §IV.C: NiFi's status-history and provenance
views — "the amount of data read, written, in, and out in the last 5
minutes" — extended from *how many* to *how long* and *where time went*).

The paper's operational story has two halves this module serves:

* **status history** — per-component gauges over time. ``MetricsRegistry``
  unifies the repo's existing counter surfaces (``ComponentStats``,
  ``Connection.snapshot()``, acquisition connector gauges) with the new
  latency histograms behind one ``collect()``, rendered either as a
  Prometheus-style text exposition (``render_text()``) or a JSON dump.
* **provenance / lineage timing** — the flow engine samples records
  (``trace_sample_rate``) and stamps a ``trace.id`` attribute; per-hop
  span events ride the existing provenance repository so
  ``FlowGraph.trace_spans()`` can reconstruct a timed span tree for one
  record's ingest→land journey.

Design constraints, in order:

1. **Mergeable.** Histograms use *fixed* power-of-two bucket boundaries
   (bucket ``i`` covers ``[2**(i-1), 2**i)`` microseconds), so histograms
   recorded independently in N worker processes merge *exactly* — merge is
   element-wise addition, and percentiles over the merged histogram equal
   percentiles over a single histogram fed all samples. This is what lets
   fabric workers ship their histogram state on every heartbeat and the
   coordinator fold them into one fabric-wide view mid-run.
2. **Bounded.** A histogram is at most :data:`NBUCKETS` integers — memory
   does not grow with the number of observations, and the serialized form
   is sparse (only non-empty buckets travel on heartbeats).
3. **Cheap.** The hot path records one ``perf_counter`` pair per *batch*
   and folds the batch size in as a bucket weight, so per-record cost is
   amortized to ~zero. Everything here is optional: a ``FlowGraph`` built
   with ``telemetry=False`` carries no registry and the engine skips every
   hook.
4. **Deterministic under test.** Histograms, flight recorders, and
   ``WindowedCounter`` accept an injected ``clock`` so tests on a
   load-spiky 1-CPU host never sleep against real time.

``FlightRecorder`` keeps the last N status snapshots in a ring — the
post-mortem view dumped to JSON when a fabric worker dies or an acceptance
scenario fails, so a red run shows *where* depth/latency diverged instead
of a bare boolean.
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from contextlib import contextmanager
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable, Iterable, Mapping, Optional

__all__ = [
    "NBUCKETS", "LatencyHistogram", "MetricsRegistry", "FlightRecorder",
    "ScrapeServer", "serve_scrape", "metric_key", "split_metric_key",
    "merge_histogram_states", "summarize_histogram_state",
    "render_histogram_state_text",
]

#: Fixed bucket count. Bucket 0 holds sub-microsecond samples; bucket i
#: (i >= 1) covers [2**(i-1), 2**i) microseconds; the last bucket is a
#: catch-all. 2**62 µs is ~146k years — nothing a pipeline measures
#: overflows the range.
NBUCKETS = 64

#: Default summary quantiles (and their text-exposition labels).
_QUANTILES = ((0.5, "p50_ms"), (0.9, "p90_ms"), (0.99, "p99_ms"))


def bucket_index(seconds: float) -> int:
    """Bucket for a duration. Fixed boundaries — never configuration-
    dependent — so any two histograms merge exactly."""
    us = int(seconds * 1e6)
    if us <= 0:
        return 0
    return min(us.bit_length(), NBUCKETS - 1)


def _bucket_midpoint_sec(i: int) -> float:
    """Representative value for bucket ``i``: the geometric midpoint of
    its [2**(i-1), 2**i) µs range (0.5 µs for the sub-µs bucket)."""
    if i == 0:
        return 0.5e-6
    return (2.0 ** (i - 0.5)) / 1e6


class LatencyHistogram:
    """Thread-safe, mergeable, bounded-memory latency histogram.

    ``record(seconds, n)`` folds ``n`` observations of the same duration in
    at once — the flow engine times a *batch* and records with
    ``n=len(batch)``, amortizing the clock reads. ``merge`` is exact
    (fixed boundaries); ``percentile`` answers from bucket midpoints, so
    its error is bounded by the power-of-two bucket width (~±41%
    worst-case on an individual sample, far tighter on the aggregate —
    exactly the resolution regime of Prometheus/HDR-style log buckets).
    """

    __slots__ = ("_counts", "_count", "_sum", "_lock", "_clock")

    def __init__(self, clock: Optional[Callable[[], float]] = None) -> None:
        self._counts = [0] * NBUCKETS
        self._count = 0
        self._sum = 0.0
        self._lock = threading.Lock()
        self._clock = clock or time.perf_counter

    # -- recording -----------------------------------------------------------
    def record(self, seconds: float, n: int = 1) -> None:
        """Record ``n`` observations of ``seconds`` (batch-amortized)."""
        if n <= 0:
            return
        i = bucket_index(seconds)
        s = seconds * n
        with self._lock:
            self._counts[i] += n
            self._count += n
            self._sum += s

    def record_many(self, durations: Iterable[float]) -> None:
        """Record individually-measured durations under one lock hold."""
        add = [0] * NBUCKETS
        total = 0
        tsum = 0.0
        for d in durations:
            add[bucket_index(d)] += 1
            total += 1
            tsum += d
        if not total:
            return
        with self._lock:
            for i, c in enumerate(add):
                if c:
                    self._counts[i] += c
            self._count += total
            self._sum += tsum

    @contextmanager
    def timer(self, n: int = 1):
        """``with hist.timer(n=len(batch)):`` — one clock pair per block."""
        t0 = self._clock()
        try:
            yield
        finally:
            self.record(self._clock() - t0, n)

    # -- reading -------------------------------------------------------------
    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum_seconds(self) -> float:
        with self._lock:
            return self._sum

    def percentile(self, q: float) -> float:
        """q-th percentile in seconds (q in [0, 1]); 0.0 when empty."""
        if not 0.0 <= q <= 1.0:
            raise ValueError("quantile must be within [0, 1]")
        with self._lock:
            total = self._count
            counts = list(self._counts)
        if not total:
            return 0.0
        rank = q * total
        seen = 0
        for i, c in enumerate(counts):
            seen += c
            if seen >= rank and c:
                return _bucket_midpoint_sec(i)
        for i in range(NBUCKETS - 1, -1, -1):     # pragma: no cover — q=1.0
            if counts[i]:
                return _bucket_midpoint_sec(i)
        return 0.0

    def summary(self) -> dict:
        """Count, mean, and the standard quantiles in milliseconds."""
        with self._lock:
            total = self._count
            tsum = self._sum
        out = {"count": total,
               "mean_ms": round(tsum / total * 1e3, 3) if total else 0.0}
        for q, label in _QUANTILES:
            out[label] = round(self.percentile(q) * 1e3, 3)
        return out

    # -- merge / serialization ----------------------------------------------
    def merge(self, other: "LatencyHistogram") -> "LatencyHistogram":
        """Fold ``other`` into self (exact: fixed bucket boundaries)."""
        with other._lock:
            counts = list(other._counts)
            count = other._count
            tsum = other._sum
        with self._lock:
            for i, c in enumerate(counts):
                if c:
                    self._counts[i] += c
            self._count += count
            self._sum += tsum
        return self

    def to_dict(self) -> dict:
        """Sparse JSON-safe state: ``{"b": {bucket: count}, "n": ..., "s": ...}``."""
        with self._lock:
            return {
                "b": {str(i): c for i, c in enumerate(self._counts) if c},
                "n": self._count,
                "s": self._sum,
            }

    @classmethod
    def from_dict(cls, state: Mapping,
                  clock: Optional[Callable[[], float]] = None
                  ) -> "LatencyHistogram":
        h = cls(clock=clock)
        for i, c in (state.get("b") or {}).items():
            h._counts[int(i)] += int(c)
        h._count = int(state.get("n", 0))
        h._sum = float(state.get("s", 0.0))
        return h


# -- canonical metric keys ----------------------------------------------------
def metric_key(name: str, labels: Mapping[str, str] | None = None) -> str:
    """Canonical ``name{k="v",...}`` key (labels sorted) — both the registry
    index and the cross-worker merge key for serialized histogram state."""
    if not labels:
        return name
    lab = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
    return f"{name}{{{lab}}}"


def split_metric_key(key: str) -> tuple[str, str]:
    """``'a{x="1"}'`` -> ``('a', 'x="1"')``; label-less keys -> ``(key, '')``."""
    if "{" in key:
        name, rest = key.split("{", 1)
        return name, rest.rstrip("}")
    return key, ""


# -- serialized-state helpers (coordinator side) ------------------------------
def merge_histogram_states(into: dict, state: Mapping[str, Mapping]) -> dict:
    """Fold one serialized ``{key: hist.to_dict()}`` map into ``into``.
    Exact for the same reason instance merge is: fixed boundaries."""
    for key, hs in state.items():
        cur = into.get(key)
        if cur is None:
            into[key] = {"b": dict((hs.get("b") or {})),
                         "n": int(hs.get("n", 0)),
                         "s": float(hs.get("s", 0.0))}
            continue
        for i, c in (hs.get("b") or {}).items():
            cur["b"][i] = cur["b"].get(i, 0) + int(c)
        cur["n"] += int(hs.get("n", 0))
        cur["s"] += float(hs.get("s", 0.0))
    return into


def summarize_histogram_state(state: Mapping[str, Mapping]) -> dict:
    """``{key: summary}`` for a serialized state map (fabric ``status()``)."""
    return {key: LatencyHistogram.from_dict(hs).summary()
            for key, hs in state.items()}


def render_histogram_state_text(state: Mapping[str, Mapping],
                                prefix: str = "repro_") -> str:
    """Prometheus summary-style exposition for a serialized state map."""
    lines: list[str] = []
    for key in sorted(state):
        h = LatencyHistogram.from_dict(state[key])
        name, labels = split_metric_key(key)
        base = prefix + name
        for q, _ in _QUANTILES:
            qlab = f'quantile="{q}"'
            lab = f"{labels},{qlab}" if labels else qlab
            lines.append(f"{base}{{{lab}}} {h.percentile(q):.9f}")
        suffix = f"{{{labels}}}" if labels else ""
        lines.append(f"{base}_count{suffix} {h.count}")
        lines.append(f"{base}_sum{suffix} {h.sum_seconds:.9f}")
    return "\n".join(lines) + ("\n" if lines else "")


class MetricsRegistry:
    """Process-local metric surface: named+labelled latency histograms plus
    pluggable gauge *sources* (callables returning ``{instance: {field:
    value}}`` — the shape of ``ComponentStats.snapshot()``,
    ``Connection.snapshot()``, and the acquisition connector gauges), all
    behind one ``collect()`` / ``render_text()`` / ``to_json()``.

    ``histograms_state()`` is the fabric wire format: the canonical-key →
    sparse-dict map a worker ships on every heartbeat.
    """

    def __init__(self, clock: Optional[Callable[[], float]] = None) -> None:
        self._clock = clock
        self._lock = threading.Lock()
        self._hists: dict[str, LatencyHistogram] = {}
        self._sources: dict[str, Callable[[], Mapping]] = {}

    # -- histograms ----------------------------------------------------------
    def histogram(self, name: str, **labels: str) -> LatencyHistogram:
        """Get-or-create the histogram for ``(name, labels)``."""
        key = metric_key(name, labels)
        with self._lock:
            h = self._hists.get(key)
            if h is None:
                h = self._hists[key] = LatencyHistogram(clock=self._clock)
            return h

    def histograms_state(self) -> dict:
        """Serialized ``{canonical key: hist.to_dict()}`` (heartbeat cargo)."""
        with self._lock:
            hists = list(self._hists.items())
        return {key: h.to_dict() for key, h in hists}

    def summaries(self) -> dict:
        """``{canonical key: summary}`` — the ``status()['telemetry']`` body."""
        with self._lock:
            hists = list(self._hists.items())
        return {key: h.summary() for key, h in hists}

    def merged(self, name: str) -> LatencyHistogram:
        """One histogram folding every label set of ``name`` together."""
        out = LatencyHistogram()
        with self._lock:
            hists = list(self._hists.items())
        for key, h in hists:
            if split_metric_key(key)[0] == name:
                out.merge(h)
        return out

    # -- gauge sources -------------------------------------------------------
    def register_source(self, kind: str, fn: Callable[[], Mapping]) -> None:
        """Register a gauge source. ``fn()`` must return ``{instance:
        {field: value}}``; non-numeric fields are skipped at render time.
        ``kind`` becomes the instance label name (e.g. ``processor``)."""
        with self._lock:
            self._sources[kind] = fn

    # -- collection ----------------------------------------------------------
    def collect(self) -> dict:
        """One unified snapshot: every gauge source plus every histogram."""
        with self._lock:
            sources = list(self._sources.items())
        gauges = {}
        for kind, fn in sources:
            try:
                gauges[kind] = {str(k): dict(v) for k, v in fn().items()}
            except Exception:           # a dying component must not kill scrape
                gauges[kind] = {}
        return {"gauges": gauges, "histograms": self.summaries()}

    def render_text(self, prefix: str = "repro_") -> str:
        """Prometheus-style text exposition of ``collect()``."""
        snap = self.collect()
        lines: list[str] = []
        for kind in sorted(snap["gauges"]):
            for inst in sorted(snap["gauges"][kind]):
                fields = snap["gauges"][kind][inst]
                for field in sorted(fields):
                    v = fields[field]
                    if isinstance(v, bool) or not isinstance(v, (int, float)):
                        continue
                    lines.append(
                        f'{prefix}{kind}_{field}{{{kind}="{inst}"}} {v}')
        text = "\n".join(lines) + ("\n" if lines else "")
        return text + render_histogram_state_text(
            self.histograms_state(), prefix=prefix)

    def to_json(self) -> str:
        return json.dumps(self.collect(), sort_keys=True, default=str)


class FlightRecorder:
    """Bounded ring of the last N status snapshots — the post-mortem a
    worker death or failed acceptance scenario dumps to JSON, so a red run
    shows where queue depth / latency / watermarks diverged over the final
    seconds instead of one boolean."""

    def __init__(self, capacity: int = 64,
                 clock: Optional[Callable[[], float]] = None) -> None:
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        self.capacity = capacity
        self._clock = clock or time.time
        self._ring: deque = deque(maxlen=capacity)
        self._lock = threading.Lock()

    def record(self, snapshot: Mapping) -> None:
        with self._lock:
            self._ring.append({"ts": self._clock(), "status": snapshot})

    def snapshots(self) -> list:
        with self._lock:
            return list(self._ring)

    def dump_json(self) -> str:
        return json.dumps(self.snapshots(), sort_keys=True, default=str)

    def dump(self, path) -> str:
        """Write the ring to ``path`` (JSON); returns the path as str."""
        data = self.dump_json()
        with open(path, "w", encoding="utf-8") as f:
            f.write(data)
        return str(path)


# -- scrape endpoint ----------------------------------------------------------
class ScrapeServer:
    """A tiny stdlib HTTP server exposing one text render at ``/metrics``
    (and ``/``). Daemon-threaded; ``close()`` is idempotent."""

    def __init__(self, render_fn: Callable[[], str], port: int = 0,
                 host: str = "127.0.0.1") -> None:
        outer = self

        class _Handler(BaseHTTPRequestHandler):
            def do_GET(self) -> None:           # noqa: N802 — stdlib API
                if self.path.split("?", 1)[0] not in ("/", "/metrics"):
                    self.send_error(404)
                    return
                try:
                    body = outer.render_fn().encode("utf-8")
                except Exception as e:      # noqa: BLE001 — scrape must answer
                    self.send_error(500, str(e))
                    return
                self.send_response(200)
                self.send_header("Content-Type",
                                 "text/plain; version=0.0.4; charset=utf-8")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *a) -> None:  # silence per-request stderr
                pass

        self.render_fn = render_fn
        self._httpd = ThreadingHTTPServer((host, port), _Handler)
        self._httpd.daemon_threads = True
        self.port = self._httpd.server_address[1]
        self.url = f"http://{host}:{self.port}/metrics"
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name=f"metrics-scrape-{self.port}",
            daemon=True)
        self._thread.start()
        self._closed = False

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5.0)


def serve_scrape(render_fn: Callable[[], str], port: int = 0,
                 host: str = "127.0.0.1") -> ScrapeServer:
    """Start an HTTP scrape endpoint serving ``render_fn()`` at /metrics."""
    return ScrapeServer(render_fn, port=port, host=host)
