"""Deterministic synthetic data sources reproducing the paper's case study
(§IV.B): a Big-RSS-like aggregator, a Twitter-firehose-like stream and a raw
WebSocket feed. All are seeded generators — fully replayable (the property
the ingestion fabric's recovery story builds on) and fast enough to drive
multi-100k-records/s benchmarks.

Articles deliberately include the noise the paper filters: exact duplicates
(retweets / syndicated copies), malformed payloads, and off-language items.
"""
from __future__ import annotations

import json
import random
from typing import Iterator

from .flowfile import FlowFile, make_flowfile

_WORDS = (
    "market stream data global news flash flood election satellite launch "
    "storm rally protest economy vaccine energy grid transit health summit "
    "quarter earnings merger strike wildfire quake rescue policy senate "
    "court ruling trade port cargo drought harvest festival derby final "
    "transfer record champion orbit probe lander relay fiber outage patch "
    "breach audit ledger token chain index fund bond yield rate cut hike"
).split()

_SOURCES_RSS = ("reuters", "ap", "afp", "bbc", "cbc", "nhk", "dw", "abc")
_LANGS = ("en", "en", "en", "fr", "de", "ja", "es")   # en-heavy mix


def _sentence(rng: random.Random, n: int) -> str:
    # one choices() call per sentence, not one choice() per word — the
    # sources must outrun the fabric they feed
    return " ".join(rng.choices(_WORDS, k=n))


def synth_article(rng: random.Random, idx: int, source: str) -> dict:
    return {
        "id": f"{source}-{idx}",
        "source": source,
        "lang": rng.choice(_LANGS),
        "title": _sentence(rng, 8),
        "body": _sentence(rng, rng.randint(40, 160)),
        "ts": 1534660000 + idx,          # paper's Fig.3 epoch (Aug 2018)
    }


class RssAggregatorSource:
    """Big-RSS analogue. ``dup_rate`` injects syndicated duplicates,
    ``junk_rate`` injects malformed JSON (erroneous items to filter), and
    ``poison_rate`` injects well-formed articles tagged ``kind="poison"`` —
    records a downstream stage chokes on, for exercising the retry /
    dead-letter machinery. With ``poison_rate=0`` (default) the yielded
    stream is bit-identical to the seed's (same rng consumption)."""

    def __init__(self, count: int, seed: int = 0, dup_rate: float = 0.08,
                 junk_rate: float = 0.01, poison_rate: float = 0.0,
                 name: str = "big-rss") -> None:
        self.count = count
        self.seed = seed
        self.dup_rate = dup_rate
        self.junk_rate = junk_rate
        self.poison_rate = poison_rate
        self.name = name

    def __call__(self) -> Iterator[FlowFile]:
        rng = random.Random(self.seed)
        recent: list[dict] = []
        for i in range(self.count):
            r = rng.random()
            if r < self.junk_rate:
                yield make_flowfile(b"\x00corrupt\xff" + bytes([i % 251]),
                                    source=self.name, kind="junk")
                continue
            if (self.poison_rate
                    and r < self.junk_rate + self.poison_rate):
                art = synth_article(rng, i, rng.choice(_SOURCES_RSS))
                art["poison"] = 1
                yield make_flowfile(json.dumps(art, separators=(",", ":")),
                                    source=self.name, kind="poison",
                                    lang=art["lang"], origin=art["source"])
                continue
            if recent and r < self.junk_rate + self.poison_rate + self.dup_rate:
                art = rng.choice(recent)          # syndicated duplicate
            else:
                art = synth_article(rng, i, rng.choice(_SOURCES_RSS))
                recent.append(art)
                if len(recent) > 256:
                    recent.pop(0)
            yield make_flowfile(json.dumps(art, separators=(",", ":")),
                                source=self.name, kind="article",
                                lang=art["lang"], origin=art["source"])


class FirehoseSource:
    """Twitter-Streaming-API analogue: short texts, heavier duplicate rate
    (retweets), keyword attribute for the paper's filter rules."""

    _KEYWORDS = ("finance", "sports", "politics", "science", "weather")

    def __init__(self, count: int, seed: int = 1, dup_rate: float = 0.2,
                 name: str = "twitter") -> None:
        self.count = count
        self.seed = seed
        self.dup_rate = dup_rate
        self.name = name

    def __call__(self) -> Iterator[FlowFile]:
        rng = random.Random(self.seed)
        recent: list[str] = []
        for i in range(self.count):
            if recent and rng.random() < self.dup_rate:
                text = rng.choice(recent)         # retweet
            else:
                text = _sentence(rng, rng.randint(5, 24))
                recent.append(text)
                if len(recent) > 512:
                    recent.pop(0)
            kw = rng.choice(self._KEYWORDS)
            payload = json.dumps({"id": i, "text": text, "keyword": kw,
                                  "lang": rng.choice(_LANGS)},
                                 separators=(",", ":"))
            yield make_flowfile(payload, source=self.name, kind="tweet",
                                keyword=kw)


class WebSocketSource:
    """Custom socket feed of the case study — line-oriented opaque payloads."""

    def __init__(self, count: int, seed: int = 2, name: str = "websocket") -> None:
        self.count = count
        self.seed = seed
        self.name = name

    def __call__(self) -> Iterator[FlowFile]:
        rng = random.Random(self.seed)
        for i in range(self.count):
            yield make_flowfile(
                f"evt {i} {_sentence(rng, rng.randint(10, 40))}",
                source=self.name, kind="event")


def corpus_documents(n_docs: int, seed: int = 7) -> Iterator[str]:
    """Deterministic text corpus for the LM-training consumers."""
    rng = random.Random(seed)
    for i in range(n_docs):
        yield _sentence(rng, rng.randint(30, 300))
