"""Wire-real source connectors (paper §III.A: GetHTTP / ListenWebSocket).

PR 4's acquisition layer made the runtime live but every shipped connector
was simulated; these are the first :class:`~repro.core.acquisition
.SourceConnector`\\ s that speak real network protocols, driven *unchanged*
by :class:`~repro.core.acquisition.AcquisitionRuntime` — reconnect backoff,
cursor checkpoints, and watermarks work over real sockets exactly as they
do over ``SimulatedEndpoint``.

``HttpPollConnector`` (NiFi: GetHTTP; the paper's RSS pull path)
    A long-poller over ``http.client`` against a paginated *cursor feed*:
    ``GET <path>?cursor=K&max=N`` returns a JSON envelope of base64-framed
    records plus the next cursor; ``POST <ack_path>?cursor=K`` tells the
    server everything up to ``K`` is durably admitted. Conditional GETs are
    first-class: the client replays the server's ``ETag`` /
    ``Last-Modified`` via ``If-None-Match`` / ``If-Modified-Since`` and a
    ``304 Not Modified`` costs no body (the polite idle-poll of a feed that
    hasn't grown). A server response whose cursor is stale or malformed
    (doesn't advance by exactly the number of items served) is a protocol
    violation: the session is dropped and the runtime reconnects from the
    client's own cursor — the client's count, not the server's claim, is
    authoritative.

``WebSocketConnector`` (NiFi: ListenWebSocket / ConnectWebSocket)
    An RFC 6455 *client* over a plain ``socket``: real opening handshake
    (``Sec-WebSocket-Key`` → ``Sec-WebSocket-Accept`` validation), real
    frame codec (FIN/opcode bits, 7/16/64-bit lengths, mandatory
    client-to-server masking, fragmented-message reassembly, ping→pong,
    close frames). The subprotocol on top is pull-based so the connector
    contract holds: each ``poll`` sends one request frame and reads one
    (possibly fragmented) JSON envelope back; ``ack`` is fire-and-forget.
    The server may redeliver a bounded unacked suffix on reconnect
    (at-least-once endpoints) and announces the resume point in a hello
    frame, which feeds the ``redelivered`` duplicate gauge.

Wire format (shared with the in-repo test servers in
``tests/net_fixtures.py``): each record travels as
``{"i": canonical_index, "c": base64(content), "a": {attributes}}`` — the
attributes carry ``event.ts`` stamped by the server from the canonical
stream index, so event-time watermarks are exact end to end. Envelopes are
``{"items": [...], "cursor": "<emission index>", "end": bool,
"remaining": int}``.

Both connectors translate every transport failure (refused connection,
reset, short read mid-frame, torn chunked body, protocol violations) into
:class:`~repro.core.acquisition.ConnectorError`, which is exactly the
signal the runtime's reconnect-with-backoff machinery consumes.
"""
from __future__ import annotations

import base64
import hashlib
import http.client
import json
import os
import socket
import struct

from .acquisition import ConnectorError, EndOfStream, SourceConnector
from .flowfile import FlowFile

__all__ = ["HttpPollConnector", "WebSocketConnector",
           "flowfile_to_wire_item", "wire_item_to_flowfile",
           "WS_GUID", "ws_accept_key", "ws_encode_frame", "ws_read_frame",
           "ws_read_message", "recv_exact",
           "OP_CONT", "OP_TEXT", "OP_BINARY", "OP_CLOSE", "OP_PING",
           "OP_PONG"]


# ---------------------------------------------------------------------------
# Wire record framing (shared by connectors and the test feed servers)
# ---------------------------------------------------------------------------
def flowfile_to_wire_item(index: int, ff: FlowFile) -> dict:
    """One record as it travels in a feed envelope. Content is base64 —
    payloads may be arbitrary bytes (the RSS source emits binary junk
    records on purpose)."""
    return {"i": index,
            "c": base64.b64encode(ff.content).decode("ascii"),
            "a": dict(ff.attributes)}


def wire_item_to_flowfile(item: dict) -> FlowFile:
    return FlowFile(content=base64.b64decode(item["c"]),
                    attributes={str(k): str(v)
                                for k, v in item.get("a", {}).items()})


def _parse_envelope(raw: bytes, who: str) -> dict:
    try:
        env = json.loads(raw)
    except (ValueError, UnicodeDecodeError) as e:
        raise ConnectorError(f"{who}: malformed feed envelope: {e}") from e
    if not isinstance(env, dict) or not isinstance(env.get("items", []), list):
        raise ConnectorError(f"{who}: malformed feed envelope")
    return env


class _CursorFeedClient:
    """Shared cursor/gauge state and envelope bookkeeping for both
    connectors — the client-authoritative cursor protocol lives in exactly
    one place."""

    name: str

    def __init__(self) -> None:
        self._pos = 0
        self._remaining: int | None = None
        self._end_seen = False
        self.redelivered_total = 0

    def cursor(self) -> str | None:
        return str(self._pos)

    def lag(self) -> int | None:
        return self._remaining

    def redelivered(self) -> int:
        return self.redelivered_total

    def _consume_envelope(self, env: dict) -> list[FlowFile]:
        """Validate and absorb one feed envelope: advance the cursor,
        update the lag gauge, detect end-of-stream. The client's count is
        authoritative — the server's next-cursor must advance by exactly
        the records it served; anything else (stale, backwards,
        non-decimal) is a protocol violation that drops the session rather
        than silently skipping or re-reading records."""
        items = env.get("items", [])
        try:
            new_pos = int(env["cursor"])
        except (KeyError, TypeError, ValueError) as e:
            raise ConnectorError(
                f"{self.name}: invalid feed cursor "
                f"{env.get('cursor')!r}") from e
        if new_pos != self._pos + len(items):
            raise ConnectorError(
                f"{self.name}: stale feed cursor {new_pos} "
                f"(expected {self._pos + len(items)})")
        rem = env.get("remaining")
        self._remaining = int(rem) if rem is not None else None
        if env.get("end") and not items:
            self._end_seen = True
            raise EndOfStream(self.name)
        if not items:
            return []
        self._pos = new_pos
        return [wire_item_to_flowfile(it) for it in items]


# ---------------------------------------------------------------------------
# HTTP/RSS long-poller
# ---------------------------------------------------------------------------
class HttpPollConnector(_CursorFeedClient, SourceConnector):
    """Cursor-feed long-poller over ``http.client`` (see module docstring).

    The cursor token is the decimal emission index, owned client-side: every
    ``poll`` passes it explicitly, so a reconnect (or a rebuilt process
    resuming from a checkpoint) just asks for the suffix again — the server
    holds no per-client session state on the data path."""

    def __init__(self, name: str, host: str, port: int, *,
                 path: str = "/feed", ack_path: str = "/ack",
                 timeout: float = 10.0) -> None:
        super().__init__()
        self.name = name
        self.host = host
        self.port = port
        self.path = path
        self.ack_path = ack_path
        self.timeout = timeout
        self._conn: http.client.HTTPConnection | None = None
        self._high = 0            # highest emission index ever seen
        self._etag: str | None = None
        self._last_modified: str | None = None
        self.polls_304 = 0        # conditional-GET hits (observability)

    # -- SourceConnector -----------------------------------------------------
    def connect(self, cursor: str | None) -> None:
        self.close()                     # reconnect: drop any old session
        try:
            k = int(cursor) if cursor else 0
        except ValueError as e:
            raise ConnectorError(f"{self.name}: bad cursor {cursor!r}") from e
        conn = http.client.HTTPConnection(self.host, self.port,
                                          timeout=self.timeout)
        try:
            conn.connect()        # probe now: a refused TCP connect must
        except OSError as e:      # surface as a reconnect, not a poll error
            raise ConnectorError(f"{self.name}: connect: {e}") from e
        self._conn = conn
        if k < self._high:        # resuming behind what we already saw
            self.redelivered_total += self._high - k
        self._pos = k
        self._etag = None         # stale validators must not 304 a resume
        self._last_modified = None
        self._end_seen = False

    def _request(self, method: str, url: str,
                 headers: dict[str, str]) -> http.client.HTTPResponse:
        assert self._conn is not None
        try:
            self._conn.request(method, url, headers=headers)
            return self._conn.getresponse()
        except (http.client.HTTPException, OSError) as e:
            raise ConnectorError(f"{self.name}: {method} {url}: "
                                 f"{type(e).__name__}: {e}") from e

    def poll(self, max_records: int) -> list[FlowFile]:
        if self._conn is None:
            raise ConnectorError(f"{self.name}: not connected")
        if self._end_seen:
            raise EndOfStream(self.name)
        headers = {}
        if self._etag is not None:
            headers["If-None-Match"] = self._etag
        if self._last_modified is not None:
            headers["If-Modified-Since"] = self._last_modified
        resp = self._request(
            "GET", f"{self.path}?cursor={self._pos}&max={max_records}",
            headers)
        try:
            if resp.status == 304:
                resp.read()       # drain so the connection stays reusable
                self.polls_304 += 1
                return []
            body = resp.read()
        except (http.client.HTTPException, OSError) as e:
            raise ConnectorError(f"{self.name}: read: {e}") from e
        if resp.status != 200:
            raise ConnectorError(
                f"{self.name}: feed returned HTTP {resp.status}")
        self._etag = resp.getheader("ETag") or self._etag
        self._last_modified = (resp.getheader("Last-Modified")
                               or self._last_modified)
        ffs = self._consume_envelope(_parse_envelope(body, self.name))
        self._high = max(self._high, self._pos)
        return ffs

    def ack(self, cursor: str) -> None:
        if self._conn is None:
            raise ConnectorError(f"{self.name}: not connected")
        resp = self._request("POST", f"{self.ack_path}?cursor={int(cursor)}",
                             {})
        try:
            resp.read()
        except (http.client.HTTPException, OSError) as e:
            raise ConnectorError(f"{self.name}: ack read: {e}") from e
        if resp.status not in (200, 204):
            raise ConnectorError(
                f"{self.name}: ack returned HTTP {resp.status}")

    def close(self) -> None:
        if self._conn is not None:
            try:
                self._conn.close()
            finally:
                self._conn = None


# ---------------------------------------------------------------------------
# RFC 6455 frame codec (client side; the test server reuses it)
# ---------------------------------------------------------------------------
WS_GUID = "258EAFA5-E914-47DA-95CA-C5AB0DC85B11"

OP_CONT, OP_TEXT, OP_BINARY, OP_CLOSE, OP_PING, OP_PONG = \
    0x0, 0x1, 0x2, 0x8, 0x9, 0xA
_CONTROL_OPS = (OP_CLOSE, OP_PING, OP_PONG)

#: sanity cap on a declared frame length — a desynced peer (torn-frame
#: recovery is a first-class fault mode here) must not make recv_exact
#: buffer gigabytes off a bogus 64-bit length field
_MAX_FRAME_BYTES = 1 << 24


def ws_accept_key(key: str) -> str:
    """Sec-WebSocket-Accept for a client key (RFC 6455 §4.2.2)."""
    digest = hashlib.sha1((key + WS_GUID).encode("ascii")).digest()
    return base64.b64encode(digest).decode("ascii")


def ws_encode_frame(payload: bytes, opcode: int = OP_TEXT, *,
                    mask: bool, fin: bool = True) -> bytes:
    """Serialize one frame. Clients MUST mask (RFC 6455 §5.3); servers MUST
    NOT."""
    b0 = (0x80 if fin else 0) | opcode
    n = len(payload)
    if n < 126:
        header = struct.pack("!BB", b0, (0x80 if mask else 0) | n)
    elif n < 1 << 16:
        header = struct.pack("!BBH", b0, (0x80 if mask else 0) | 126, n)
    else:
        header = struct.pack("!BBQ", b0, (0x80 if mask else 0) | 127, n)
    if not mask:
        return header + payload
    key = os.urandom(4)
    masked = bytes(b ^ key[i & 3] for i, b in enumerate(payload))
    return header + key + masked


def recv_exact(sock: socket.socket, n: int) -> bytes:
    """Read exactly ``n`` bytes; a peer vanishing mid-message is a
    :class:`ConnectorError` (the reconnect signal), never a short read."""
    buf = bytearray()
    while len(buf) < n:
        try:
            chunk = sock.recv(n - len(buf))
        except OSError as e:
            raise ConnectorError(f"socket error mid-frame: {e}") from e
        if not chunk:
            raise ConnectorError(
                f"connection closed mid-frame ({len(buf)}/{n} bytes)")
        buf += chunk
    return bytes(buf)


def ws_read_frame(sock: socket.socket) -> tuple[bool, int, bytes]:
    """Read one frame → ``(fin, opcode, unmasked payload)``."""
    b0, b1 = recv_exact(sock, 2)
    fin = bool(b0 & 0x80)
    opcode = b0 & 0x0F
    masked = bool(b1 & 0x80)
    n = b1 & 0x7F
    if n == 126:
        (n,) = struct.unpack("!H", recv_exact(sock, 2))
    elif n == 127:
        (n,) = struct.unpack("!Q", recv_exact(sock, 8))
    if n > _MAX_FRAME_BYTES:
        raise ConnectorError(f"frame length {n} exceeds "
                             f"{_MAX_FRAME_BYTES} (desynced peer?)")
    key = recv_exact(sock, 4) if masked else None
    payload = recv_exact(sock, n) if n else b""
    if key is not None:
        payload = bytes(b ^ key[i & 3] for i, b in enumerate(payload))
    return fin, opcode, payload


def ws_read_message(sock: socket.socket, *,
                    mask_replies: bool) -> tuple[int, bytes]:
    """Read one complete message, reassembling continuation fragments and
    transparently answering pings (control frames may interleave with the
    fragments of a data message — RFC 6455 §5.4/§5.5). Returns
    ``(data opcode, payload)``; a close frame returns ``(OP_CLOSE, code+reason)``.
    ``mask_replies`` is True on the client side (pongs must be masked)."""
    opcode: int | None = None
    parts: list[bytes] = []
    while True:
        fin, op, payload = ws_read_frame(sock)
        if op in _CONTROL_OPS:
            if not fin:
                raise ConnectorError("fragmented control frame")
            if op == OP_PING:
                sock.sendall(ws_encode_frame(payload, OP_PONG,
                                             mask=mask_replies))
                continue
            if op == OP_PONG:
                continue
            return OP_CLOSE, payload
        if opcode is None:
            if op == OP_CONT:
                raise ConnectorError("continuation frame with no message")
            opcode = op
        elif op != OP_CONT:
            raise ConnectorError("interleaved data messages")
        parts.append(payload)
        if fin:
            return opcode, b"".join(parts)


# ---------------------------------------------------------------------------
# WebSocket client connector
# ---------------------------------------------------------------------------
class WebSocketConnector(_CursorFeedClient, SourceConnector):
    """RFC 6455 client speaking the pull-based feed subprotocol (see module
    docstring). The cursor token is the decimal emission index; the resume
    point actually granted by the server (which may rewind by its
    redelivery window — at-least-once endpoints re-send their unacked tail)
    arrives in the post-handshake hello frame."""

    def __init__(self, name: str, host: str, port: int, *,
                 path: str = "/stream", timeout: float = 10.0) -> None:
        super().__init__()
        self.name = name
        self.host = host
        self.port = port
        self.path = path
        self.timeout = timeout
        self._sock: socket.socket | None = None

    # -- handshake -----------------------------------------------------------
    def connect(self, cursor: str | None) -> None:
        self.close()                     # reconnect: drop any old session
        try:
            k = int(cursor) if cursor else 0
        except ValueError as e:
            raise ConnectorError(f"{self.name}: bad cursor {cursor!r}") from e
        key = base64.b64encode(os.urandom(16)).decode("ascii")
        request = (
            f"GET {self.path}?cursor={k} HTTP/1.1\r\n"
            f"Host: {self.host}:{self.port}\r\n"
            "Upgrade: websocket\r\n"
            "Connection: Upgrade\r\n"
            f"Sec-WebSocket-Key: {key}\r\n"
            "Sec-WebSocket-Version: 13\r\n\r\n")
        try:
            sock = socket.create_connection((self.host, self.port),
                                            timeout=self.timeout)
        except OSError as e:
            raise ConnectorError(f"{self.name}: connect: {e}") from e
        try:
            sock.sendall(request.encode("ascii"))
            status, headers = self._read_http_response(sock)
            if status != 101:
                raise ConnectorError(
                    f"{self.name}: handshake rejected: HTTP {status}")
            if headers.get("sec-websocket-accept") != ws_accept_key(key):
                raise ConnectorError(
                    f"{self.name}: bad Sec-WebSocket-Accept (not a "
                    "websocket endpoint?)")
            # hello frame: the resume point the server actually granted
            op, payload = ws_read_message(sock, mask_replies=True)
            if op == OP_CLOSE:
                raise ConnectorError(f"{self.name}: closed during hello")
            hello = _parse_envelope(payload, self.name)
            resumed = int(hello.get("resumed", k))
            if resumed > k:
                raise ConnectorError(
                    f"{self.name}: server resumed at {resumed} "
                    f"past requested cursor {k} (records would be lost)")
            self.redelivered_total += k - resumed
            self._pos = resumed
            rem = hello.get("remaining")
            self._remaining = int(rem) if rem is not None else None
        except (ConnectorError, OSError, ValueError) as e:
            sock.close()
            if isinstance(e, ConnectorError):
                raise
            raise ConnectorError(f"{self.name}: handshake: {e}") from e
        self._sock = sock
        self._end_seen = False

    @staticmethod
    def _read_http_response(sock: socket.socket
                            ) -> tuple[int, dict[str, str]]:
        """Read status line + headers of the handshake response (no body —
        a 101 never has one). Peek-then-consume in chunks: the server's
        first frame (hello) may already sit behind the header terminator,
        and it must stay in the socket for the frame reader."""
        raw = bytearray()
        while True:
            try:
                chunk = sock.recv(4096, socket.MSG_PEEK)
            except OSError as e:
                raise ConnectorError(f"handshake read: {e}") from e
            if not chunk:
                raise ConnectorError("connection closed during handshake")
            i = (bytes(raw) + chunk).find(b"\r\n\r\n")
            if i >= 0:
                recv_exact(sock, i + 4 - len(raw))   # consume headers only
                raw = (raw + chunk)[:i + 4]
                break
            recv_exact(sock, len(chunk))
            raw += chunk
            if len(raw) > 1 << 16:
                raise ConnectorError("oversized handshake response")
        head = bytes(raw).split(b"\r\n\r\n", 1)[0].decode("latin-1")
        lines = head.split("\r\n")
        parts = lines[0].split(None, 2)
        if len(parts) < 2 or not parts[1].isdigit():
            raise ConnectorError(f"malformed status line {lines[0]!r}")
        headers = {}
        for line in lines[1:]:
            name, _, value = line.partition(":")
            headers[name.strip().lower()] = value.strip()
        return int(parts[1]), headers

    # -- data path -----------------------------------------------------------
    def _send(self, obj: dict) -> None:
        assert self._sock is not None
        try:
            self._sock.sendall(ws_encode_frame(
                json.dumps(obj, separators=(",", ":")).encode(),
                OP_TEXT, mask=True))
        except OSError as e:
            raise ConnectorError(f"{self.name}: send: {e}") from e

    def poll(self, max_records: int) -> list[FlowFile]:
        if self._sock is None:
            raise ConnectorError(f"{self.name}: not connected")
        if self._end_seen:
            raise EndOfStream(self.name)
        self._send({"cmd": "poll", "max": max_records})
        op, payload = ws_read_message(self._sock, mask_replies=True)
        if op == OP_CLOSE:
            raise ConnectorError(f"{self.name}: server closed the session")
        return self._consume_envelope(_parse_envelope(payload, self.name))

    def ack(self, cursor: str) -> None:
        if self._sock is None:
            raise ConnectorError(f"{self.name}: not connected")
        self._send({"cmd": "ack", "cursor": int(cursor)})

    def close(self) -> None:
        sock, self._sock = self._sock, None
        if sock is not None:
            try:
                sock.sendall(ws_encode_frame(struct.pack("!H", 1000),
                                             OP_CLOSE, mask=True))
            except OSError:
                pass
            sock.close()
