"""FlowGraph: assembles processors + connections into a running dataflow
(the NiFi canvas, paper Fig. 1/2) with provenance wired through and SEND
events recorded at sinks."""
from __future__ import annotations

import threading
import time
from typing import Callable

from .connection import Connection
from .flowfile import FlowFile
from .processor import FlowNode, Processor, Source, _Worker
from .provenance import ProvenanceRepository


class FlowError(RuntimeError):
    pass


class FlowGraph:
    def __init__(self, name: str = "flow",
                 provenance: ProvenanceRepository | None = None) -> None:
        self.name = name
        self.provenance = provenance or ProvenanceRepository()
        self.nodes: dict[str, FlowNode] = {}
        self.connections: list[Connection] = []
        self.stopping = threading.Event()
        self._workers: list[_Worker] = []
        self._errors: list[tuple[str, BaseException]] = []
        self._lock = threading.Lock()

    # -- assembly -------------------------------------------------------------
    def add(self, processor: Processor) -> Processor:
        if processor.name in self.nodes:
            raise FlowError(f"duplicate processor name {processor.name!r}")
        self.nodes[processor.name] = FlowNode(processor)
        return processor

    def connect(self, src: Processor | str, relationship: str,
                dst: Processor | str,
                object_threshold: int | None = None,
                size_threshold: int | None = None,
                prioritizer: Callable[[FlowFile], float] | None = None
                ) -> Connection:
        src_name = src if isinstance(src, str) else src.name
        dst_name = dst if isinstance(dst, str) else dst.name
        if src_name not in self.nodes or dst_name not in self.nodes:
            raise FlowError("connect() before add()")
        src_node, dst_node = self.nodes[src_name], self.nodes[dst_name]
        if relationship not in src_node.processor.relationships:
            raise FlowError(
                f"{src_name} has no relationship {relationship!r} "
                f"(has {src_node.processor.relationships})")
        if isinstance(dst_node.processor, Source):
            raise FlowError(f"{dst_name} is a source; cannot be a destination")
        kwargs = {}
        if object_threshold is not None:
            kwargs["object_threshold"] = object_threshold
        if size_threshold is not None:
            kwargs["size_threshold"] = size_threshold
        if dst_node.input is None:
            conn = Connection(f"{src_name}:{relationship}->{dst_name}",
                              prioritizer=prioritizer, **kwargs)
            dst_node.input = conn
            self.connections.append(conn)
        else:
            # fan-in: multiple upstreams share the destination's input queue
            conn = dst_node.input
        src_node.outputs.setdefault(relationship, []).append(conn)
        dst_node.upstreams.append(src_node)
        return conn

    # -- execution --------------------------------------------------------------
    def _record_error(self, component: str, err: BaseException) -> None:
        with self._lock:
            self._errors.append((component, err))
        self.stopping.set()

    def start(self) -> None:
        self._validate()
        for node in self.nodes.values():
            w = _Worker(node, self)
            self._workers.append(w)
        for w in self._workers:
            w.start()

    def _validate(self) -> None:
        for node in self.nodes.values():
            if not isinstance(node.processor, Source) and node.input is None:
                raise FlowError(
                    f"processor {node.processor.name!r} has no input connection")

    def stop(self) -> None:
        self.stopping.set()
        self.join(timeout=10.0)

    def join(self, timeout: float | None = None) -> None:
        deadline = None if timeout is None else time.monotonic() + timeout
        for w in self._workers:
            remaining = None
            if deadline is not None:
                remaining = max(0.0, deadline - time.monotonic())
            w.join(remaining)
        if self._errors:
            comp, err = self._errors[0]
            raise FlowError(f"processor {comp!r} failed: {err!r}") from err

    def run_to_completion(self, timeout: float = 300.0) -> None:
        """Start, wait for all sources to exhaust and queues to drain."""
        self.start()
        self.join(timeout=timeout)
        alive = [w.name for w in self._workers if w.is_alive()]
        if alive:
            self.stopping.set()
            raise FlowError(f"flow did not complete; alive: {alive}")

    # -- observability ------------------------------------------------------------
    def status(self) -> dict:
        return {
            "processors": {n: fn.processor.stats.snapshot()
                           for n, fn in self.nodes.items()},
            "connections": [c.snapshot() for c in self.connections],
            "provenance_counts": self.provenance.counts(),
        }
