"""FlowGraph: assembles processors + connections into a running dataflow
(the NiFi canvas, paper Fig. 1/2) with provenance wired through and SEND
events recorded at sinks.

The graph is also the *supervisor* (paper: robustness in handling failures):
``add(proc, restart_policy=...)`` sets a per-processor restart budget,
``connect(..., max_retries=N)`` arms record-level retry on a connection,
``connect(..., durable=log)`` makes a connection WAL-backed (crash recovery
from the last acked frontier), and ``route_dead_letters_to(dlq)`` wires the
quarantine path for poison/exhausted records. All knobs default off — a
plain graph keeps the seed's fail-fast semantics.
"""
from __future__ import annotations

import itertools
import threading
import time
from typing import Callable, Optional, TYPE_CHECKING

from .connection import Connection, DurableConnection
from .flowfile import FlowFile

if TYPE_CHECKING:
    from .acquisition import AcquisitionRuntime
    from .logstore import LogStore
from .processor import (ATTR_TRACE_ID, FlowNode, Processor, RestartPolicy,
                        Source, _Worker)
from .provenance import ProvenanceRepository
from .telemetry import MetricsRegistry


class FlowError(RuntimeError):
    pass


#: FlowFile attribute carrying the admission priority class (stamped by the
#: acquisition runtime for ingresses opened with ``priority != 0``). Higher
#: values are delivered first (queue prioritizer) and shed last (congestion
#: shedding, see core/acquisition.py).
ATTR_INGRESS_PRIORITY = "ingress.priority"


def ingress_priority(ff: FlowFile) -> int:
    """Priority class stamped at admission (0 when never stamped)."""
    return int(ff.attributes.get(ATTR_INGRESS_PRIORITY, "0"))


class _ExternalUpstream:
    """Sentinel upstream for records admitted from outside the graph (a live
    connector's poll loop). Quacks like a FlowNode for the one thing the
    termination check reads — ``done`` — so the destination worker keeps
    draining until the external producer declares end-of-stream."""

    def __init__(self, name: str) -> None:
        self.name = name
        self.done = threading.Event()


class IngressHandle:
    """Write side of an external admission point (see
    :meth:`FlowGraph.add_ingress`). The producer offers FlowFiles into
    ``connection`` (``offer_batch`` — blocking there IS the backpressure)
    and calls :meth:`complete` exactly once when its stream is finished, so
    the destination worker can drain and terminate."""

    def __init__(self, name: str, connection: Connection,
                 upstream: _ExternalUpstream, priority: int = 0) -> None:
        self.name = name
        self.connection = connection
        #: admission priority class — the producer stamps it onto every
        #: record it admits (``ATTR_INGRESS_PRIORITY``); higher wins
        self.priority = priority
        self._upstream = upstream

    def complete(self) -> None:
        self._upstream.done.set()

    @property
    def completed(self) -> bool:
        return self._upstream.done.is_set()


class FlowGraph:
    def __init__(self, name: str = "flow",
                 provenance: ProvenanceRepository | None = None,
                 telemetry: bool = True,
                 trace_sample_rate: float = 0.0,
                 clock: Callable[[], float] | None = None) -> None:
        self.name = name
        #: monotonic source shared with the graph's workers (join deadlines,
        #: source linger, retry penalties); injectable for deterministic tests
        self._clock: Callable[[], float] = \
            clock if clock is not None else time.monotonic
        self.provenance = provenance or ProvenanceRepository()
        self.nodes: dict[str, FlowNode] = {}
        self.connections: list[Connection] = []
        self.stopping = threading.Event()
        self._workers: list[_Worker] = []
        self._errors: list[tuple[str, BaseException]] = []
        self._lock = threading.Lock()
        self._dlq_conn: Connection | None = None
        self._dlq_node: FlowNode | None = None
        self._ingresses: list[IngressHandle] = []
        #: live-source runtime feeding this graph (set by AcquisitionRuntime;
        #: surfaces per-connector stats through status())
        self.acquisition: "AcquisitionRuntime | None" = None
        #: per-process metric surface (paper §IV.C status history); ``None``
        #: when built with ``telemetry=False`` — every engine hook is gated
        #: on that, so an untelemetered graph pays zero instrumentation cost
        self.telemetry: MetricsRegistry | None = \
            MetricsRegistry() if telemetry else None
        if self.telemetry is not None:
            self.telemetry.register_source("processor", self._processor_gauges)
            self.telemetry.register_source(
                "connection",
                lambda: {c.name: c.snapshot() for c in self.connections})
        # trace sampling: every k-th admitted record is stamped (k =
        # round(1/rate)); 0 disables. The counter is a shared stride across
        # all admission points, so the sample is uniform over admissions.
        if trace_sample_rate < 0.0 or trace_sample_rate > 1.0:
            raise ValueError("trace_sample_rate must be within [0, 1]")
        self.trace_sample_rate = trace_sample_rate
        self._trace_every = (0 if trace_sample_rate <= 0.0
                             else max(1, round(1.0 / trace_sample_rate)))
        self._trace_counter = itertools.count(1)

    def _processor_gauges(self) -> dict:
        return {n: fn.processor.stats.snapshot()
                for n, fn in self.nodes.items()}

    # -- assembly -------------------------------------------------------------
    def add(self, processor: Processor,
            restart_policy: RestartPolicy | None = None,
            min_workers: int | None = None,
            max_workers: int | None = None) -> Processor:
        """Register a processor. ``min_workers``/``max_workers`` override the
        class-level elastic pool bounds (see core/processor.py docstring);
        eligibility is validated at :meth:`start`, once the input connection
        type is known."""
        if processor.name in self.nodes:
            raise FlowError(f"duplicate processor name {processor.name!r}")
        node = FlowNode(
            processor, restart_policy,
            min_workers=min_workers, max_workers=max_workers)
        if self.telemetry is not None:
            node.proc_hist = self.telemetry.histogram(
                "process_seconds", processor=processor.name)
        self.nodes[processor.name] = node
        return processor

    def connect(self, src: Processor | str, relationship: str,
                dst: Processor | str,
                object_threshold: int | None = None,
                size_threshold: int | None = None,
                prioritizer: Callable[[FlowFile], float] | None = None,
                max_retries: int | None = None,
                retry_penalty_sec: float | None = None,
                durable: "Optional[LogStore]" = None
                ) -> Connection:
        """Wire ``src.relationship -> dst``. ``max_retries`` arms record
        retry on the destination's input; ``durable`` (any ``LogStore`` —
        single-host or replicated) makes that input a WAL-backed
        :class:`DurableConnection`. On fan-in the first ``connect`` to a
        destination fixes its queue settings."""
        src_name = src if isinstance(src, str) else src.name
        dst_name = dst if isinstance(dst, str) else dst.name
        if src_name not in self.nodes or dst_name not in self.nodes:
            raise FlowError("connect() before add()")
        src_node, dst_node = self.nodes[src_name], self.nodes[dst_name]
        if relationship not in src_node.processor.relationships:
            raise FlowError(
                f"{src_name} has no relationship {relationship!r} "
                f"(has {src_node.processor.relationships})")
        if isinstance(dst_node.processor, Source):
            raise FlowError(f"{dst_name} is a source; cannot be a destination")
        kwargs = {}
        if object_threshold is not None:
            kwargs["object_threshold"] = object_threshold
        if size_threshold is not None:
            kwargs["size_threshold"] = size_threshold
        if max_retries is not None:
            kwargs["max_retries"] = max_retries
        if retry_penalty_sec is not None:
            kwargs["retry_penalty_sec"] = retry_penalty_sec
        if dst_node.input is None:
            name = f"{src_name}:{relationship}->{dst_name}"
            if durable is not None:
                if prioritizer is not None:
                    raise FlowError("durable connections are FIFO-only")
                conn = DurableConnection(name, durable, **kwargs)
            else:
                conn = Connection(name, prioritizer=prioritizer, **kwargs)
            if self.telemetry is not None:
                conn.attach_dwell_histogram(self.telemetry.histogram(
                    "queue_dwell_seconds",
                    processor=src_name, relationship=relationship))
            dst_node.input = conn
            self.connections.append(conn)
        else:
            # fan-in: multiple upstreams share the destination's input queue
            conn = dst_node.input
        src_node.outputs.setdefault(relationship, []).append(conn)
        dst_node.upstreams.append(src_node)
        return conn

    def add_ingress(self, dst: Processor | str, *,
                    name: str | None = None,
                    priority: int = 0,
                    object_threshold: int | None = None,
                    size_threshold: int | None = None,
                    max_retries: int | None = None,
                    retry_penalty_sec: float | None = None,
                    durable: "Optional[LogStore]" = None) -> IngressHandle:
        """Open an external admission point into ``dst``'s input connection —
        how live acquisition (``core/acquisition.py``) feeds the graph
        without being a thread-per-Source processor. Creates the connection
        when ``dst`` has none yet (same queue knobs as :meth:`connect`,
        including ``durable`` WAL backing); later calls — or a mix of
        ingresses and ordinary upstream connections — fan into the same
        queue. Each call returns its own handle: the destination terminates
        only after *every* handle completed, every graph upstream finished,
        and the queue drained.

        ``priority`` declares the admission priority class: the producer
        stamps it onto every record (``ATTR_INGRESS_PRIORITY``), a priority
        queue delivers higher classes first, and congestion shedding drops
        lower classes first. The first ingress to *create* a non-durable
        connection with any nonzero priority in play installs the priority
        prioritizer; durable connections stay FIFO (the WAL frontier is a
        count prefix), so priority there only steers the shed path."""
        dst_name = dst if isinstance(dst, str) else dst.name
        if dst_name not in self.nodes:
            raise FlowError("add_ingress() before add()")
        dst_node = self.nodes[dst_name]
        if isinstance(dst_node.processor, Source):
            raise FlowError(f"{dst_name} is a source; cannot be a destination")
        if dst_node.input is None:
            kwargs = {}
            if object_threshold is not None:
                kwargs["object_threshold"] = object_threshold
            if size_threshold is not None:
                kwargs["size_threshold"] = size_threshold
            if max_retries is not None:
                kwargs["max_retries"] = max_retries
            if retry_penalty_sec is not None:
                kwargs["retry_penalty_sec"] = retry_penalty_sec
            conn_name = f"__ingress__->{dst_name}"
            if durable is not None:
                conn = DurableConnection(conn_name, durable, **kwargs)
            else:
                prioritizer = None
                if priority != 0:
                    prioritizer = lambda ff: -ingress_priority(ff)  # noqa: E731
                conn = Connection(conn_name, prioritizer=prioritizer, **kwargs)
            if self.telemetry is not None:
                conn.attach_dwell_histogram(self.telemetry.histogram(
                    "queue_dwell_seconds",
                    processor=dst_name, relationship="ingress"))
            dst_node.input = conn
            self.connections.append(conn)
        elif (priority != 0
              and not isinstance(dst_node.input, DurableConnection)):
            # a later prioritized ingress fanning into an existing FIFO
            # queue upgrades it to priority ordering (no-op if one is
            # already installed)
            dst_node.input.install_prioritizer(
                lambda ff: -ingress_priority(ff))
        ingress_name = name or f"ingress-{len(self._ingresses)}->{dst_name}"
        upstream = _ExternalUpstream(ingress_name)
        dst_node.upstreams.append(upstream)
        handle = IngressHandle(ingress_name, dst_node.input, upstream,
                               priority=priority)
        self._ingresses.append(handle)
        return handle

    def route_dead_letters_to(self, dlq: Processor | str,
                              object_threshold: int | None = None) -> Connection:
        """Declare ``dlq`` (an already-``add``-ed processor, typically a
        ``DeadLetterQueue``) as the graph-wide quarantine: any processor's
        exhausted/poison records are offered to its input connection. The
        node is kept alive until every other node finishes."""
        name = dlq if isinstance(dlq, str) else dlq.name
        if name not in self.nodes:
            raise FlowError("route_dead_letters_to() before add()")
        node = self.nodes[name]
        if isinstance(node.processor, Source):
            raise FlowError(f"{name} is a source; cannot be a dead-letter sink")
        if node.input is None:
            kwargs = {}
            if object_threshold is not None:
                kwargs["object_threshold"] = object_threshold
            node.input = Connection(f"__dead_letters__->{name}", **kwargs)
            if self.telemetry is not None:
                node.input.attach_dwell_histogram(self.telemetry.histogram(
                    "queue_dwell_seconds",
                    processor=name, relationship="dead_letters"))
            self.connections.append(node.input)
        elif object_threshold is not None:
            raise FlowError(
                f"{name} already has an input connection; "
                "object_threshold cannot be applied retroactively")
        self._dlq_conn = node.input
        self._dlq_node = node
        return node.input

    # -- execution --------------------------------------------------------------
    def _record_error(self, component: str, err: BaseException) -> None:
        with self._lock:
            self._errors.append((component, err))
        self.stopping.set()

    def start(self) -> None:
        self._validate()
        if self._dlq_node is not None:
            # the quarantine can receive from ANY node: it must outlive all
            # of them before its drain-and-done termination check may pass
            self._dlq_node.upstreams = [n for n in self.nodes.values()
                                        if n is not self._dlq_node]
        if self.telemetry is not None:
            # terminal nodes are where a record "lands": stamp ingest→land
            # latency there, measured against the FlowFile's admission time
            # (entry_ts survives log round-trips, so fabric workers report
            # true end-to-end latency, not post-replay latency)
            for node in self.nodes.values():
                if not node.outputs and node.e2e_hist is None:
                    node.e2e_hist = self.telemetry.histogram(
                        "ingest_to_land_seconds",
                        processor=node.processor.name)
        for node in self.nodes.values():
            w = _Worker(node, self)
            self._workers.append(w)
        for w in self._workers:
            w.start()

    def _validate(self) -> None:
        for node in self.nodes.values():
            proc = node.processor
            if not isinstance(proc, Source) and node.input is None:
                raise FlowError(
                    f"processor {proc.name!r} has no input connection")
            if node.max_workers > 1:
                # pool eligibility (see core/processor.py docstring): the
                # combinations below are unsound, not merely slow
                if isinstance(proc, Source):
                    raise FlowError(
                        f"{proc.name!r}: sources cannot run a worker pool "
                        "(one replayable generator, one cursor)")
                if isinstance(node.input, DurableConnection):
                    raise FlowError(
                        f"{proc.name!r}: worker pools are unsupported on a "
                        "durable input — the acked frontier is a count "
                        "prefix, and concurrent out-of-order acks would "
                        "cover unsettled records")
                if proc.buffers_across_triggers:
                    raise FlowError(
                        f"{proc.name!r}: buffers_across_triggers processors "
                        "hold cross-trigger state; a worker pool would "
                        "interleave it")
                if proc.idle_trigger_sec is not None:
                    raise FlowError(
                        f"{proc.name!r}: idle-triggered processors are "
                        "single-threaded state machines; a worker pool "
                        "would fire their empty trigger concurrently")

    def stop(self) -> None:
        self.stopping.set()
        self.join(timeout=10.0)

    def join(self, timeout: float | None = None) -> None:
        deadline = None if timeout is None else self._clock() + timeout
        for w in self._workers:
            remaining = None
            if deadline is not None:
                remaining = max(0.0, deadline - self._clock())
            w.join(remaining)
        if self._errors:
            comp, err = self._errors[0]
            raise FlowError(f"processor {comp!r} failed: {err!r}") from err

    def run_to_completion(self, timeout: float = 300.0) -> None:
        """Start, wait for all sources to exhaust and queues to drain."""
        self.start()
        self.join(timeout=timeout)
        alive = self.alive_workers()
        if alive:
            self.stopping.set()
            raise FlowError(f"flow did not complete; alive: {alive}")

    # -- observability ------------------------------------------------------------
    def alive_workers(self) -> list[str]:
        """Names of worker threads still running (empty once drained)."""
        return [w.name for w in self._workers if w.is_alive()]
    def status(self) -> dict:
        procs = {}
        for n, fn in self.nodes.items():
            snap = fn.processor.stats.snapshot()
            snap["state"] = fn.state
            snap["pending_retries"] = len(fn.pending_retries)
            procs[n] = snap
        out = {
            "processors": procs,
            "connections": [c.snapshot() for c in self.connections],
            "provenance_counts": self.provenance.counts(),
            "failed": sorted(n for n, fn in self.nodes.items()
                             if fn.state == "FAILED"),
            "telemetry": (self.telemetry.summaries()
                          if self.telemetry is not None else {}),
        }
        if self.acquisition is not None:
            out["acquisition"] = self.acquisition.status()
        return out

    # -- tracing (paper Fig. 4: lineage, extended with per-hop timing) -------
    def sample_trace(self, ffs: list[FlowFile]) -> list[FlowFile]:
        """Stamp every k-th record (k = round(1/``trace_sample_rate``)) with
        :data:`ATTR_TRACE_ID` at an admission point. Traced records get a
        timed span event recorded per hop (see ``_Worker._process_batch``);
        identity passthrough when tracing is off."""
        if self._trace_every <= 0 or not ffs:
            return ffs
        out = list(ffs)
        for i, ff in enumerate(out):
            if next(self._trace_counter) % self._trace_every == 0:
                out[i] = ff.derive(
                    attributes={ATTR_TRACE_ID: ff.lineage_id})
        return out

    def trace_spans(self, trace_id: str) -> list[dict]:
        """Timed span tree of one traced record, reconstructed from its
        provenance lineage: every ``span`` event this graph recorded for it,
        in time order, with the hop's batch-amortized elapsed time. Each
        entry carries ``uuid``/``parent`` so callers can rebuild the
        derivation tree; the flat list is already the Fig. 4 path."""
        spans = []
        for ev in self.provenance.lineage(trace_id):
            if not ev.details.startswith("span "):
                continue
            fields = dict(kv.split("=", 1)
                          for kv in ev.details.split()[1:] if "=" in kv)
            spans.append({
                "component": ev.component,
                "event_type": ev.event_type,
                "ts": ev.ts,
                "uuid": ev.flowfile_uuid,
                "elapsed_us": int(fields.get("elapsed_us", 0)),
                "batch": int(fields.get("batch", 1)),
            })
        spans.sort(key=lambda s: s["ts"])
        return spans
