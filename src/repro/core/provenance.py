"""Provenance repository (paper §III / Fig. 4).

Every significant event in a FlowFile's life is recorded: CREATE (entered the
fabric), TRANSFORM (content/attributes changed), ROUTE (sent down a named
relationship), SEND (left the fabric to a sink/log), DROP (filtered out),
REPLAY (re-emitted from the log). Events are grouped by ``lineage_id`` so the
full path of a logical record can be walked — NiFi's data-lineage view.

The repository is an in-memory ring with optional JSONL spill, bounded so a
hot path never blocks on provenance (the paper notes the provenance repo is a
performance governor; we make recording O(1) and lock-light).

With a spill configured, lineage queries are **indexed**: every spilled
event's byte offset is recorded in a per-lineage-id map, so ``lineage()``
seeks straight to that record's events instead of linearly scanning the ring
— and it sees the *full* history of the record, including events the bounded
ring evicted long ago (Fig. 4 queries at scale). A pre-existing spill file
is indexed once at open.
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from pathlib import Path
from typing import Iterable, Iterator

EVENT_TYPES = ("CREATE", "TRANSFORM", "ROUTE", "SEND", "DROP", "REPLAY",
               "FETCH", "COMMIT")


@dataclass(frozen=True, slots=True)
class ProvenanceEvent:
    event_type: str
    flowfile_uuid: str
    lineage_id: str
    component: str                      # processor / connection / sink name
    ts: float = field(default_factory=time.time)
    details: str = ""

    def to_json(self) -> str:
        return json.dumps({
            "event_type": self.event_type, "flowfile_uuid": self.flowfile_uuid,
            "lineage_id": self.lineage_id, "component": self.component,
            "ts": self.ts, "details": self.details,
        }, separators=(",", ":"))


class ProvenanceRepository:
    """Bounded, thread-safe event store with lineage queries."""

    def __init__(self, capacity: int = 100_000,
                 spill_path: str | Path | None = None,
                 route_sample: int = 1) -> None:
        """``route_sample``: record 1-in-N ROUTE/TRANSFORM events (lineage
        endpoints CREATE/SEND/DROP are always recorded; counts stay exact).
        A scalability knob for very hot flows — §Perf measured +9% ingest
        throughput at N=16 with endpoint lineage intact."""
        self._events: deque[ProvenanceEvent] = deque(maxlen=capacity)
        self._lock = threading.Lock()
        self._counts: dict[str, int] = {t: 0 for t in EVENT_TYPES}
        self._spill_path = Path(spill_path) if spill_path else None
        # lineage id -> byte offsets of that record's events in the spill
        # file (jsonl lines are pure-ASCII json, so char len == byte len)
        self._spill_index: dict[str, list[int]] = {}
        self._spill_pos = 0
        self._spill = None
        if self._spill_path is not None:
            self._index_existing_spill()
            self._spill = open(self._spill_path, "a", buffering=1 << 20)
        self.route_sample = max(1, route_sample)
        self._route_seen = 0

    def _index_existing_spill(self) -> None:
        """One-time scan of a pre-existing spill file (append mode keeps its
        events queryable across restarts)."""
        if not self._spill_path.exists():
            return
        pos = 0
        with open(self._spill_path, "rb") as f:
            for line in f:
                if not line.endswith(b"\n"):
                    break                       # torn tail from a crash
                try:
                    lid = json.loads(line)["lineage_id"]
                except (ValueError, KeyError):
                    lid = None
                if lid is not None:
                    self._spill_index.setdefault(lid, []).append(pos)
                pos += len(line)
        if pos != self._spill_path.stat().st_size:
            with open(self._spill_path, "r+b") as f:
                f.truncate(pos)                 # drop the torn suffix
        self._spill_pos = pos

    def _spill_locked(self, ev: ProvenanceEvent) -> None:
        line = ev.to_json() + "\n"
        self._spill_index.setdefault(ev.lineage_id, []).append(self._spill_pos)
        self._spill_pos += len(line)
        self._spill.write(line)

    # -- recording -----------------------------------------------------------
    def record(self, event_type: str, flowfile, component: str,
               details: str = "") -> None:
        if event_type not in self._counts:
            raise ValueError(f"unknown provenance event type {event_type!r}")
        if self.route_sample > 1 and event_type in ("ROUTE", "TRANSFORM"):
            with self._lock:
                self._route_seen += 1
                if self._route_seen % self.route_sample:
                    self._counts[event_type] += 1   # counts stay exact
                    return
        ev = ProvenanceEvent(event_type=event_type,
                             flowfile_uuid=flowfile.uuid,
                             lineage_id=flowfile.lineage_id,
                             component=component, details=details)
        with self._lock:
            self._events.append(ev)
            self._counts[event_type] += 1
            if self._spill is not None:
                self._spill_locked(ev)

    def record_batch(self, event_type: str, flowfiles, component: str,
                     details: str = "") -> None:
        """Record one event per FlowFile under a single lock acquisition.

        The hot-path variant: a contended per-event lock forces a thread
        context switch per record across the whole flow graph; batching keeps
        the repository off the ingest critical path (the paper flags the
        provenance repo as a performance governor)."""
        if event_type not in self._counts:
            raise ValueError(f"unknown provenance event type {event_type!r}")
        n_total = len(flowfiles)
        with self._lock:
            if self.route_sample > 1 and event_type in ("ROUTE", "TRANSFORM"):
                start = self._route_seen
                self._route_seen += n_total
                flowfiles = [ff for i, ff in enumerate(flowfiles, start + 1)
                             if i % self.route_sample == 0]
            evs = [ProvenanceEvent(event_type=event_type,
                                   flowfile_uuid=ff.uuid,
                                   lineage_id=ff.lineage_id,
                                   component=component, details=details)
                   for ff in flowfiles]
            self._events.extend(evs)
            self._counts[event_type] += n_total      # counts stay exact
            if self._spill is not None:
                for ev in evs:
                    self._spill_locked(ev)

    # -- queries (paper: troubleshooting / optimization / replay points) ----
    def lineage(self, lineage_id: str) -> list[ProvenanceEvent]:
        """All events of one logical record. With a spill configured this is
        an indexed lookup — O(events of this lineage), not O(all events) —
        and it includes events the bounded in-memory ring already evicted."""
        with self._lock:
            if self._spill is None:
                return [e for e in self._events if e.lineage_id == lineage_id]
            offsets = list(self._spill_index.get(lineage_id, ()))
            self._spill.flush()     # make buffered lines readable
        out: list[ProvenanceEvent] = []
        with open(self._spill_path, "rb") as f:
            for off in offsets:
                f.seek(off)
                d = json.loads(f.readline())
                out.append(ProvenanceEvent(
                    event_type=d["event_type"],
                    flowfile_uuid=d["flowfile_uuid"],
                    lineage_id=d["lineage_id"], component=d["component"],
                    ts=d["ts"], details=d.get("details", "")))
        return out

    def events(self, event_type: str | None = None,
               component: str | None = None,
               since: float = 0.0) -> list[ProvenanceEvent]:
        with self._lock:
            out = list(self._events)
        if event_type is not None:
            out = [e for e in out if e.event_type == event_type]
        if component is not None:
            out = [e for e in out if e.component == component]
        if since:
            out = [e for e in out if e.ts >= since]
        return out

    def counts(self) -> dict[str, int]:
        with self._lock:
            return dict(self._counts)

    def lineage_chain(self, lineage_id: str) -> list[str]:
        """Ordered component path a logical record took (the Fig. 4 graph,
        linearized)."""
        evs = sorted(self.lineage(lineage_id), key=lambda e: e.ts)
        chain: list[str] = []
        for e in evs:
            if not chain or chain[-1] != e.component:
                chain.append(e.component)
        return chain

    def close(self) -> None:
        if self._spill is not None:
            self._spill.close()
            self._spill = None


#: A process-wide default repository; flows may construct private ones.
DEFAULT_REPOSITORY = ProvenanceRepository()
