"""Multi-process ingestion fabric: sharded acquisition workers over a
socket-transported log (paper §III — the "scalable" half of the claim).

Until this module the whole reproduction ran in one Python process, GIL-
bound near 4k rec/s. The fabric shards the case study across OS processes
the way the paper's systems shard across nodes:

  * the **coordinator** (this process) owns the durable ``LogStore`` and
    hosts it behind a :class:`~repro.core.transport.LogServer` — the Kafka
    *broker*. It also runs the *controller* half of Kafka's
    broker/controller split: a heartbeat failure detector plus lease-based
    assignment of **shard groups** to workers, with leader-epoch fencing
    (the PR 3 epoch machinery, now enforced at the storage boundary by the
    server's :class:`~repro.core.transport.FenceTable`);
  * each **worker** is an OS process (``multiprocessing`` spawn) holding a
    lease on one or more shard groups. A shard group is a vertical slice of
    the pipeline: a subset of ``AcquisitionRuntime`` connectors plus a
    *disjoint* subset of each landing topic's partitions (NiFi would run
    the same flow on every node of a cluster and divide the feed;
    AsterixDB's feeds job runs an intake/compute cascade per node group).
    Workers reach the log only through :class:`RemoteLogStore` — NiFi
    site-to-site, in Kafka terms the producer wire protocol.

Failure handling (paper: "robustness in handling node failures"): workers
heartbeat over the control channel; when one misses
``lease_timeout_sec`` the coordinator declares it dead, bumps the fence
epoch of every partition its groups own (so a paused-not-dead zombie's
in-flight appends are rejected at the server — *then* it is safe to move
the work), and reassigns the groups to surviving workers. The takeover
worker rebuilds each group's pipeline and resumes from the group's cursor
checkpoints (topic ``__acq__.<name>.<group>``) and durable ingress WAL —
the same crash-recovery contract the single-process runtime already
proved, now driven by a failure detector instead of a restart.

Guarantees across a worker ``kill -9`` (with ``durable`` ingress):

  * zero acked-record loss — acked = admitted past the ingress WAL, or
    covered by a cursor checkpoint (the endpoint redelivers the rest);
  * bounded duplicates — at-least-once redelivery + WAL replay, deduped
    per-shard like the single-process pipeline;
  * monotonic fabric-wide low watermark — per-connector watermarks are
    seeded from checkpoints on takeover and aggregated coordinator-side as
    per-connector maxima.

The control protocol is JSON frames over the same length-prefixed framing
as the data protocol (``OP_CTRL``): ``hello`` / ``assign`` / ``hb`` /
``group_done`` / ``group_failed`` / ``shutdown``.
"""
from __future__ import annotations

import importlib
import json
import multiprocessing as mp
import os
import socket
import threading
import time
from pathlib import Path
from typing import Callable, Sequence

from .logstore import LogStore
from .telemetry import (FlightRecorder, ScrapeServer, merge_histogram_states,
                        render_histogram_state_text, serve_scrape,
                        summarize_histogram_state)
from .transport import (FenceTable, LogServer, RemoteLogStore, recv_ctrl,
                        send_ctrl, TransportError)

__all__ = ["IngestionFabric", "LeaseTable", "FabricError", "resolve_factory"]


class FabricError(RuntimeError):
    pass


def resolve_factory(path: str) -> Callable:
    """Resolve ``"package.module:function"`` — how a worker process turns a
    JSON shard spec back into executable pipeline code."""
    mod_name, _, fn_name = path.partition(":")
    if not fn_name:
        raise ValueError(f"factory {path!r} is not 'module:function'")
    fn = getattr(importlib.import_module(mod_name), fn_name, None)
    if fn is None:
        raise ValueError(f"factory {path!r} not found")
    return fn


# ---------------------------------------------------------------------------
# lease bookkeeping (pure state machine — unit-testable without processes)
# ---------------------------------------------------------------------------

class LeaseTable:
    """Coordinator-side assignment state: which worker holds which shard
    group, under which epoch, and who is still heartbeating.

    Pure bookkeeping over an injected clock (``now`` parameters) so the
    election logic is testable without processes or sleeps. Thread-safe.

    The epoch is per-group and bumps on every reassignment; it is the fence
    token the coordinator pushes into the data server's
    :class:`~repro.core.transport.FenceTable` *before* the new assignment
    goes out, which is what makes a lease takeover safe against a zombie
    holder (Kafka's controller epoch / leader epoch pairing)."""

    def __init__(self, lease_timeout_sec: float) -> None:
        if lease_timeout_sec <= 0:
            raise ValueError("lease_timeout_sec must be positive")
        self.lease_timeout_sec = lease_timeout_sec
        self._lock = threading.Lock()
        self._beats: dict[str, float] = {}       # worker -> last heartbeat
        self._dead: set[str] = set()
        # group -> {"worker", "epoch", "state": assigned|done}
        self._groups: dict[str, dict] = {}

    # -- workers --
    def register_worker(self, worker: str, now: float) -> None:
        with self._lock:
            if worker in self._dead:
                raise FabricError(f"worker {worker!r} was declared dead")
            self._beats[worker] = now

    def heartbeat(self, worker: str, now: float) -> bool:
        """Record a beat. Returns False (beat ignored) for a worker already
        declared dead — a paused-not-dead zombie does not resurrect."""
        with self._lock:
            if worker in self._dead or worker not in self._beats:
                return False
            self._beats[worker] = now
            return True

    def expired_workers(self, now: float) -> list[str]:
        with self._lock:
            return [w for w, t in self._beats.items()
                    if w not in self._dead
                    and now - t > self.lease_timeout_sec]

    def alive_workers(self) -> list[str]:
        with self._lock:
            return sorted(w for w in self._beats if w not in self._dead)

    # -- groups --
    def assign_initial(self, groups: Sequence[str]) -> dict[str, str]:
        """Round-robin the groups over registered workers (first epoch 1).
        Returns {group: worker}."""
        with self._lock:
            workers = sorted(w for w in self._beats if w not in self._dead)
            if not workers:
                raise FabricError("no workers registered")
            out = {}
            for i, gid in enumerate(groups):
                w = workers[i % len(workers)]
                self._groups[gid] = {"worker": w, "epoch": 1,
                                     "state": "assigned"}
                out[gid] = w
            return out

    def declare_dead(self, worker: str) -> list[tuple[str, str, int]]:
        """Mark ``worker`` dead and reassign its unfinished groups to the
        least-loaded survivors. Returns ``[(group, new_worker, new_epoch)]``
        — the caller must fence each group's partitions at ``new_epoch``
        before delivering the new assignments."""
        with self._lock:
            if worker in self._dead:
                return []
            self._dead.add(worker)
            survivors = sorted(w for w in self._beats if w not in self._dead)
            if not survivors:
                raise FabricError(
                    f"worker {worker!r} died and no survivors remain")
            load = {w: 0 for w in survivors}
            for g in self._groups.values():
                if g["state"] != "done" and g["worker"] in load:
                    load[g["worker"]] += 1
            moved = []
            for gid, g in sorted(self._groups.items()):
                if g["worker"] == worker and g["state"] != "done":
                    new = min(survivors, key=lambda w: (load[w], w))
                    load[new] += 1
                    g["worker"] = new
                    g["epoch"] += 1
                    g["state"] = "assigned"
                    moved.append((gid, new, g["epoch"]))
            return moved

    def mark_done(self, gid: str, worker: str, epoch: int) -> bool:
        """Accept a completion report iff it carries the current lease
        (a fenced zombie finishing its local drain does not complete the
        group — its successor owns it now)."""
        with self._lock:
            g = self._groups.get(gid)
            if g is None or g["worker"] != worker or g["epoch"] != epoch:
                return False
            g["state"] = "done"
            return True

    def holder(self, gid: str) -> tuple[str, int]:
        with self._lock:
            g = self._groups[gid]
            return g["worker"], g["epoch"]

    def all_done(self) -> bool:
        with self._lock:
            return bool(self._groups) and all(
                g["state"] == "done" for g in self._groups.values())

    def snapshot(self) -> dict:
        with self._lock:
            return {"groups": {g: dict(v) for g, v in self._groups.items()},
                    "dead": sorted(self._dead),
                    "workers": sorted(self._beats)}


# ---------------------------------------------------------------------------
# coordinator
# ---------------------------------------------------------------------------

class IngestionFabric:
    """Coordinator for N worker processes sharding an acquisition pipeline.

    ``shards`` is a list of JSON-serializable shard-group specs::

        {"group": "g0",
         "factory": "repro.data.pipeline:build_fabric_news_worker",
         "kwargs": {...},                      # factory parameters
         "partitions": {"articles": [0, 2]}}   # topic -> owned partitions

    ``factory(log, spec)`` runs in the worker process and must return
    ``(flow, acquisition_runtime)`` for the group; ``spec`` is the dict
    above plus ``"epoch"``. The ``partitions`` map is the fence unit: on
    takeover the coordinator advances the data server's fence for exactly
    these partitions before re-assigning, so a zombie's appends to them are
    rejected. (Ingress-WAL topics are deliberately left unfenced: a
    zombie's WAL appends are durable records the takeover replays —
    bounded duplicates, never loss.)
    """

    def __init__(self, root: str | Path, store: LogStore, *,
                 shards: Sequence[dict], workers: int,
                 name: str = "fabric",
                 heartbeat_sec: float = 0.2,
                 lease_timeout_sec: float = 2.0,
                 group_timeout_sec: float = 300.0,
                 spawn_timeout_sec: float = 60.0,
                 clock: Callable[[], float] | None = None) -> None:
        if workers < 1:
            raise ValueError("workers must be >= 1")
        for spec in shards:
            for key in ("group", "factory", "partitions"):
                if key not in spec:
                    raise ValueError(f"shard spec missing {key!r}: {spec}")
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.store = store
        self.name = name
        self.shards = {s["group"]: s for s in shards}
        self.n_workers = workers
        self.heartbeat_sec = heartbeat_sec
        self.group_timeout_sec = group_timeout_sec
        self.spawn_timeout_sec = spawn_timeout_sec
        #: monotonic source for spawn deadlines, lease heartbeats, and the
        #: failure detector (injectable; LeaseTable stays pure over it)
        self._clock: Callable[[], float] = \
            clock if clock is not None else time.monotonic
        self.fences = FenceTable()
        self.leases = LeaseTable(lease_timeout_sec)
        self.data_server = LogServer(store, fences=self.fences)
        self._ctrl_sock = socket.create_server(("127.0.0.1", 0))
        self._ctrl_sock.settimeout(0.2)
        self._stop = threading.Event()
        self._lock = threading.Lock()
        self._procs: dict[str, mp.process.BaseProcess] = {}
        self._conns: dict[str, socket.socket] = {}
        self._send_locks: dict[str, threading.Lock] = {}
        self._threads: list[threading.Thread] = []
        self._hello = threading.Semaphore(0)
        #: per-connector ("<group>/<name>") max watermark seen — maxima keep
        #: the aggregate monotonic across checkpoint-lagged takeovers
        self._wm: dict[str, float] = {}
        self._wm_known: set[str] = set()      # connectors that reported
        self._wm_finished: set[str] = set()
        self._groups_seen: set[str] = set()   # groups that reported once
        self._wm_history: list[float] = []
        self.reassignments: list[tuple[str, str, str, int]] = []
        self._group_errors: dict[str, str] = {}
        #: per (group, epoch) RemoteLogStore transport counters reported at
        #: group completion — status() aggregates them fabric-wide so the
        #: benches can track round trips per record
        self._transport: dict[str, dict] = {}
        #: ``wid -> {gid -> histogram state}``: each worker's latest
        #: heartbeat view of its ACTIVE groups (replaced wholesale per wid,
        #: so lost beats are harmless and a dead worker's last report keeps
        #: counting its in-flight work). Finished groups are evicted from
        #: the live view and move to ``_telemetry_final`` via their
        #: ``group_done`` report — groups routinely complete inside one
        #: heartbeat period, so the beats alone could miss an entire run.
        self._telemetry: dict[str, dict] = {}
        self._telemetry_final: dict[str, dict] = {}
        #: ring of recent status snapshots — dumped to flight-<wid>.json
        #: when the failure detector declares a worker dead
        self.flight = FlightRecorder(capacity=64)
        self._scrape: ScrapeServer | None = None
        self._all_done = threading.Event()
        self._started = False

    # -- lifecycle --
    def start(self) -> "IngestionFabric":
        """Spawn the workers, wait for every hello, push the initial
        assignments, and arm the failure detector. Returns once every
        worker is connected and every group is assigned — the moment to
        start a benchmark clock."""
        if self._started:
            raise FabricError("fabric already started")
        self._started = True
        self.data_server.start()
        t = threading.Thread(target=self._accept_loop,
                             name=f"{self.name}-accept", daemon=True)
        t.start()
        self._threads.append(t)
        ctx = mp.get_context("spawn")
        host, port = self._ctrl_sock.getsockname()[:2]
        for i in range(self.n_workers):
            wid = f"w{i}"
            p = ctx.Process(
                target=_worker_main,
                args=(wid, (host, port), self.data_server.address,
                      str(self.root / "workers" / wid), self.heartbeat_sec),
                name=f"{self.name}-{wid}", daemon=True)
            p.start()
            self._procs[wid] = p
        deadline = self._clock() + self.spawn_timeout_sec
        for _ in range(self.n_workers):
            if not self._hello.acquire(timeout=max(
                    0.0, deadline - self._clock())):
                self.shutdown(force=True)
                raise FabricError(
                    f"workers failed to connect within "
                    f"{self.spawn_timeout_sec}s")
        for gid, wid in self.leases.assign_initial(
                sorted(self.shards)).items():
            self._send_assign(gid, wid)
        mon = threading.Thread(target=self._monitor_loop,
                               name=f"{self.name}-monitor", daemon=True)
        mon.start()
        self._threads.append(mon)
        return self

    def wait(self, timeout: float | None = None) -> dict:
        """Block until every shard group reports done (under its current
        lease), then gracefully shut the workers down. Raises on group
        failure or timeout."""
        if not self._all_done.wait(
                timeout if timeout is not None else self.group_timeout_sec):
            snap = self.status()
            self.shutdown(force=True)
            raise FabricError(f"fabric did not complete: {snap['leases']}")
        with self._lock:
            errors = dict(self._group_errors)
        if errors:
            self.shutdown(force=True)
            raise FabricError(f"groups failed: {errors}")
        self.shutdown()
        return self.status()

    def kill_worker(self, wid: str) -> int:
        """``SIGKILL`` a worker process (the acceptance scenario's failure
        injection). Returns the killed pid."""
        p = self._procs[wid]
        if p.pid is None:
            raise FabricError(f"worker {wid} not started")
        os.kill(p.pid, 9)
        p.join(timeout=10.0)
        return p.pid

    def shutdown(self, force: bool = False) -> None:
        self._stop.set()
        with self._lock:
            conns = dict(self._conns)
        for wid, conn in conns.items():
            try:
                with self._send_locks[wid]:
                    send_ctrl(conn, {"t": "shutdown"})
            except (OSError, TransportError, ValueError):
                pass
        for p in self._procs.values():
            p.join(timeout=5.0)
            if p.is_alive():
                if force:
                    p.terminate()
                    p.join(timeout=5.0)
                if p.is_alive():
                    p.kill()
                    p.join(timeout=5.0)
        for conn in conns.values():
            try:
                conn.close()
            except OSError:
                pass
        try:
            self._ctrl_sock.close()
        except OSError:
            pass
        if self._scrape is not None:
            self._scrape.close()
        self.data_server.stop()

    # -- observability --
    def status(self) -> dict:
        with self._lock:
            wm_hist = list(self._wm_history)
            errors = dict(self._group_errors)
            transports = [dict(t) for t in self._transport.values()]
        transport: dict[str, int] = {}
        for t in transports:
            for k, v in t.items():
                if isinstance(v, (int, float)):
                    transport[k] = transport.get(k, 0) + v
        return {
            "leases": self.leases.snapshot(),
            "reassignments": list(self.reassignments),
            "low_watermark": wm_hist[-1] if wm_hist else None,
            "watermark_history": wm_hist,
            "group_errors": errors,
            "transport": transport,
            "telemetry": summarize_histogram_state(self.telemetry_state()),
        }

    def telemetry_state(self) -> dict:
        """Raw fabric-wide histogram state, merged bucket-wise: every
        finished group's exact final report plus each worker's latest
        heartbeat view of its still-active groups. A dead worker's last
        beat keeps counting the work it did before dying — replayed
        records are then *observed* twice (once per attempt), which is the
        honest reading for latency telemetry."""
        with self._lock:
            reports = [dict(t) for t in self._telemetry_final.values()]
            reports += [dict(t) for by_gid in self._telemetry.values()
                        for t in by_gid.values()]
        merged: dict = {}
        for state in reports:
            merge_histogram_states(merged, state)
        return merged

    def render_metrics_text(self) -> str:
        """Prometheus-style text exposition of the merged fabric
        telemetry plus a few coordinator gauges."""
        status = self.status()
        lines = [render_histogram_state_text(self.telemetry_state())]
        lw = status["low_watermark"]
        if lw is not None:
            lines.append(f"repro_fabric_low_watermark {lw}")
        lines.append(
            f"repro_fabric_reassignments {len(status['reassignments'])}")
        lines.append(
            f"repro_fabric_group_errors {len(status['group_errors'])}")
        for k, v in sorted(status["transport"].items()):
            lines.append(f'repro_fabric_transport{{counter="{k}"}} {v}')
        return "\n".join(ln for ln in lines if ln) + "\n"

    def serve_metrics(self, port: int = 0,
                      host: str = "127.0.0.1") -> ScrapeServer:
        """Start (or return the already-running) HTTP scrape endpoint
        serving :meth:`render_metrics_text` at ``GET /metrics``."""
        if self._scrape is None:
            self._scrape = serve_scrape(
                self.render_metrics_text, port=port, host=host)
        return self._scrape

    def low_watermark_history(self) -> list[float]:
        with self._lock:
            return list(self._wm_history)

    # -- control plane --
    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._ctrl_sock.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            t = threading.Thread(target=self._serve_worker, args=(conn,),
                                 daemon=True)
            t.start()

    def _serve_worker(self, conn: socket.socket) -> None:
        conn.settimeout(30.0)
        try:
            msg = recv_ctrl(conn)
        except (TransportError, OSError, ValueError):
            conn.close()
            return
        if msg.get("t") != "hello":
            conn.close()
            return
        wid = msg["worker"]
        now = self._clock()
        with self._lock:
            self._conns[wid] = conn
            self._send_locks[wid] = threading.Lock()
        self.leases.register_worker(wid, now)
        self._hello.release()
        while not self._stop.is_set():
            try:
                msg = recv_ctrl(conn)
            except socket.timeout:
                continue
            except (TransportError, OSError, ValueError):
                return          # EOF: the monitor declares death by lease
            kind = msg.get("t")
            if kind == "hb":
                self.leases.heartbeat(wid, self._clock())
                self._ingest_watermarks(msg)
                tel = msg.get("telemetry")
                if tel is not None:
                    with self._lock:
                        self._telemetry[wid] = tel
            elif kind == "group_done":
                if msg.get("transport"):
                    with self._lock:
                        self._transport[f"{msg['group']}@e{msg['epoch']}"] = \
                            msg["transport"]
                if self.leases.mark_done(msg["group"], wid, msg["epoch"]):
                    with self._lock:
                        if msg.get("telemetry"):
                            self._telemetry_final[
                                f"{msg['group']}@e{msg['epoch']}"] = \
                                msg["telemetry"]
                        # evict the group from every live heartbeat view:
                        # its exact final state supersedes the lagging beat
                        for t in self._telemetry.values():
                            t.pop(msg["group"], None)
                    for conn_name in msg.get("finished", []):
                        with self._lock:
                            self._wm_finished.add(
                                f"{msg['group']}/{conn_name}")
                    if self.leases.all_done():
                        self._all_done.set()
            elif kind == "group_failed":
                # a *fenced* failure on a stale lease is expected zombie
                # noise; anything else is a real error that fails the run
                holder, epoch = self.leases.holder(msg["group"])
                if not (msg.get("fenced") and
                        (holder != wid or epoch != msg["epoch"])):
                    with self._lock:
                        self._group_errors[msg["group"]] = msg.get(
                            "error", "unknown")
                    self._all_done.set()

    def _ingest_watermarks(self, msg: dict) -> None:
        with self._lock:
            for gid, conns in (msg.get("groups") or {}).items():
                self._groups_seen.add(gid)
                for cname, info in conns.items():
                    key = f"{gid}/{cname}"
                    self._wm_known.add(key)
                    wm = info.get("watermark")
                    if wm is not None and wm > self._wm.get(key, float("-inf")):
                        self._wm[key] = wm
                    if info.get("state") in ("COMPLETED", "STOPPED"):
                        self._wm_finished.add(key)
            if self._groups_seen != set(self.shards):
                return          # startup: min over a partial fleet is junk
            # fabric-wide low watermark: min over unfinished connectors'
            # maxima — monotone because maxima only rise and the active
            # set only shrinks (takeovers reuse the same group/conn keys)
            active = self._wm_known - self._wm_finished
            if active and all(k in self._wm for k in active):
                low = min(self._wm[k] for k in active)
                if not self._wm_history or low > self._wm_history[-1]:
                    self._wm_history.append(low)

    def _send_assign(self, gid: str, wid: str) -> None:
        _, epoch = self.leases.holder(gid)
        spec = dict(self.shards[gid])
        spec["epoch"] = epoch
        with self._lock:
            conn = self._conns.get(wid)
            lock = self._send_locks.get(wid)
        if conn is None or lock is None:
            raise FabricError(f"no control connection to worker {wid!r}")
        with lock:
            send_ctrl(conn, {"t": "assign", "spec": spec})

    def _monitor_loop(self) -> None:
        """The failure detector: poll heartbeat freshness, fence + reassign
        on expiry (fence FIRST — the zombie must be locked out of the
        storage layer before its groups move)."""
        interval = max(0.05, self.heartbeat_sec / 2)
        while not self._stop.is_set():
            time.sleep(interval)
            self.flight.record(self.status())
            for wid in self.leases.expired_workers(self._clock()):
                try:
                    moved = self.leases.declare_dead(wid)
                except FabricError as e:
                    with self._lock:
                        self._group_errors["<fabric>"] = str(e)
                    self._all_done.set()
                    return
                try:
                    self.flight.dump(self.root / f"flight-{wid}.json")
                except OSError:
                    pass
                for gid, new_wid, epoch in moved:
                    for topic, parts in self.shards[gid]["partitions"].items():
                        for p in parts:
                            self.fences.advance(topic, p, epoch)
                    try:
                        self._send_assign(gid, new_wid)
                    except (OSError, TransportError, FabricError) as e:
                        with self._lock:
                            self._group_errors[gid] = (
                                f"reassign to {new_wid} failed: {e}")
                        self._all_done.set()
                        return
                    self.reassignments.append((gid, wid, new_wid, epoch))


# ---------------------------------------------------------------------------
# worker process
# ---------------------------------------------------------------------------

def _is_fenced(exc: BaseException) -> bool:
    """True when ``exc`` (or anything in its cause/context chain) is a fence
    rejection — the expected way a zombie's shard group dies."""
    from .transport import FencedError
    seen: set[int] = set()
    cur: BaseException | None = exc
    while cur is not None and id(cur) not in seen:
        seen.add(id(cur))
        if isinstance(cur, FencedError) or "stale epoch" in str(cur):
            return True
        cur = cur.__cause__ or cur.__context__
    return False


def _worker_main(worker_id: str, control_addr: tuple[str, int],
                 data_addr: tuple[str, int], scratch: str,
                 heartbeat_sec: float) -> None:
    """Worker entry point (``multiprocessing`` spawn target).

    Connects the control channel, heartbeats, and runs one thread per
    assigned shard group: build the group's pipeline against a
    :class:`RemoteLogStore` fenced at the lease epoch, drive it to
    completion, report back. A group that fails with a fence rejection
    reports ``fenced`` — the coordinator ignores it when the lease has
    already moved on."""
    ctrl = socket.create_connection(control_addr, timeout=10.0)
    ctrl.settimeout(1.0)
    send_lock = threading.Lock()

    def send(msg: dict) -> None:
        try:
            with send_lock:
                send_ctrl(ctrl, msg)
        except (OSError, TransportError, ValueError):
            pass                   # coordinator gone: we exit on recv EOF

    send({"t": "hello", "worker": worker_id})
    stop = threading.Event()
    groups: dict[str, dict] = {}   # gid -> {"runtime", "flow", "log", ...}
    groups_lock = threading.Lock()

    def _group_telemetry(flow, log) -> dict:
        tel: dict = {}
        if flow.telemetry is not None:
            merge_histogram_states(tel, flow.telemetry.histograms_state())
        merge_histogram_states(tel, log.rpc_histograms_state())
        return tel

    def run_group(spec: dict) -> None:
        gid, epoch = spec["group"], spec["epoch"]
        log = RemoteLogStore(
            data_addr, Path(scratch) / gid / f"epoch-{epoch}",
            op_timeout=60.0)
        log.set_fence_epoch(epoch)
        try:
            flow, rt = resolve_factory(spec["factory"])(log, spec)
            with groups_lock:
                groups[gid] = {"runtime": rt, "flow": flow, "log": log,
                               "epoch": epoch}
            rt.run_with_flow(timeout=spec.get("timeout_sec", 300.0))
            status = rt.status()["connectors"]
            # final histogram state rides the completion report: groups
            # routinely finish inside one heartbeat period, so the beat
            # alone could miss the run entirely
            try:
                tel = _group_telemetry(flow, log)
            except Exception:   # noqa: BLE001 — best-effort telemetry
                tel = {}
            send({"t": "group_done", "group": gid, "epoch": epoch,
                  "finished": [n for n, s in status.items()
                               if s.get("state") in ("COMPLETED",
                                                     "STOPPED")],
                  "transport": log.transport_stats(),
                  "telemetry": tel})
        except Exception as e:   # noqa: BLE001 — report, don't kill worker
            send({"t": "group_failed", "group": gid, "epoch": epoch,
                  "fenced": _is_fenced(e),
                  "error": f"{type(e).__name__}: {e}"})
        finally:
            with groups_lock:
                groups.pop(gid, None)
            try:
                log.close()
            except Exception:   # noqa: BLE001
                pass

    def heartbeat_loop() -> None:
        while not stop.is_set():
            payload: dict = {}
            tel: dict = {}
            with groups_lock:
                active = {g: dict(v) for g, v in groups.items()}
            for gid, v in active.items():
                rt = v["runtime"]
                try:
                    conns = rt.status()["connectors"]
                except Exception:   # noqa: BLE001 — racing teardown
                    continue
                payload[gid] = {
                    n: {"watermark": s.get("watermark"),
                        "state": s.get("state")}
                    for n, s in conns.items()}
                try:
                    tel[gid] = _group_telemetry(v["flow"], v["log"])
                except Exception:   # noqa: BLE001 — racing teardown
                    pass
            # telemetry is keyed per group and always present (even empty):
            # the live view covers ACTIVE groups only — once a group's
            # exact final state ships via group_done, the coordinator
            # evicts its live entry so the two never double-count
            send({"t": "hb", "worker": worker_id, "groups": payload,
                  "telemetry": tel})
            stop.wait(heartbeat_sec)

    hb = threading.Thread(target=heartbeat_loop, daemon=True)
    hb.start()
    while True:
        try:
            msg = recv_ctrl(ctrl)
        except socket.timeout:
            continue
        except (TransportError, OSError, ValueError):
            break                  # coordinator gone
        kind = msg.get("t")
        if kind == "assign":
            threading.Thread(target=run_group, args=(msg["spec"],),
                             daemon=True).start()
        elif kind == "shutdown":
            break
    stop.set()
    hb.join(timeout=2.0)
    try:
        ctrl.close()
    except OSError:
        pass
