"""Wire transport for the :class:`~repro.core.logstore.LogStore` contract
(paper §III: the distribution layer is what lets acquisition scale past one
node; NiFi's site-to-site protocol plays this role between NiFi instances,
and Kafka's broker wire protocol plays it between producers/consumers and
the broker).

Until this module, every store lived in the producer's process. Here the
batched ``append_batch``/``pread``-range ``read`` machinery from the segment
store *is* the protocol: each operation is one length-prefixed binary frame
over TCP, so a remote store behaves like a local one — same dense offsets,
same at-least-once append semantics, same range reads.

Three pieces:

  * a framed codec — ``u32 length | u8 opcode | body`` with a hard 16 MiB
    frame cap (mirroring the WebSocket connector's frame cap) and torn-frame
    detection: a short read mid-frame raises :class:`TransportError` rather
    than yielding a half-decoded record batch;
  * :class:`LogServer` — hosts any ``LogStore`` behind a listening socket
    (thread per connection, like the test fixtures' WS/HTTP servers). The
    server optionally enforces **write fencing**: appends carry a leader
    epoch, and a :class:`FenceTable` bumped by the fabric coordinator
    rejects stale-epoch writers (the Kafka broker/controller split:
    storage enforces the controller's epoch decisions);
  * :class:`RemoteLogStore` — a ``LogStore`` client. Reads and offset
    queries retry transparently across reconnects (they are idempotent);
    ``append_batch`` retries make delivery at-least-once, upgraded to
    exactly-once when the caller stamps idempotent producer ids
    (``producer_id``/``base_seq``, deduped store-side — see
    ``logstore.ProducerDedupTable``).

The request/response cycle is strictly serial per connection; concurrency
comes from opening more connections (each fabric worker holds its own).
"""
from __future__ import annotations

import json
import socket
import struct
import threading
import time
from pathlib import Path
from typing import Sequence

from .log import PartitionedLog, route_partition
from .logstore import LogRecord, LogStore

__all__ = [
    "MAX_FRAME", "TransportError", "FrameTooLarge", "FencedError",
    "FenceTable", "LogServer", "RemoteLogStore",
    "send_frame", "recv_frame", "encode_records", "decode_records",
    "serve_store",
]

#: Hard cap on one wire frame (header excluded) — mirrors the 16 MiB frame
#: cap of the WebSocket connector. A peer announcing a larger frame is
#: protocol-corrupt (or hostile); both sides drop the connection instead of
#: allocating unbounded buffers.
MAX_FRAME = 16 << 20

_LEN = struct.Struct("<I")
_U16 = struct.Struct("<H")
_U32 = struct.Struct("<I")
_U64 = struct.Struct("<Q")
_I32 = struct.Struct("<i")
_I64 = struct.Struct("<q")
_REC = struct.Struct("<II")          # key_len, val_len
_OFFREC = struct.Struct("<QII")      # offset, key_len, val_len
_PARTOFF = struct.Struct("<iQ")      # partition, offset

# -- opcodes ----------------------------------------------------------------
OP_CREATE_TOPIC = 0x01
OP_TOPICS = 0x02
OP_NUM_PARTITIONS = 0x03
OP_APPEND_BATCH = 0x04
OP_READ = 0x05
OP_BEGIN_OFFSET = 0x06
OP_END_OFFSET = 0x07
OP_FLUSH = 0x08
OP_FLUSH_TOPIC = 0x09
OP_ENFORCE_RETENTION = 0x0A
OP_DROP_SEGMENTS_BELOW = 0x0B
OP_PING = 0x0C
#: JSON control frame — not part of the LogStore surface; the fabric's
#: coordinator/worker control channel reuses this framing (see core/fabric).
OP_CTRL = 0x20

# -- response status codes --------------------------------------------------
ST_OK = 0
ST_ERR = 1          # server-side RuntimeError / unexpected exception
ST_ERR_KEY = 2      # KeyError (unknown topic, ...)
ST_ERR_VALUE = 3    # ValueError (bad partition, out-of-sequence batch, ...)
ST_ERR_FENCED = 4   # stale leader epoch — the writer is a fenced zombie


class TransportError(ConnectionError):
    """Connection-level failure: torn frame, unexpected EOF, reconnect
    exhaustion. Retryable for idempotent operations."""


class FrameTooLarge(ValueError):
    """A frame exceeded :data:`MAX_FRAME`. Deliberately *not* a
    :class:`TransportError`: retrying an oversized batch can never succeed,
    so the client surfaces it to the caller instead of reconnect-looping."""


class FencedError(RuntimeError):
    """An append carried a stale leader epoch. The writer has been
    superseded (its lease expired and the coordinator re-elected); it must
    stop — its partition now belongs to another worker."""


# -- framing ----------------------------------------------------------------

def recv_exact(sock: socket.socket, n: int) -> bytes:
    """Read exactly ``n`` bytes. EOF before the first byte raises
    ``TransportError("closed")``; EOF mid-way is a torn frame."""
    chunks: list[bytes] = []
    got = 0
    while got < n:
        chunk = sock.recv(min(n - got, 1 << 20))
        if not chunk:
            if got == 0:
                raise TransportError("connection closed")
            raise TransportError(
                f"torn frame: expected {n} bytes, connection closed after "
                f"{got}")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def send_frame(sock: socket.socket, op: int, body: bytes = b"") -> None:
    if 1 + len(body) > MAX_FRAME:
        raise FrameTooLarge(
            f"frame of {1 + len(body)} bytes exceeds cap of {MAX_FRAME}")
    sock.sendall(_LEN.pack(1 + len(body)) + bytes([op]) + body)


def recv_frame(sock: socket.socket) -> tuple[int, bytes]:
    (length,) = _LEN.unpack(recv_exact(sock, 4))
    if length < 1 or length > MAX_FRAME:
        raise FrameTooLarge(f"peer announced {length}-byte frame "
                            f"(cap {MAX_FRAME})")
    payload = recv_exact(sock, length)
    return payload[0], payload[1:]


def _pack_str(s: str) -> bytes:
    b = s.encode("utf-8")
    if len(b) > 0xFFFF:
        raise ValueError("string field exceeds 64 KiB")
    return _U16.pack(len(b)) + b


class _Reader:
    """Sequential decoder over one frame body; every read is bounds-checked
    so a truncated body raises instead of mis-decoding."""

    __slots__ = ("buf", "pos")

    def __init__(self, buf: bytes) -> None:
        self.buf = buf
        self.pos = 0

    def take(self, n: int) -> bytes:
        if self.pos + n > len(self.buf):
            raise TransportError("torn frame body")
        out = self.buf[self.pos:self.pos + n]
        self.pos += n
        return out

    def u16(self) -> int:
        return _U16.unpack(self.take(2))[0]

    def u32(self) -> int:
        return _U32.unpack(self.take(4))[0]

    def u64(self) -> int:
        return _U64.unpack(self.take(8))[0]

    def i32(self) -> int:
        return _I32.unpack(self.take(4))[0]

    def i64(self) -> int:
        return _I64.unpack(self.take(8))[0]

    def string(self) -> str:
        return self.take(self.u16()).decode("utf-8")

    def done(self) -> None:
        if self.pos != len(self.buf):
            raise TransportError(
                f"frame body has {len(self.buf) - self.pos} trailing bytes")


def encode_records(records: Sequence[tuple[bytes, bytes]]) -> bytes:
    parts = [_U32.pack(len(records))]
    for key, value in records:
        parts.append(_REC.pack(len(key), len(value)))
        parts.append(key)
        parts.append(value)
    return b"".join(parts)


def decode_records(r: _Reader) -> list[tuple[bytes, bytes]]:
    n = r.u32()
    out = []
    for _ in range(n):
        klen, vlen = _REC.unpack(r.take(8))
        out.append((r.take(klen), r.take(vlen)))
    return out


def send_ctrl(sock: socket.socket, obj: dict) -> None:
    """JSON control frame (fabric coordinator<->worker channel)."""
    send_frame(sock, OP_CTRL, json.dumps(obj, separators=(",", ":")).encode())


def recv_ctrl(sock: socket.socket) -> dict:
    op, body = recv_frame(sock)
    if op != OP_CTRL:
        raise TransportError(f"expected control frame, got opcode {op:#x}")
    return json.loads(body)


# -- server -----------------------------------------------------------------


class FenceTable:
    """Leader epochs per ``(topic, partition)``, enforced on fenced appends.

    The fabric coordinator ``advance()``s an entry when it reassigns the
    partition to a new worker; the :class:`LogServer` then rejects appends
    carrying an older epoch. Partitions with no entry are unfenced (epoch 0
    wire value means "no fencing requested" on the append side)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._epochs: dict[tuple[str, int], int] = {}

    def advance(self, topic: str, partition: int, epoch: int) -> int:
        """Raise the fence for a partition (monotonic; never lowers)."""
        with self._lock:
            cur = self._epochs.get((topic, partition), 0)
            if epoch > cur:
                self._epochs[(topic, partition)] = epoch
                cur = epoch
            return cur

    def current(self, topic: str, partition: int) -> int:
        with self._lock:
            return self._epochs.get((topic, partition), 0)

    def check(self, topic: str, partition: int, epoch: int) -> None:
        with self._lock:
            cur = self._epochs.get((topic, partition), 0)
        if epoch < cur:
            raise FencedError(
                f"append to {topic}/{partition} with stale epoch {epoch} "
                f"(current {cur})")


class LogServer:
    """Host a ``LogStore`` behind a TCP listener (one thread per
    connection, serial request/response per connection).

    ``fences`` (a :class:`FenceTable`) arms write fencing: appends with a
    non-zero epoch are validated against it; appends with epoch 0 bypass
    fencing (single-writer setups). ``store`` must be thread-safe — both
    shipped stores are."""

    def __init__(self, store: LogStore, host: str = "127.0.0.1",
                 port: int = 0, *, fences: FenceTable | None = None) -> None:
        self.store = store
        self.fences = fences
        self._sock = socket.create_server((host, port))
        self._host, self._port = self._sock.getsockname()[:2]
        self._sock.settimeout(0.2)
        self._stop = threading.Event()
        self._accept_thread: threading.Thread | None = None
        self._conn_threads: list[threading.Thread] = []
        self._lock = threading.Lock()

    # -- lifecycle --
    @property
    def address(self) -> tuple[str, int]:
        return (self._host, self._port)

    @property
    def host(self) -> str:
        return self._host

    @property
    def port(self) -> int:
        return self._port

    def start(self) -> "LogServer":
        t = threading.Thread(target=self._accept_loop,
                             name=f"logserver-{self._port}", daemon=True)
        self._accept_thread = t
        t.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._accept_thread is not None:
            self._accept_thread.join(timeout=5.0)
        try:
            self._sock.close()
        except OSError:
            pass
        with self._lock:
            threads = list(self._conn_threads)
        for t in threads:
            t.join(timeout=2.0)

    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._sock.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            conn.settimeout(0.5)
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            t = threading.Thread(target=self._serve_conn, args=(conn,),
                                 daemon=True)
            with self._lock:
                self._conn_threads.append(t)
                self._conn_threads = [x for x in self._conn_threads
                                      if x.is_alive() or x is t]
            t.start()

    # -- per-connection service --
    def _serve_conn(self, conn: socket.socket) -> None:
        try:
            while not self._stop.is_set():
                try:
                    op, body = recv_frame(conn)
                except socket.timeout:
                    continue
                except (TransportError, FrameTooLarge, OSError):
                    return   # peer gone or protocol-corrupt: drop the conn
                try:
                    status, resp = ST_OK, self._dispatch(op, body)
                except KeyError as e:
                    status, resp = ST_ERR_KEY, str(e.args[0] if e.args else e).encode()
                except FencedError as e:
                    status, resp = ST_ERR_FENCED, str(e).encode()
                except (ValueError, TransportError) as e:
                    status, resp = ST_ERR_VALUE, str(e).encode()
                except Exception as e:   # noqa: BLE001 — survive bad requests
                    status, resp = ST_ERR, f"{type(e).__name__}: {e}".encode()
                try:
                    send_frame(conn, status, resp)
                except (OSError, FrameTooLarge):
                    return
        finally:
            try:
                conn.close()
            except OSError:
                pass

    def _dispatch(self, op: int, body: bytes) -> bytes:
        r = _Reader(body)
        store = self.store
        if op == OP_APPEND_BATCH:
            topic = r.string()
            partition: int | None = r.i32()
            if partition < 0:
                partition = None
            epoch = r.u64()
            producer_id: str | None = r.string() or None
            base_seq: int | None = r.i64()
            if base_seq < 0:
                base_seq = None
            records = decode_records(r)
            r.done()
            if epoch and self.fences is not None:
                nparts = store.num_partitions(topic)
                if partition is not None:
                    self.fences.check(topic, partition, epoch)
                else:
                    for key, _ in records:
                        self.fences.check(
                            topic, route_partition(key, nparts), epoch)
            kwargs = {}
            if producer_id is not None:
                kwargs = {"producer_id": producer_id, "base_seq": base_seq}
            placed = store.append_batch(topic, records, partition=partition,
                                        **kwargs)
            return _U32.pack(len(placed)) + b"".join(
                _PARTOFF.pack(p, off) for p, off in placed)
        if op == OP_READ:
            topic, partition = r.string(), r.u32()
            offset, max_records = r.u64(), r.u32()
            r.done()
            recs = store.read(topic, partition, offset,
                              max_records=max_records)
            parts = [_U32.pack(len(recs))]
            for rec in recs:
                parts.append(_OFFREC.pack(rec.offset, len(rec.key),
                                          len(rec.value)))
                parts.append(rec.key)
                parts.append(rec.value)
            return b"".join(parts)
        if op == OP_BEGIN_OFFSET or op == OP_END_OFFSET:
            topic, partition = r.string(), r.u32()
            r.done()
            fn = (store.begin_offset if op == OP_BEGIN_OFFSET
                  else store.end_offset)
            return _U64.pack(fn(topic, partition))
        if op == OP_CREATE_TOPIC:
            topic, partitions = r.string(), r.u32()
            r.done()
            store.create_topic(topic, partitions=partitions)
            return b""
        if op == OP_TOPICS:
            r.done()
            names = store.topics()
            return _U32.pack(len(names)) + b"".join(
                _pack_str(n) for n in names)
        if op == OP_NUM_PARTITIONS:
            topic = r.string()
            r.done()
            return _U32.pack(store.num_partitions(topic))
        if op == OP_FLUSH:
            fsync = bool(r.take(1)[0])
            r.done()
            store.flush(fsync=fsync)
            return b""
        if op == OP_FLUSH_TOPIC:
            topic = r.string()
            fsync = bool(r.take(1)[0])
            r.done()
            store.flush_topic(topic, fsync=fsync)
            return b""
        if op == OP_ENFORCE_RETENTION:
            topic, retention = r.string(), r.u64()
            r.done()
            return _U64.pack(store.enforce_retention(topic, retention))
        if op == OP_DROP_SEGMENTS_BELOW:
            topic, partition, offset = r.string(), r.u32(), r.u64()
            r.done()
            return _U64.pack(store.drop_segments_below(
                topic, partition, offset))
        if op == OP_PING:
            r.done()
            return b""
        raise ValueError(f"unknown opcode {op:#x}")


# -- client -----------------------------------------------------------------


class RemoteLogStore(LogStore):
    """``LogStore`` client over the framed TCP protocol.

    * ``root`` is **client-local scratch** (consumer-group offset stores
      default into it); the server's segment files live under the server
      store's own root.
    * Idempotent operations (reads, offsets, topic admin, flush) reconnect
      and retry transparently. ``append_batch`` also retries — delivery is
      at-least-once, exactly-once when the caller stamps
      ``producer_id``/``base_seq`` (the server-side store dedups retried
      batches).
    * ``set_fence_epoch(e)`` attaches a leader epoch to every subsequent
      append; a fenced server rejects the write with :class:`FencedError`
      once the coordinator has raised the fence (zombie writer).
    * ``close()`` closes this client session only — never the server store.
    """

    def __init__(self, address: tuple[str, int], root: Path | str, *,
                 connect_timeout: float = 5.0, op_timeout: float = 30.0,
                 retries: int = 3, retry_backoff_sec: float = 0.05) -> None:
        self.address = (address[0], int(address[1]))
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.connect_timeout = connect_timeout
        self.op_timeout = op_timeout
        self.retries = retries
        self.retry_backoff_sec = retry_backoff_sec
        self._lock = threading.RLock()
        self._sock: socket.socket | None = None
        self._epoch = 0
        self._nparts: dict[str, int] = {}
        self.reconnects = 0

    # -- connection management --
    def set_fence_epoch(self, epoch: int) -> None:
        """Attach leader epoch ``epoch`` to all subsequent appends."""
        with self._lock:
            self._epoch = int(epoch)

    def _ensure_sock(self) -> socket.socket:
        if self._sock is None:
            s = socket.create_connection(self.address,
                                         timeout=self.connect_timeout)
            s.settimeout(self.op_timeout)
            s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            self._sock = s
        return self._sock

    def _drop_sock(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None

    def _call(self, op: int, body: bytes) -> bytes:
        """One request/response cycle with reconnect-retry. All LogStore
        operations are safe to retry: reads/offsets are pure, appends are
        made idempotent by producer ids (or degrade to at-least-once)."""
        with self._lock:
            last: Exception | None = None
            for attempt in range(self.retries + 1):
                try:
                    sock = self._ensure_sock()
                    send_frame(sock, op, body)
                    status, resp = recv_frame(sock)
                except (OSError, TransportError) as e:
                    self._drop_sock()
                    last = e
                    if attempt < self.retries:
                        self.reconnects += 1
                        time.sleep(self.retry_backoff_sec * (attempt + 1))
                        continue
                    raise TransportError(
                        f"log server {self.address} unreachable after "
                        f"{self.retries + 1} attempts: {e}") from e
                if status == ST_OK:
                    return resp
                msg = resp.decode("utf-8", errors="replace")
                if status == ST_ERR_KEY:
                    raise KeyError(msg)
                if status == ST_ERR_VALUE:
                    raise ValueError(msg)
                if status == ST_ERR_FENCED:
                    raise FencedError(msg)
                raise RuntimeError(f"server error: {msg}")
            raise TransportError(str(last))  # pragma: no cover

    # -- topic admin --
    def create_topic(self, topic: str, partitions: int = 1) -> None:
        self._call(OP_CREATE_TOPIC, _pack_str(topic) + _U32.pack(partitions))
        with self._lock:
            self._nparts[topic] = partitions

    def topics(self) -> list[str]:
        r = _Reader(self._call(OP_TOPICS, b""))
        return [r.string() for _ in range(r.u32())]

    def num_partitions(self, topic: str) -> int:
        with self._lock:
            cached = self._nparts.get(topic)
        if cached is not None:
            return cached   # partition counts are fixed at create_topic
        r = _Reader(self._call(OP_NUM_PARTITIONS, _pack_str(topic)))
        n = r.u32()
        with self._lock:
            self._nparts[topic] = n
        return n

    # -- producer --
    def append(self, topic: str, key: bytes, value: bytes,
               partition: int | None = None) -> tuple[int, int]:
        return self.append_batch(topic, [(key, value)], partition)[0]

    def append_batch(self, topic: str,
                     records: Sequence[tuple[bytes, bytes]],
                     partition: int | None = None, *,
                     producer_id: str | None = None,
                     base_seq: int | None = None
                     ) -> list[tuple[int, int]]:
        if not records:
            return []
        if producer_id is not None and partition is None:
            raise ValueError("idempotent appends require an explicit "
                             "partition (the producer resolves routing)")
        with self._lock:
            epoch = self._epoch
        body = (_pack_str(topic)
                + _I32.pack(-1 if partition is None else partition)
                + _U64.pack(epoch)
                + _pack_str(producer_id or "")
                + _I64.pack(-1 if base_seq is None else base_seq)
                + encode_records(records))
        r = _Reader(self._call(OP_APPEND_BATCH, body))
        n = r.u32()
        if n != len(records):
            raise TransportError(
                f"append acked {n} records, sent {len(records)}")
        return [_PARTOFF.unpack(r.take(12)) for _ in range(n)]

    def flush(self, fsync: bool = True) -> None:
        self._call(OP_FLUSH, bytes([int(fsync)]))

    def flush_topic(self, topic: str, fsync: bool = True) -> None:
        self._call(OP_FLUSH_TOPIC, _pack_str(topic) + bytes([int(fsync)]))

    # -- consumer --
    def read(self, topic: str, partition: int, offset: int,
             max_records: int = 512) -> list[LogRecord]:
        body = (_pack_str(topic) + _U32.pack(partition) + _U64.pack(offset)
                + _U32.pack(max_records))
        r = _Reader(self._call(OP_READ, body))
        out = []
        for _ in range(r.u32()):
            off, klen, vlen = _OFFREC.unpack(r.take(16))
            out.append(LogRecord(topic, partition, off,
                                 r.take(klen), r.take(vlen)))
        return out

    def begin_offset(self, topic: str, partition: int) -> int:
        return _U64.unpack(self._call(
            OP_BEGIN_OFFSET, _pack_str(topic) + _U32.pack(partition)))[0]

    def end_offset(self, topic: str, partition: int) -> int:
        return _U64.unpack(self._call(
            OP_END_OFFSET, _pack_str(topic) + _U32.pack(partition)))[0]

    # -- retention --
    def enforce_retention(self, topic: str, retention_bytes: int) -> int:
        return _U64.unpack(self._call(
            OP_ENFORCE_RETENTION,
            _pack_str(topic) + _U64.pack(retention_bytes)))[0]

    def drop_segments_below(self, topic: str, partition: int,
                            offset: int) -> int:
        return _U64.unpack(self._call(
            OP_DROP_SEGMENTS_BELOW,
            _pack_str(topic) + _U32.pack(partition) + _U64.pack(offset)))[0]

    def ping(self) -> None:
        self._call(OP_PING, b"")

    def close(self) -> None:
        with self._lock:
            self._drop_sock()


# -- standalone server process helper ---------------------------------------

def serve_store(root: str, conn) -> None:
    """``multiprocessing`` target: host a :class:`PartitionedLog` at
    ``root`` behind a :class:`LogServer`, report ``(host, port)`` through
    ``conn`` (a ``multiprocessing.Pipe`` end), then serve until the parent
    sends anything (or hangs up). Used by the cross-process transport tests
    and handy as a minimal standalone log daemon."""
    store = PartitionedLog(root)
    server = LogServer(store).start()
    conn.send(server.address)
    try:
        conn.recv()            # block until shutdown signal / EOF
    except (EOFError, OSError):
        pass
    server.stop()
    store.flush(fsync=False)
    store.close()
