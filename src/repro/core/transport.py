"""Wire transport for the :class:`~repro.core.logstore.LogStore` contract
(paper §III: the distribution layer is what lets acquisition scale past one
node; NiFi's site-to-site protocol plays this role between NiFi instances,
and Kafka's broker wire protocol plays it between producers/consumers and
the broker).

Until this module, every store lived in the producer's process. Here the
batched ``append_batch``/``pread``-range ``read`` machinery from the segment
store *is* the protocol: each operation is one length-prefixed binary frame
over TCP, so a remote store behaves like a local one — same dense offsets,
same at-least-once append semantics, same range reads.

Wire protocol
-------------
Every frame is length-prefixed and carries a **correlation id**::

    request:  u32 length | u8 opcode | u32 corr | body
    response: u32 length | u8 status | u32 corr | body

``length`` counts everything after itself (opcode + corr + body) and is
capped at :data:`MAX_FRAME` (mirroring the WebSocket connector's frame cap);
a peer announcing a larger frame is protocol-corrupt (or hostile) and the
connection is dropped instead of allocating unbounded buffers. A short read
mid-frame raises :class:`TransportError` (torn frame) rather than yielding a
half-decoded record batch. Control frames (``OP_CTRL``, the fabric's
coordinator/worker channel) use ``corr = 0`` — they are a message stream,
not request/response.

Pipelining rules
----------------
The server handles each connection **serially in arrival order** and echoes
the request's ``corr`` on its response, so a client may keep a bounded
window of requests in flight on one socket and demultiplex completions:

* :class:`RemoteLogStore` assigns monotonically increasing correlation ids
  and keeps at most ``max_inflight`` unacknowledged requests outstanding; a
  dedicated reader thread matches responses to waiters, so the client lock
  is held only to send — never across a round trip. Concurrent threads
  sharing one client overlap their round trips instead of convoying.
* On a connection failure, the first thread to notice reconnects and
  **replays every unacknowledged request, byte-identical and in original
  submit order** (acknowledged requests are never re-sent). Order-preserving
  replay keeps per-partition producer sequences dense; byte-identical
  replay lets the store's :class:`~repro.core.logstore.ProducerDedupTable`
  recognize a batch the server applied before the ack was lost — a
  partially-acked pipeline retries exactly-once for idempotent appends and
  at-least-once otherwise.
* The dedup table holds one window per ``(topic, partition, producer_id)``,
  so a producer must keep at most ONE unacknowledged batch in flight per
  partition (the batching :class:`~repro.core.delivery.Producer` serializes
  its drains, satisfying this by construction); the wire layer itself does
  not reorder or merge producer-stamped batches.
* Epoch fencing survives replay unchanged: the epoch is baked into the
  frozen frame at submit time, and the server re-checks the
  :class:`FenceTable` on every (re)delivery.

Client-side append coalescing
-----------------------------
Plain appends — no ``producer_id``, explicit partition — to the same
``(topic, partition)`` coalesce into one wire call when they arrive while
an earlier append to that key is still on the wire (group commit), bounded
by ``coalesce_max_records``/``coalesce_max_bytes`` and an optional
``coalesce_linger_sec`` accumulation window. Each caller still gets exactly
its own dense ``(partition, offset)`` slice back; a failed wire call fails
every caller it carried. WAL journals, checkpoint appends, and spill
parking — one small RPC each before — ride the same frame under load.
Producer-stamped appends never coalesce: merging would change the batch
composition between retries and break the byte-identical dedup contract.

Read-ahead and the end-offset cache
-----------------------------------
The server advertises the partition's end offset on every read and append
response; the client caches it per ``(topic, partition)``. ``end_offset``
is served from the cache within ``end_cache_ttl_sec`` (same-client appends
refresh it for free, so read-your-writes stays exact; cross-client
staleness is bounded by the TTL), which makes an idle
:class:`~repro.core.delivery.Consumer.poll` over a remote store cost zero
round trips — mirroring the local cached-end gate. ``read`` fetches up to
``readahead_records`` beyond the request and serves subsequent sequential
reads from the buffer (log records are immutable by offset, so the buffer
can never go stale); a read past the buffered run falls through to the
wire.

Three pieces:

  * the framed codec above, with torn-frame detection;
  * :class:`LogServer` — hosts any ``LogStore`` behind a listening socket
    (thread per connection). The server optionally enforces **write
    fencing**: appends carry a leader epoch, and a :class:`FenceTable`
    bumped by the fabric coordinator rejects stale-epoch writers (the
    Kafka broker/controller split: storage enforces the controller's epoch
    decisions);
  * :class:`RemoteLogStore` — the pipelined ``LogStore`` client described
    above. ``transport_stats()`` exposes RPC/coalescing/cache counters so
    benchmarks can report round trips per record.
"""
from __future__ import annotations

import json
import socket
import struct
import threading
import time
from collections import deque
from pathlib import Path
from typing import Callable, Sequence

from . import faults
from .log import PartitionedLog, route_partition
from .logstore import LogRecord, LogStore
from .telemetry import LatencyHistogram, metric_key

__all__ = [
    "MAX_FRAME", "TransportError", "FrameTooLarge", "FencedError",
    "FenceTable", "LogServer", "RemoteLogStore",
    "send_frame", "recv_frame", "encode_records", "decode_records",
    "serve_store",
]

#: Hard cap on one wire frame (length prefix excluded) — mirrors the 16 MiB
#: frame cap of the WebSocket connector. A peer announcing a larger frame is
#: protocol-corrupt (or hostile); both sides drop the connection instead of
#: allocating unbounded buffers.
MAX_FRAME = 16 << 20

#: Server-side byte budget for one read response: the server stops encoding
#: records once the body crosses this (at least one record always ships), so
#: a read-ahead fetch of large records can never build an oversized frame —
#: callers loop on short reads anyway (the LogStore read contract returns
#: *up to* ``max_records``).
_READ_RESP_BUDGET = 8 << 20

#: Reader-thread poll granularity: how quickly a demux loop notices its
#: session was replaced / the client closed.
_READER_POLL_SEC = 0.5

_LEN = struct.Struct("<I")
_U16 = struct.Struct("<H")
_U32 = struct.Struct("<I")
_U64 = struct.Struct("<Q")
_I32 = struct.Struct("<i")
_I64 = struct.Struct("<q")
_REC = struct.Struct("<II")          # key_len, val_len
_OFFREC = struct.Struct("<QII")      # offset, key_len, val_len
_PARTOFF = struct.Struct("<iQ")      # partition, offset (also partition, end)

# -- opcodes ----------------------------------------------------------------
OP_CREATE_TOPIC = 0x01
OP_TOPICS = 0x02
OP_NUM_PARTITIONS = 0x03
OP_APPEND_BATCH = 0x04
OP_READ = 0x05
OP_BEGIN_OFFSET = 0x06
OP_END_OFFSET = 0x07
OP_FLUSH = 0x08
OP_FLUSH_TOPIC = 0x09
OP_ENFORCE_RETENTION = 0x0A
OP_DROP_SEGMENTS_BELOW = 0x0B
OP_PING = 0x0C
#: JSON control frame — not part of the LogStore surface; the fabric's
#: coordinator/worker control channel reuses this framing (see core/fabric).
OP_CTRL = 0x20

#: opcode -> human-readable name (the ``op`` label on per-op RPC latency
#: histograms; see :meth:`RemoteLogStore.rpc_histograms_state`)
OP_NAMES = {
    0x01: "create_topic", 0x02: "topics", 0x03: "num_partitions",
    0x04: "append_batch", 0x05: "read", 0x06: "begin_offset",
    0x07: "end_offset", 0x08: "flush", 0x09: "flush_topic",
    0x0A: "enforce_retention", 0x0B: "drop_segments_below",
    0x0C: "ping", 0x20: "ctrl",
}

# -- response status codes --------------------------------------------------
ST_OK = 0
ST_ERR = 1          # server-side RuntimeError / unexpected exception
ST_ERR_KEY = 2      # KeyError (unknown topic, ...)
ST_ERR_VALUE = 3    # ValueError (bad partition, out-of-sequence batch, ...)
ST_ERR_FENCED = 4   # stale leader epoch — the writer is a fenced zombie


class TransportError(ConnectionError):
    """Connection-level failure: torn frame, unexpected EOF, reconnect
    exhaustion. Retryable for idempotent operations."""


class FrameTooLarge(ValueError):
    """A frame exceeded :data:`MAX_FRAME`. Deliberately *not* a
    :class:`TransportError`: retrying an oversized batch can never succeed,
    so the client surfaces it to the caller instead of reconnect-looping."""


class FencedError(RuntimeError):
    """An append carried a stale leader epoch. The writer has been
    superseded (its lease expired and the coordinator re-elected); it must
    stop — its partition now belongs to another worker."""


# -- framing ----------------------------------------------------------------

def recv_exact(sock: socket.socket, n: int) -> bytes:
    """Read exactly ``n`` bytes. EOF before the first byte raises
    ``TransportError("closed")``; EOF mid-way is a torn frame."""
    chunks: list[bytes] = []
    got = 0
    while got < n:
        chunk = sock.recv(min(n - got, 1 << 20))
        if not chunk:
            if got == 0:
                raise TransportError("connection closed")
            raise TransportError(
                f"torn frame: expected {n} bytes, connection closed after "
                f"{got}")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def frame_bytes(op: int, corr: int, body: bytes = b"") -> bytes:
    """Assemble one wire frame (``u32 len | u8 op | u32 corr | body``)."""
    if 5 + len(body) > MAX_FRAME:
        raise FrameTooLarge(
            f"frame of {5 + len(body)} bytes exceeds cap of {MAX_FRAME}")
    return _LEN.pack(5 + len(body)) + bytes([op]) + _U32.pack(corr) + body


def send_frame(sock: socket.socket, op: int, body: bytes = b"",
               corr: int = 0) -> None:
    sock.sendall(frame_bytes(op, corr, body))


def recv_frame(sock: socket.socket) -> tuple[int, int, bytes]:
    """Receive one frame; returns ``(opcode_or_status, corr, body)``."""
    (length,) = _LEN.unpack(recv_exact(sock, 4))
    if length < 5 or length > MAX_FRAME:
        raise FrameTooLarge(f"peer announced {length}-byte frame "
                            f"(cap {MAX_FRAME})")
    payload = recv_exact(sock, length)
    return payload[0], _U32.unpack_from(payload, 1)[0], payload[5:]


def _pack_str(s: str) -> bytes:
    b = s.encode("utf-8")
    if len(b) > 0xFFFF:
        raise ValueError("string field exceeds 64 KiB")
    return _U16.pack(len(b)) + b


class _Reader:
    """Sequential decoder over one frame body; every read is bounds-checked
    so a truncated body raises instead of mis-decoding."""

    __slots__ = ("buf", "pos")

    def __init__(self, buf: bytes) -> None:
        self.buf = buf
        self.pos = 0

    def take(self, n: int) -> bytes:
        if self.pos + n > len(self.buf):
            raise TransportError("torn frame body")
        out = self.buf[self.pos:self.pos + n]
        self.pos += n
        return out

    def u16(self) -> int:
        return _U16.unpack(self.take(2))[0]

    def u32(self) -> int:
        return _U32.unpack(self.take(4))[0]

    def u64(self) -> int:
        return _U64.unpack(self.take(8))[0]

    def i32(self) -> int:
        return _I32.unpack(self.take(4))[0]

    def i64(self) -> int:
        return _I64.unpack(self.take(8))[0]

    def string(self) -> str:
        return self.take(self.u16()).decode("utf-8")

    def done(self) -> None:
        if self.pos != len(self.buf):
            raise TransportError(
                f"frame body has {len(self.buf) - self.pos} trailing bytes")


def encode_records(records: Sequence[tuple[bytes, bytes]]) -> bytes:
    parts = [_U32.pack(len(records))]
    for key, value in records:
        parts.append(_REC.pack(len(key), len(value)))
        parts.append(key)
        parts.append(value)
    return b"".join(parts)


def decode_records(r: _Reader) -> list[tuple[bytes, bytes]]:
    n = r.u32()
    out = []
    for _ in range(n):
        klen, vlen = _REC.unpack(r.take(8))
        out.append((r.take(klen), r.take(vlen)))
    return out


def send_ctrl(sock: socket.socket, obj: dict) -> None:
    """JSON control frame (fabric coordinator<->worker channel)."""
    send_frame(sock, OP_CTRL, json.dumps(obj, separators=(",", ":")).encode())


def recv_ctrl(sock: socket.socket) -> dict:
    op, _corr, body = recv_frame(sock)
    if op != OP_CTRL:
        raise TransportError(f"expected control frame, got opcode {op:#x}")
    return json.loads(body)


# -- server -----------------------------------------------------------------


class FenceTable:
    """Leader epochs per ``(topic, partition)``, enforced on fenced appends.

    The fabric coordinator ``advance()``s an entry when it reassigns the
    partition to a new worker; the :class:`LogServer` then rejects appends
    carrying an older epoch. Partitions with no entry are unfenced (epoch 0
    wire value means "no fencing requested" on the append side)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._epochs: dict[tuple[str, int], int] = {}

    def advance(self, topic: str, partition: int, epoch: int) -> int:
        """Raise the fence for a partition (monotonic; never lowers)."""
        with self._lock:
            cur = self._epochs.get((topic, partition), 0)
            if epoch > cur:
                self._epochs[(topic, partition)] = epoch
                cur = epoch
            return cur

    def current(self, topic: str, partition: int) -> int:
        with self._lock:
            return self._epochs.get((topic, partition), 0)

    def check(self, topic: str, partition: int, epoch: int) -> None:
        with self._lock:
            cur = self._epochs.get((topic, partition), 0)
        if epoch < cur:
            raise FencedError(
                f"append to {topic}/{partition} with stale epoch {epoch} "
                f"(current {cur})")


class LogServer:
    """Host a ``LogStore`` behind a TCP listener (one thread per
    connection; requests on a connection are served serially in arrival
    order, which is what lets clients pipeline against it).

    ``fences`` (a :class:`FenceTable`) arms write fencing: appends with a
    non-zero epoch are validated against it; appends with epoch 0 bypass
    fencing (single-writer setups). ``store`` must be thread-safe — both
    shipped stores are.

    Fault sites (see :mod:`repro.core.faults`): ``transport.server.recv``
    fires after a request frame is decoded and before dispatch (a raised
    fault drops the connection with the request unapplied);
    ``transport.server.respond`` fires after dispatch and before the
    response frame (a raised fault drops the connection *inside the
    ambiguous ack window* — the op applied but the client never hears it),
    which is how tests tear a partially-acked pipeline deterministically."""

    def __init__(self, store: LogStore, host: str = "127.0.0.1",
                 port: int = 0, *, fences: FenceTable | None = None) -> None:
        self.store = store
        self.fences = fences
        self._sock = socket.create_server((host, port))
        self._host, self._port = self._sock.getsockname()[:2]
        self._sock.settimeout(0.2)
        self._stop = threading.Event()
        self._accept_thread: threading.Thread | None = None
        self._conn_threads: list[threading.Thread] = []
        self._lock = threading.Lock()

    # -- lifecycle --
    @property
    def address(self) -> tuple[str, int]:
        return (self._host, self._port)

    @property
    def host(self) -> str:
        return self._host

    @property
    def port(self) -> int:
        return self._port

    def start(self) -> "LogServer":
        t = threading.Thread(target=self._accept_loop,
                             name=f"logserver-{self._port}", daemon=True)
        self._accept_thread = t
        t.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._accept_thread is not None:
            self._accept_thread.join(timeout=5.0)
        try:
            self._sock.close()
        except OSError:
            pass
        with self._lock:
            threads = list(self._conn_threads)
        for t in threads:
            t.join(timeout=2.0)

    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._sock.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            conn.settimeout(0.5)
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            t = threading.Thread(target=self._serve_conn, args=(conn,),
                                 daemon=True)
            with self._lock:
                self._conn_threads.append(t)
                self._conn_threads = [x for x in self._conn_threads
                                      if x.is_alive() or x is t]
            t.start()

    # -- per-connection service --
    def _serve_conn(self, conn: socket.socket) -> None:
        try:
            while not self._stop.is_set():
                try:
                    op, corr, body = recv_frame(conn)
                except socket.timeout:
                    continue
                except (TransportError, FrameTooLarge, OSError):
                    return   # peer gone or protocol-corrupt: drop the conn
                try:
                    faults.fire("transport.server.recv", op=op, corr=corr)
                except Exception:   # noqa: BLE001 — injected conn drop
                    return          # request lost before it was applied
                try:
                    status, resp = ST_OK, self._dispatch(op, body)
                except KeyError as e:
                    status, resp = ST_ERR_KEY, str(e.args[0] if e.args else e).encode()
                except FencedError as e:
                    status, resp = ST_ERR_FENCED, str(e).encode()
                except (ValueError, TransportError) as e:
                    status, resp = ST_ERR_VALUE, str(e).encode()
                except Exception as e:   # noqa: BLE001 — survive bad requests
                    status, resp = ST_ERR, f"{type(e).__name__}: {e}".encode()
                try:
                    faults.fire("transport.server.respond", op=op, corr=corr)
                except Exception:   # noqa: BLE001 — injected conn drop
                    return          # applied but unacked: ambiguous window
                try:
                    send_frame(conn, status, resp, corr)
                except (OSError, FrameTooLarge):
                    return
        finally:
            try:
                conn.close()
            except OSError:
                pass

    def _dispatch(self, op: int, body: bytes) -> bytes:
        r = _Reader(body)
        store = self.store
        if op == OP_APPEND_BATCH:
            topic = r.string()
            partition: int | None = r.i32()
            if partition < 0:
                partition = None
            epoch = r.u64()
            producer_id: str | None = r.string() or None
            base_seq: int | None = r.i64()
            if base_seq < 0:
                base_seq = None
            records = decode_records(r)
            r.done()
            if epoch and self.fences is not None:
                nparts = store.num_partitions(topic)
                if partition is not None:
                    self.fences.check(topic, partition, epoch)
                else:
                    for key, _ in records:
                        self.fences.check(
                            topic, route_partition(key, nparts), epoch)
            kwargs = {}
            if producer_id is not None:
                kwargs = {"producer_id": producer_id, "base_seq": base_seq}
            placed = store.append_batch(topic, records, partition=partition,
                                        **kwargs)
            # advertise the end offset of every touched partition so the
            # client's cache stays read-your-writes exact for free
            pset = sorted({p for p, _ in placed})
            return (_U32.pack(len(placed))
                    + b"".join(_PARTOFF.pack(p, off) for p, off in placed)
                    + _U32.pack(len(pset))
                    + b"".join(_PARTOFF.pack(p, store.end_offset(topic, p))
                               for p in pset))
        if op == OP_READ:
            topic, partition = r.string(), r.u32()
            offset, max_records = r.u64(), r.u32()
            r.done()
            recs = store.read(topic, partition, offset,
                              max_records=max_records)
            parts = []
            total = count = 0
            for rec in recs:
                parts.append(_OFFREC.pack(rec.offset, len(rec.key),
                                          len(rec.value)))
                parts.append(rec.key)
                parts.append(rec.value)
                total += 16 + len(rec.key) + len(rec.value)
                count += 1
                if total >= _READ_RESP_BUDGET:
                    break   # short read; callers loop (contract: up to N)
            return (_U64.pack(store.end_offset(topic, partition))
                    + _U32.pack(count) + b"".join(parts))
        if op == OP_BEGIN_OFFSET or op == OP_END_OFFSET:
            topic, partition = r.string(), r.u32()
            r.done()
            fn = (store.begin_offset if op == OP_BEGIN_OFFSET
                  else store.end_offset)
            return _U64.pack(fn(topic, partition))
        if op == OP_CREATE_TOPIC:
            topic, partitions = r.string(), r.u32()
            r.done()
            store.create_topic(topic, partitions=partitions)
            return b""
        if op == OP_TOPICS:
            r.done()
            names = store.topics()
            return _U32.pack(len(names)) + b"".join(
                _pack_str(n) for n in names)
        if op == OP_NUM_PARTITIONS:
            topic = r.string()
            r.done()
            return _U32.pack(store.num_partitions(topic))
        if op == OP_FLUSH:
            fsync = bool(r.take(1)[0])
            r.done()
            store.flush(fsync=fsync)
            return b""
        if op == OP_FLUSH_TOPIC:
            topic = r.string()
            fsync = bool(r.take(1)[0])
            r.done()
            store.flush_topic(topic, fsync=fsync)
            return b""
        if op == OP_ENFORCE_RETENTION:
            topic, retention = r.string(), r.u64()
            r.done()
            return _U64.pack(store.enforce_retention(topic, retention))
        if op == OP_DROP_SEGMENTS_BELOW:
            topic, partition, offset = r.string(), r.u32(), r.u64()
            r.done()
            return _U64.pack(store.drop_segments_below(
                topic, partition, offset))
        if op == OP_PING:
            r.done()
            return b""
        raise ValueError(f"unknown opcode {op:#x}")


# -- client -----------------------------------------------------------------


class _Pending:
    """One in-flight request: the frozen frame (byte-identical replay is
    what makes retried idempotent appends dedup) and its completion slot."""

    __slots__ = ("corr", "op", "frame", "status", "resp")

    def __init__(self, corr: int, op: int, frame: bytes) -> None:
        self.corr = corr
        self.op = op
        self.frame = frame
        self.status: int | None = None
        self.resp = b""


class _CoalesceEntry:
    """One caller's records queued at the append coalescer."""

    __slots__ = ("records", "nbytes", "event", "result", "error")

    def __init__(self, records: Sequence[tuple[bytes, bytes]]) -> None:
        self.records = list(records)
        self.nbytes = sum(len(k) + len(v) for k, v in self.records)
        self.event = threading.Event()
        self.result: list[tuple[int, int]] | None = None
        self.error: Exception | None = None


class _CoalesceQueue:
    __slots__ = ("entries", "draining")

    def __init__(self) -> None:
        self.entries: deque[_CoalesceEntry] = deque()
        self.draining = False


class RemoteLogStore(LogStore):
    """Pipelined ``LogStore`` client over the framed TCP protocol (see the
    module docstring for the wire format, pipelining rules, coalescer
    semantics, and the read-ahead / end-offset caches).

    * ``root`` is **client-local scratch** (consumer-group offset stores
      default into it); the server's segment files live under the server
      store's own root.
    * Up to ``max_inflight`` requests share one socket; a failed connection
      is re-established by the first waiter to notice and every
      unacknowledged frame is replayed byte-identical in original order.
      Delivery is therefore at-least-once, exactly-once when the caller
      stamps ``producer_id``/``base_seq`` (the server-side store dedups
      replayed batches).
    * ``set_fence_epoch(e)`` attaches a leader epoch to every subsequent
      append; a fenced server rejects the write with :class:`FencedError`
      once the coordinator has raised the fence (zombie writer).
    * ``close()`` closes this client session only — never the server store;
      a later call transparently reconnects.
    """

    def __init__(self, address: tuple[str, int], root: Path | str, *,
                 connect_timeout: float = 5.0, op_timeout: float = 30.0,
                 retries: int = 3, retry_backoff_sec: float = 0.05,
                 max_inflight: int = 32,
                 coalesce_appends: bool = True,
                 coalesce_max_records: int = 4096,
                 coalesce_max_bytes: int = 1 << 20,
                 coalesce_linger_sec: float = 0.0,
                 readahead_records: int = 1024,
                 readahead_max_bytes: int = 4 << 20,
                 end_cache_ttl_sec: float = 0.05,
                 clock: Callable[[], float] | None = None) -> None:
        self.address = (address[0], int(address[1]))
        #: monotonic source for op deadlines and cache TTLs (injectable)
        self._clock: Callable[[], float] = \
            clock if clock is not None else time.monotonic
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.connect_timeout = connect_timeout
        self.op_timeout = op_timeout
        self.retries = retries
        self.retry_backoff_sec = retry_backoff_sec
        self.max_inflight = max(1, int(max_inflight))
        self.coalesce_appends = coalesce_appends
        self.coalesce_max_records = coalesce_max_records
        self.coalesce_max_bytes = coalesce_max_bytes
        self.coalesce_linger_sec = coalesce_linger_sec
        self.readahead_records = readahead_records
        self.readahead_max_bytes = readahead_max_bytes
        self.end_cache_ttl_sec = end_cache_ttl_sec
        # session state: socket, correlation space, in-flight window. The
        # lock guards bookkeeping and sends; it is NEVER held across a recv.
        self._lock = threading.RLock()
        self._cv = threading.Condition(self._lock)
        self._sock: socket.socket | None = None
        self._gen = 0                      # session generation (reader tag)
        self._corr = 0
        self._pending: dict[int, _Pending] = {}   # corr -> req, submit order
        self._epoch = 0
        self._nparts: dict[str, int] = {}
        self.reconnects = 0
        # append coalescer (plain appends only; see module docstring)
        self._co_lock = threading.Lock()
        self._co: dict[tuple[str, int], _CoalesceQueue] = {}
        # read-ahead runs and advertised end offsets per (topic, partition)
        self._cache_lock = threading.Lock()
        self._ends: dict[tuple[str, int], tuple[int, float]] = {}
        self._ra: dict[tuple[str, int], tuple[int, list[LogRecord]]] = {}
        self._stats = {
            "rpcs": 0,                # request/response cycles issued
            "replayed_frames": 0,     # unacked frames re-sent on reconnect
            "append_rpcs": 0,
            "appended_records": 0,
            "coalesced_appends": 0,   # caller appends merged into a carrier
            "read_rpcs": 0,
            "read_records": 0,
            "readahead_hits": 0,      # reads served with zero round trips
            "end_offset_rpcs": 0,
            "end_cache_hits": 0,      # end_offsets served from the cache
        }
        # per-op RPC latency histograms (telemetry layer; lazily created on
        # first call per opcode — one perf_counter pair per round trip)
        self._op_hist: dict[int, "LatencyHistogram"] = {}

    # -- connection management --
    def set_fence_epoch(self, epoch: int) -> None:
        """Attach leader epoch ``epoch`` to all subsequent appends."""
        with self._lock:
            self._epoch = int(epoch)

    def transport_stats(self) -> dict:
        """Snapshot of the client's RPC/coalescing/cache counters (plus
        ``reconnects``) — the raw material for round-trips-per-record."""
        with self._lock:
            out = dict(self._stats)
            out["reconnects"] = self.reconnects
        return out

    def rpc_histograms_state(self) -> dict:
        """Serialized per-op RPC latency histograms, keyed in the metric
        registry's canonical form (``rpc_seconds{op="append_batch"}``) so
        fabric workers can merge them straight into heartbeat telemetry."""
        return {metric_key("rpc_seconds", {"op": OP_NAMES.get(op, hex(op))}):
                h.to_dict() for op, h in list(self._op_hist.items())}

    def _sendall_locked(self, data: bytes) -> None:
        """Send under the lock on the short-poll socket: partial sends loop,
        a stall past ``op_timeout`` is a dead peer."""
        sock = self._sock
        deadline = self._clock() + self.op_timeout
        view = memoryview(data)
        while view:
            try:
                n = sock.send(view)
            except socket.timeout as e:
                if self._clock() >= deadline:
                    raise TransportError(
                        f"send stalled for {self.op_timeout}s") from e
                continue
            view = view[n:]

    def _kill_session_locked(self) -> None:
        """Tear down the socket (the bound reader exits on the generation
        bump); pending requests stay queued for replay."""
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None
            self._gen += 1
        self._cv.notify_all()

    def _connect_locked(self) -> None:
        """Establish a session, replay every unacknowledged frame in
        original submit order (byte-identical), and start its reader."""
        s = socket.create_connection(self.address,
                                     timeout=self.connect_timeout)
        s.settimeout(_READER_POLL_SEC)
        s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        if self._gen:
            self.reconnects += 1
            self._stats["replayed_frames"] += len(self._pending)
        self._gen += 1
        self._sock = s
        try:
            if self._pending:
                for p in self._pending.values():   # dict == submit order
                    self._sendall_locked(p.frame)
        except (socket.timeout, OSError, TransportError):
            self._kill_session_locked()
            raise
        threading.Thread(target=self._reader_main, args=(s, self._gen),
                         name=f"remotelog-demux-{self.address[1]}",
                         daemon=True).start()
        self._cv.notify_all()

    def _reader_main(self, sock: socket.socket, gen: int) -> None:
        """Demultiplex responses for one session; on connection failure mark
        the session dead and wake the waiters (one of them reconnects)."""
        while True:
            try:
                status, corr, body = recv_frame(sock)
            except socket.timeout:
                with self._lock:
                    if self._gen != gen:
                        return
                continue
            except (TransportError, FrameTooLarge, OSError):
                with self._cv:
                    if self._gen == gen:
                        self._kill_session_locked()
                return
            with self._cv:
                if self._gen != gen:
                    return
                p = self._pending.pop(corr, None)
                if p is None:
                    continue    # response to a request a waiter abandoned
                p.status, p.resp = status, body
                self._cv.notify_all()

    def _call(self, op: int, body: bytes) -> bytes:
        """One pipelined request/response cycle. The client lock is held to
        enqueue and send — never across the round trip — so concurrent
        callers keep up to ``max_inflight`` requests on the wire at once.
        All LogStore operations are safe to replay: reads/offsets are pure,
        appends are made idempotent by producer ids (or degrade to
        at-least-once)."""
        if 5 + len(body) > MAX_FRAME:
            raise FrameTooLarge(
                f"frame of {5 + len(body)} bytes exceeds cap of {MAX_FRAME}")
        t0 = time.perf_counter()
        with self._cv:
            # admission: bounded in-flight window
            deadline = self._clock() + self.op_timeout
            while len(self._pending) >= self.max_inflight:
                if not self._cv.wait(
                        timeout=max(0.0, deadline - self._clock())) \
                        and len(self._pending) >= self.max_inflight:
                    raise TransportError(
                        f"in-flight window ({self.max_inflight}) stalled "
                        f"for {self.op_timeout}s")
            self._corr += 1
            corr = self._corr
            p = _Pending(corr, op, frame_bytes(op, corr, body))
            self._pending[corr] = p
            self._stats["rpcs"] += 1
            if self._sock is not None:
                try:
                    self._sendall_locked(p.frame)
                except (socket.timeout, OSError, TransportError):
                    self._kill_session_locked()   # p stays; replay re-sends
            # completion loop: whoever holds the lock when the session is
            # down drives the reconnect + ordered replay for everyone
            attempts = 0
            last: Exception | None = None
            while p.status is None:
                if self._sock is None:
                    if attempts > self.retries:
                        self._pending.pop(corr, None)
                        self._cv.notify_all()
                        raise TransportError(
                            f"log server {self.address} unreachable after "
                            f"{attempts} attempts: {last}") from last
                    if attempts:
                        self._cv.wait(self.retry_backoff_sec * attempts)
                        if p.status is not None:
                            break
                        if self._sock is not None:
                            continue   # another waiter reconnected already
                    attempts += 1
                    try:
                        self._connect_locked()
                    except (socket.timeout, OSError, TransportError) as e:
                        last = e
                elif not self._cv.wait(timeout=self.op_timeout) \
                        and p.status is None:
                    # a full op_timeout with zero completions: wedged server
                    self._pending.pop(corr, None)
                    self._kill_session_locked()
                    raise TransportError(
                        f"op {op:#x} timed out after {self.op_timeout}s")
        status, resp = p.status, p.resp
        # latency per completed cycle (admission wait + wire + demux); the
        # unreachable/timeout raise paths above never complete a cycle
        h = self._op_hist.get(op)
        if h is None:
            h = self._op_hist.setdefault(op, LatencyHistogram())
        h.record(time.perf_counter() - t0)
        if status == ST_OK:
            return resp
        msg = resp.decode("utf-8", errors="replace")
        if status == ST_ERR_KEY:
            raise KeyError(msg)
        if status == ST_ERR_VALUE:
            raise ValueError(msg)
        if status == ST_ERR_FENCED:
            raise FencedError(msg)
        raise RuntimeError(f"server error: {msg}")

    # -- topic admin --
    def create_topic(self, topic: str, partitions: int = 1) -> None:
        self._call(OP_CREATE_TOPIC, _pack_str(topic) + _U32.pack(partitions))
        with self._lock:
            self._nparts[topic] = partitions

    def topics(self) -> list[str]:
        r = _Reader(self._call(OP_TOPICS, b""))
        return [r.string() for _ in range(r.u32())]

    def num_partitions(self, topic: str) -> int:
        with self._lock:
            cached = self._nparts.get(topic)
        if cached is not None:
            return cached   # partition counts are fixed at create_topic
        r = _Reader(self._call(OP_NUM_PARTITIONS, _pack_str(topic)))
        n = r.u32()
        with self._lock:
            self._nparts[topic] = n
        return n

    # -- producer --
    def append(self, topic: str, key: bytes, value: bytes,
               partition: int | None = None) -> tuple[int, int]:
        return self.append_batch(topic, [(key, value)], partition)[0]

    def append_batch(self, topic: str,
                     records: Sequence[tuple[bytes, bytes]],
                     partition: int | None = None, *,
                     producer_id: str | None = None,
                     base_seq: int | None = None
                     ) -> list[tuple[int, int]]:
        if not records:
            return []
        if producer_id is not None and partition is None:
            raise ValueError("idempotent appends require an explicit "
                             "partition (the producer resolves routing)")
        if (self.coalesce_appends and producer_id is None
                and partition is not None):
            # plain appends to an explicit partition group-commit; stamped
            # appends must stay byte-identical across retries, so they
            # bypass the coalescer (the Producer batches them already)
            return self._append_coalesced(topic, int(partition), records)
        return self._append_wire(topic, records, partition,
                                 producer_id, base_seq)

    def _append_wire(self, topic: str,
                     records: Sequence[tuple[bytes, bytes]],
                     partition: int | None,
                     producer_id: str | None,
                     base_seq: int | None) -> list[tuple[int, int]]:
        with self._lock:
            epoch = self._epoch
        body = (_pack_str(topic)
                + _I32.pack(-1 if partition is None else partition)
                + _U64.pack(epoch)
                + _pack_str(producer_id or "")
                + _I64.pack(-1 if base_seq is None else base_seq)
                + encode_records(records))
        r = _Reader(self._call(OP_APPEND_BATCH, body))
        n = r.u32()
        if n != len(records):
            raise TransportError(
                f"append acked {n} records, sent {len(records)}")
        placed = [_PARTOFF.unpack(r.take(12)) for _ in range(n)]
        ends = [_PARTOFF.unpack(r.take(12)) for _ in range(r.u32())]
        now = self._clock()
        with self._cache_lock:
            self._stats["append_rpcs"] += 1
            self._stats["appended_records"] += n
            for part, end in ends:
                self._note_end_locked(topic, part, end, now)
        return placed

    def _append_coalesced(self, topic: str, partition: int,
                          records: Sequence[tuple[bytes, bytes]]
                          ) -> list[tuple[int, int]]:
        key = (topic, partition)
        entry = _CoalesceEntry(records)
        with self._co_lock:
            q = self._co.get(key)
            if q is None:
                q = self._co[key] = _CoalesceQueue()
            q.entries.append(entry)
            drainer = not q.draining
            q.draining = True
        if not drainer:
            # an earlier caller is on the wire for this key; it (or its
            # successors) will carry these records and post the offsets
            budget = (self.retries + 2) * (self.op_timeout
                                           + self.connect_timeout) \
                + self.coalesce_linger_sec
            if not entry.event.wait(budget):
                raise TransportError("coalesced append stalled")
            if entry.error is not None:
                raise entry.error
            return entry.result
        if self.coalesce_linger_sec > 0:
            time.sleep(self.coalesce_linger_sec)   # accumulation window
        while True:
            with self._co_lock:
                taken: list[_CoalesceEntry] = []
                nrec = nbytes = 0
                while q.entries:
                    e = q.entries[0]
                    if taken and (
                            nrec + len(e.records) > self.coalesce_max_records
                            or nbytes + e.nbytes > self.coalesce_max_bytes):
                        break
                    q.entries.popleft()
                    taken.append(e)
                    nrec += len(e.records)
                    nbytes += e.nbytes
                if not taken:
                    q.draining = False
                    break
                if len(taken) > 1:
                    self._stats["coalesced_appends"] += len(taken) - 1
            merged = (taken[0].records if len(taken) == 1
                      else [rec for e in taken for rec in e.records])
            try:
                placed = self._append_wire(topic, merged, partition,
                                           None, None)
            except Exception as err:   # noqa: BLE001 — fanned to callers
                for e in taken:
                    e.error = err
                    e.event.set()
                continue
            i = 0
            for e in taken:
                e.result = placed[i:i + len(e.records)]
                i += len(e.records)
                e.event.set()
        if entry.error is not None:
            raise entry.error
        return entry.result

    def flush(self, fsync: bool = True) -> None:
        self._call(OP_FLUSH, bytes([int(fsync)]))

    def flush_topic(self, topic: str, fsync: bool = True) -> None:
        self._call(OP_FLUSH_TOPIC, _pack_str(topic) + bytes([int(fsync)]))

    # -- consumer --
    def _note_end_locked(self, topic: str, partition: int, end: int,
                         now: float) -> None:
        key = (topic, partition)
        cur = self._ends.get(key)
        if cur is None or end >= cur[0]:
            self._ends[key] = (end, now)

    def read(self, topic: str, partition: int, offset: int,
             max_records: int = 512) -> list[LogRecord]:
        key = (topic, partition)
        if self.readahead_records > 0:
            with self._cache_lock:
                run = self._ra.get(key)
                if run is not None:
                    start, recs = run
                    if start <= offset < start + len(recs):
                        i = offset - start
                        out = recs[i:i + max_records]
                        known = self._ends.get(key)
                        # a short slice is served only when the run reaches
                        # everything this client knows exists — otherwise
                        # fall through and fetch fresh (same-client appends
                        # keep `known` exact, so read-your-writes holds)
                        if (len(out) == max_records or known is None
                                or start + len(recs) >= known[0]):
                            self._stats["readahead_hits"] += 1
                            return list(out)
        want = max(max_records, self.readahead_records)
        body = (_pack_str(topic) + _U32.pack(partition) + _U64.pack(offset)
                + _U32.pack(want))
        r = _Reader(self._call(OP_READ, body))
        end = r.u64()
        out = []
        for _ in range(r.u32()):
            off, klen, vlen = _OFFREC.unpack(r.take(16))
            out.append(LogRecord(topic, partition, off,
                                 r.take(klen), r.take(vlen)))
        now = self._clock()
        with self._cache_lock:
            self._stats["read_rpcs"] += 1
            self._stats["read_records"] += len(out)
            self._note_end_locked(topic, partition, end, now)
            if self.readahead_records > 0 and out:
                cached = out
                total = 0
                for idx, rec in enumerate(out):
                    total += 32 + len(rec.key) + len(rec.value)
                    if total >= self.readahead_max_bytes:
                        cached = out[:idx + 1]
                        break
                if key not in self._ra and len(self._ra) >= 64:
                    self._ra.pop(next(iter(self._ra)))   # oldest-inserted
                self._ra[key] = (cached[0].offset, cached)
        return out[:max_records]

    def begin_offset(self, topic: str, partition: int) -> int:
        return _U64.unpack(self._call(
            OP_BEGIN_OFFSET, _pack_str(topic) + _U32.pack(partition)))[0]

    def end_offset(self, topic: str, partition: int) -> int:
        if self.end_cache_ttl_sec > 0:
            now = self._clock()
            with self._cache_lock:
                cur = self._ends.get((topic, partition))
                if cur is not None and now - cur[1] <= self.end_cache_ttl_sec:
                    self._stats["end_cache_hits"] += 1
                    return cur[0]
        end = _U64.unpack(self._call(
            OP_END_OFFSET, _pack_str(topic) + _U32.pack(partition)))[0]
        with self._cache_lock:
            self._stats["end_offset_rpcs"] += 1
            self._note_end_locked(topic, partition, end, self._clock())
        return end

    # -- retention --
    def enforce_retention(self, topic: str, retention_bytes: int) -> int:
        return _U64.unpack(self._call(
            OP_ENFORCE_RETENTION,
            _pack_str(topic) + _U64.pack(retention_bytes)))[0]

    def drop_segments_below(self, topic: str, partition: int,
                            offset: int) -> int:
        return _U64.unpack(self._call(
            OP_DROP_SEGMENTS_BELOW,
            _pack_str(topic) + _U32.pack(partition) + _U64.pack(offset)))[0]

    def ping(self) -> None:
        self._call(OP_PING, b"")

    def close(self) -> None:
        with self._cv:
            self._kill_session_locked()


# -- standalone server process helper ---------------------------------------

def serve_store(root: str, conn) -> None:
    """``multiprocessing`` target: host a :class:`PartitionedLog` at
    ``root`` behind a :class:`LogServer`, report ``(host, port)`` through
    ``conn`` (a ``multiprocessing.Pipe`` end), then serve until the parent
    sends anything (or hangs up). Used by the cross-process transport tests
    and handy as a minimal standalone log daemon."""
    store = PartitionedLog(root)
    server = LogServer(store).start()
    conn.send(server.address)
    try:
        conn.recv()            # block until shutdown signal / EOF
    except (EOFError, OSError):
        pass
    server.stop()
    store.flush(fsync=False)
    store.close()
