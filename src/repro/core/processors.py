"""Concrete processors — the paper's extraction / enrichment / integration
toolbox (§III.B) plus the distribution sinks (§III.C).

Each maps to a NiFi processor named in the paper:

  DetectDuplicate     — near/exact duplicate removal (paper §III.B.1)
  ExecuteScript       — arbitrary filtering of erroneous/malicious items
  RouteOnAttribute    — routing to desired destinations (paper §II.A)
  LookupEnrich        — LookupAttribute/LookupRecord (paper §III.B.2)
  MergeContent        — integration of many records into one (paper §III.B.3)
  PartitionRecords    — PartitionRecord
  Throttle            — rate-throttling backpressure (paper §II.E)
  PublishToLog        — NiFi-as-Kafka-producer (paper §III.C)
  FileSink            — the HDFS landing zone of the case study (Fig. 3)
"""
from __future__ import annotations

import hashlib
import json
import math
import struct
import time
import zlib
from pathlib import Path
from typing import Callable, Iterable, Iterator, Mapping, Sequence

from .connection import RateThrottle
from .delivery import Producer
from .flowfile import FlowFile
from .logstore import LogStore
from .processor import (ATTR_DEAD_LETTER_REASON, ATTR_DEAD_LETTER_SOURCE,
                        ATTR_LAST_ERROR, ATTR_RETRY_COUNT,
                        ATTR_RETRY_NOT_BEFORE, Processor, REL_DROP,
                        REL_FAILURE, REL_SUCCESS)


# ---------------------------------------------------------------------------
# Dedup
# ---------------------------------------------------------------------------
class BloomFilter:
    """Fixed-size double-hash Bloom filter (approximate set membership)."""

    def __init__(self, expected_items: int, fp_rate: float = 1e-3) -> None:
        m = max(64, int(-expected_items * math.log(fp_rate) / (math.log(2) ** 2)))
        self.m = m
        self.k = max(1, int(round(m / max(1, expected_items) * math.log(2))))
        self._bits = bytearray((m + 7) // 8)

    def _hashes(self, item: bytes) -> Iterable[int]:
        h = hashlib.blake2b(item, digest_size=16).digest()
        h1 = int.from_bytes(h[:8], "little")
        h2 = int.from_bytes(h[8:], "little") | 1
        for i in range(self.k):
            yield (h1 + i * h2) % self.m

    def add(self, item: bytes) -> None:
        for idx in self._hashes(item):
            self._bits[idx >> 3] |= 1 << (idx & 7)

    def __contains__(self, item: bytes) -> bool:
        return all(self._bits[idx >> 3] & (1 << (idx & 7))
                   for idx in self._hashes(item))


class DetectDuplicate(Processor):
    """Routes to ``unique``/``duplicate`` based on a content key.

    mode='exact'  — hash-set of blake2 digests (no false positives).
    mode='bloom'  — Bloom filter: O(1) memory at millions of records/s; a
                    false-positive rate ``fp_rate`` drops that fraction of
                    unique records as duplicates (acceptable for the paper's
                    news-noise use case; measured in benchmarks).
    """

    relationships = ("unique", "duplicate")

    def __init__(self, name: str = "DetectDuplicate", mode: str = "exact",
                 key_fn: Callable[[FlowFile], bytes] | None = None,
                 expected_items: int = 1_000_000, fp_rate: float = 1e-3,
                 stamp: bool = False) -> None:
        """``stamp`` adds a ``dedup`` attribute to every record — one extra
        FlowFile copy per record on the hot path; off by default (§Perf:
        measured 1.17x ingest throughput without it)."""
        super().__init__(name)
        if mode not in ("exact", "bloom"):
            raise ValueError(f"unknown dedup mode {mode!r}")
        self.mode = mode
        self.stamp = stamp
        self.key_fn = key_fn or (lambda ff: ff.content)
        self._seen_exact: set[bytes] = set()
        self._bloom = BloomFilter(expected_items, fp_rate)

    def _is_dup(self, key: bytes) -> bool:
        if self.mode == "exact":
            digest = hashlib.blake2b(key, digest_size=16).digest()
            if digest in self._seen_exact:
                return True
            self._seen_exact.add(digest)
            return False
        if key in self._bloom:
            return True
        self._bloom.add(key)
        return False

    def process(self, ff: FlowFile):
        rel = "duplicate" if self._is_dup(self.key_fn(ff)) else "unique"
        yield rel, (ff.with_attributes(dedup=rel) if self.stamp else ff)


# ---------------------------------------------------------------------------
# Filtering / scripting
# ---------------------------------------------------------------------------
class ExecuteScript(Processor):
    """Applies ``fn(ff) -> FlowFile | None``; None routes to DROP
    (filtering of erroneous/malicious items, paper §II.F), exceptions route
    to ``failure``."""

    relationships = (REL_SUCCESS, REL_FAILURE)

    def __init__(self, name: str, fn: Callable[[FlowFile], FlowFile | None]) -> None:
        super().__init__(name)
        self.fn = fn

    def process(self, ff: FlowFile):
        try:
            out = self.fn(ff)
        except Exception as e:  # noqa: BLE001 — malformed records route to failure
            yield REL_FAILURE, ff.with_attributes(error=type(e).__name__)
            return
        if out is None:
            yield REL_DROP, ff
        else:
            yield REL_SUCCESS, out


class ContentFilter(ExecuteScript):
    """Keep records matching a predicate (language/content verification,
    paper §II.A)."""

    def __init__(self, name: str, predicate: Callable[[FlowFile], bool]) -> None:
        super().__init__(name, lambda ff: ff if predicate(ff) else None)


# ---------------------------------------------------------------------------
# Routing / prioritization
# ---------------------------------------------------------------------------
class RouteOnAttribute(Processor):
    """First matching rule wins; otherwise ``unmatched``."""

    def __init__(self, name: str,
                 rules: Mapping[str, Callable[[FlowFile], bool]]) -> None:
        super().__init__(name)
        self.rules = dict(rules)
        self.relationships = tuple(self.rules) + ("unmatched",)

    def process(self, ff: FlowFile):
        for rel, pred in self.rules.items():
            if pred(ff):
                yield rel, ff
                return
        yield "unmatched", ff


# ---------------------------------------------------------------------------
# Enrichment
# ---------------------------------------------------------------------------
class LookupEnrich(Processor):
    """Streaming enrichment (paper §III.B.2): join each record against an
    external lookup (dict or callable) and merge the result into attributes."""

    def __init__(self, name: str,
                 lookup: Mapping[str, Mapping[str, str]] | Callable[[str], Mapping[str, str] | None],
                 key_fn: Callable[[FlowFile], str],
                 on_miss: str = "pass") -> None:
        super().__init__(name)
        self._lookup = lookup if callable(lookup) else lookup.get
        self.key_fn = key_fn
        if on_miss not in ("pass", "drop", "failure"):
            raise ValueError(on_miss)
        self.on_miss = on_miss
        self.relationships = (REL_SUCCESS, REL_FAILURE)

    def process(self, ff: FlowFile):
        hit = self._lookup(self.key_fn(ff))
        if hit is None:
            if self.on_miss == "drop":
                yield REL_DROP, ff
            elif self.on_miss == "failure":
                yield REL_FAILURE, ff
            else:
                yield REL_SUCCESS, ff
            return
        yield REL_SUCCESS, ff.with_attributes(**{k: str(v) for k, v in hit.items()})


# ---------------------------------------------------------------------------
# Integration
# ---------------------------------------------------------------------------
class MergeContent(Processor):
    """Bundle up to ``max_records`` / ``max_bytes`` records into one FlowFile
    (newline-joined). Time-based flush keeps latency bounded."""

    buffers_across_triggers = True     # durable inputs defer acks (see base)

    def __init__(self, name: str = "MergeContent", max_records: int = 64,
                 max_bytes: int = 1 << 20, max_latency_sec: float = 1.0,
                 separator: bytes = b"\n",
                 clock: Callable[[], float] | None = None) -> None:
        super().__init__(name)
        #: monotonic source for the latency-bounded flush (injectable)
        self._clock: Callable[[], float] = \
            clock if clock is not None else time.monotonic
        self.max_records = max_records
        self.max_bytes = max_bytes
        self.max_latency_sec = max_latency_sec
        self.separator = separator
        self._buf: list[FlowFile] = []
        self._buf_bytes = 0
        self._oldest = 0.0

    def _bundle(self) -> FlowFile:
        content = self.separator.join(f.content for f in self._buf)
        first = self._buf[0]
        merged = first.derive(content=content, attributes={
            "merge.count": str(len(self._buf))})
        self._buf.clear()
        self._buf_bytes = 0
        return merged

    def on_trigger(self, batch: list[FlowFile]):
        for ff in batch:
            if not self._buf:
                self._oldest = self._clock()
            self._buf.append(ff)
            self._buf_bytes += ff.size
            if (len(self._buf) >= self.max_records
                    or self._buf_bytes >= self.max_bytes):
                yield REL_SUCCESS, self._bundle()
        if self._buf and self._clock() - self._oldest > self.max_latency_sec:
            yield REL_SUCCESS, self._bundle()

    def final_flush(self):
        if self._buf:
            yield REL_SUCCESS, self._bundle()


class PartitionRecords(Processor):
    """Stamp a partition key attribute (downstream PublishToLog honours it)."""

    def __init__(self, name: str, key_fn: Callable[[FlowFile], str]) -> None:
        super().__init__(name)
        self.key_fn = key_fn

    def process(self, ff: FlowFile):
        yield REL_SUCCESS, ff.with_attributes(**{"partition.key": self.key_fn(ff)})


# ---------------------------------------------------------------------------
# Throttling
# ---------------------------------------------------------------------------
class Throttle(Processor):
    """Rate-throttling pass-through (paper §II.E)."""

    def __init__(self, name: str, rate_per_sec: float, burst: int | None = None) -> None:
        super().__init__(name)
        self._bucket = RateThrottle(rate_per_sec, burst)

    def process(self, ff: FlowFile):
        self._bucket.acquire()
        yield REL_SUCCESS, ff


# ---------------------------------------------------------------------------
# Distribution sinks (paper §III.C)
# ---------------------------------------------------------------------------
class PublishToLog(Processor):
    """NiFi→Kafka edge: append each FlowFile to a topic of any ``LogStore``
    (single-host ``PartitionedLog`` or replicated ``ReplicatedLog``).

    Uses ``partition.key`` attribute when present, else the lineage id, so
    records of one logical stream stay ordered within a partition.

    Publishes through a batching ``delivery.Producer``: a whole trigger batch
    is accumulated and drained via ``append_batch`` (one pack/write per
    partition), instead of one ``struct.pack`` + CRC + ``write`` per record.

    ``partitions`` restricts publishing to an owned subset of the topic's
    partitions (the ingestion fabric assigns each worker a disjoint subset,
    so two workers never interleave writes — or sequence numbers — on one
    partition); keys then hash over the subset. ``producer_id`` stamps
    appends for store-side idempotent dedup (see ``delivery.Producer``).
    """

    def __init__(self, name: str, log: LogStore, topic: str,
                 flush_every: int = 2048,
                 batch_records: int = 512,
                 batch_bytes: int = 1 << 20,
                 partitions: "Sequence[int] | None" = None,
                 producer_id: str | None = None) -> None:
        super().__init__(name)
        self.log = log
        self.topic = topic
        self.flush_every = flush_every
        self._since_flush = 0
        self.published = 0
        self.partitions = None if partitions is None else list(partitions)
        if self.partitions is not None and not self.partitions:
            raise ValueError(f"{name}: empty partition subset")
        self._producer = Producer(log, topic,
                                  max_batch_records=batch_records,
                                  max_batch_bytes=batch_bytes,
                                  producer_id=producer_id)
        self._nparts: int | None = None

    def _partition_of(self, ff: FlowFile) -> int:
        pkey = ff.attributes.get("partition.key", ff.lineage_id)
        if self.partitions is not None:
            return self.partitions[zlib.crc32(pkey.encode())
                                   % len(self.partitions)]
        if self._nparts is None:
            self._nparts = self.log.num_partitions(self.topic)
        return zlib.crc32(pkey.encode()) % self._nparts

    def process(self, ff: FlowFile):
        return self.on_trigger([ff])

    def on_trigger(self, batch: list[FlowFile]):
        to_record = FlowFile.to_record
        self._producer.send_many(
            (*to_record(ff), self._partition_of(ff)) for ff in batch)
        self.published += len(batch)
        self._since_flush += len(batch)
        # end of trigger == a quiesce point: drain so concurrently attached
        # consumer groups see this trigger's records without waiting for the
        # size bound to trip
        self._producer.flush()
        if self._since_flush >= self.flush_every:
            self.log.flush_topic(self.topic, fsync=False)
            self._since_flush = 0
        return ()

    def on_stop(self) -> None:
        self._producer.flush()
        self.log.flush_topic(self.topic, fsync=True)


class DeadLetterQueue(Processor):
    """Quarantine sink for poison / retry-exhausted records (the robustness
    half of the paper's claim). Persists each record to a ``LogStore``
    topic **keyed by its provenance lineage id**, so a quarantined record can
    be joined back to its full lineage (paper Fig. 4) and replayed after the
    bug that poisoned it is fixed.

    Wire it with ``graph.route_dead_letters_to(dlq)``; it also accepts
    explicit connections (e.g. a processor's ``failure`` relationship).

    Re-ingestion is automatic via :meth:`redrive`: quarantined records are
    offered back into a flow (each to the processor that dead-lettered it,
    or an explicit ``dest``), with **content-hash poison fingerprinting** —
    a record that comes back to quarantine after a redrive is recognized by
    its fingerprint on every later redrive and skipped, so true poison
    cannot re-poison the flow in a redrive loop. Redrive progress (per-
    partition frontier + the fingerprint set) is persisted to
    ``<topic>.__redrive__`` through the same log, so redrives are
    crash-safe and incremental.
    """

    _VLEN = struct.Struct("<I")

    def __init__(self, name: str, log: LogStore, *,
                 topic: str = "dead-letters", partitions: int = 1) -> None:
        super().__init__(name)
        self.log = log
        self.topic = topic
        log.create_topic(topic, partitions=partitions)
        self._producer = Producer(log, topic)
        self.quarantined = 0

    # -- wire format: key = lineage id, value = len(header)|header|content --
    @classmethod
    def encode(cls, ff: FlowFile) -> tuple[bytes, bytes]:
        header, content = ff.to_record()
        return (ff.lineage_id.encode(),
                cls._VLEN.pack(len(header)) + header + content)

    @classmethod
    def decode(cls, value: bytes) -> FlowFile:
        (hlen,) = cls._VLEN.unpack_from(value, 0)
        start = cls._VLEN.size
        return FlowFile.from_record(value[start:start + hlen],
                                    value[start + hlen:])

    def process(self, ff: FlowFile):
        return self.on_trigger([ff])

    def on_trigger(self, batch: list[FlowFile]):
        encode = self.encode
        self._producer.send_many((*encode(ff), None) for ff in batch)
        self.quarantined += len(batch)
        # quarantine is cold-path: land every trigger immediately so the
        # operator (and the replay helper) sees poison records right away
        self._producer.flush()
        return ()

    def on_stop(self) -> None:
        self._producer.flush()
        self.log.flush_topic(self.topic, fsync=True)

    @classmethod
    def replay(cls, log: LogStore, topic: str = "dead-letters"
               ) -> Iterator[FlowFile]:
        """Yield every quarantined FlowFile (for re-ingestion once fixed)."""
        for r in log.iter_records(topic):
            yield cls.decode(r.value)

    # -- automatic re-drive --------------------------------------------------
    #: attributes stripped on redrive so re-ingested records get a fresh
    #: retry budget (and aren't mistaken for already-failed ones)
    _REDRIVE_STRIP = (ATTR_RETRY_COUNT, ATTR_RETRY_NOT_BEFORE,
                      ATTR_LAST_ERROR, ATTR_DEAD_LETTER_SOURCE,
                      ATTR_DEAD_LETTER_REASON)

    @staticmethod
    def fingerprint(ff: FlowFile) -> str:
        """Stable content-hash identity of a quarantined record (survives
        uuid/attribute churn across redrive attempts)."""
        return hashlib.blake2b(ff.content, digest_size=16).hexdigest()

    def _redrive_state_topic(self) -> str:
        return self.topic + ".__redrive__"

    def _load_redrive_state(self) -> tuple[dict[int, int], set[str]]:
        st = self._redrive_state_topic()
        self.log.create_topic(st, partitions=1)
        end = self.log.end_offset(st, 0)
        if end:
            recs = self.log.read(st, 0, end - 1, 1)
            if recs:
                state = json.loads(recs[0].value)
                return ({int(k): int(v)
                         for k, v in state["frontier"].items()},
                        set(state["fingerprints"]))
        return {}, set()

    def _save_redrive_state(self, frontier: dict[int, int],
                            fingerprints: set[str]) -> None:
        st = self._redrive_state_topic()
        prev_end = self.log.end_offset(st, 0)
        state = {"frontier": {str(k): v for k, v in frontier.items()},
                 "fingerprints": sorted(fingerprints)}
        self.log.append(st, b"", json.dumps(state).encode(), partition=0)
        # fsync before GC'ing the superseded state: dropping the old
        # segments while the new record sits in the page cache would let a
        # machine crash erase the redrive frontier entirely (cold path —
        # one fsync per redrive pass)
        self.log.flush_topic(st, fsync=True)
        # every state record but the newest is dead — GC sealed segments
        self.log.drop_segments_below(st, 0, prev_end)

    def redrive(self, flow, *, dest: "Processor | str | None" = None,
                batch_records: int = 512,
                stall_timeout: float = 30.0) -> dict:
        """Offer quarantined records back into ``flow`` (closing the manual
        ``replay()`` loop): each record goes to the input connection of the
        processor that dead-lettered it (``dead.letter.source``), or to
        ``dest`` when given. Records whose content fingerprint was already
        redriven once — i.e. they came *back* to quarantine — are skipped
        as confirmed poison. Returns
        ``{"redriven": n, "skipped_poison": m, "unroutable": u}``.

        Memory stays bounded by ``batch_records``: each scanned batch is
        offered downstream before the next is read, with backpressure felt
        immediately. At-least-once: a failure mid-redrive leaves the state
        unsaved, so everything scanned this pass stays redrivable (records
        already offered may be duplicated on the retry). A destination
        connection that stays full for ``stall_timeout`` seconds without
        accepting anything (flow not running, threshold too small) raises
        instead of hanging the redrive forever."""
        dest_name = dest if isinstance(dest, (str, type(None))) else dest.name
        if dest_name is not None and (
                dest_name not in flow.nodes
                or flow.nodes[dest_name].input is None):
            # an explicit-but-wrong dest is a caller error: raising BEFORE
            # the scan keeps the frontier untouched, so nothing is silently
            # forfeited to a typo (default per-record routing still counts
            # unknown sources as unroutable and moves on)
            raise ValueError(
                f"redrive dest {dest_name!r} is not a connected processor "
                "of this flow")
        frontier, seen_fps = self._load_redrive_state()
        redriven = skipped = unroutable = 0
        for p in range(self.log.num_partitions(self.topic)):
            off = max(frontier.get(p, 0),
                      self.log.begin_offset(self.topic, p))
            end_p = self.log.end_offset(self.topic, p)
            while off < end_p:
                recs = self.log.read(self.topic, p, off, batch_records)
                if not recs:
                    break
                by_target: dict[str, list[FlowFile]] = {}
                for r in recs:
                    ff = self.decode(r.value)
                    fp = self.fingerprint(ff)
                    if fp in seen_fps:
                        skipped += 1    # came back after a redrive: poison
                        continue
                    target = dest_name or ff.attributes.get(
                        ATTR_DEAD_LETTER_SOURCE)
                    if target is None or target not in flow.nodes \
                            or flow.nodes[target].input is None:
                        unroutable += 1  # left quarantined; frontier moves on
                        continue
                    attrs = {k: v for k, v in ff.attributes.items()
                             if k not in self._REDRIVE_STRIP}
                    by_target.setdefault(target, []).append(FlowFile(
                        content=ff.content, attributes=attrs,
                        lineage_id=ff.lineage_id, parent_uuid=ff.uuid,
                        entry_ts=ff.entry_ts))
                    seen_fps.add(fp)
                    redriven += 1
                for target, ffs in by_target.items():
                    self._offer_redriven(flow, target, ffs, stall_timeout)
                off = recs[-1].offset + 1
            frontier[p] = off
        self._save_redrive_state(frontier, seen_fps)
        return {"redriven": redriven, "skipped_poison": skipped,
                "unroutable": unroutable}

    def _offer_redriven(self, flow, target: str, ffs: "list[FlowFile]",
                        stall_timeout: float) -> None:
        conn = flow.nodes[target].input
        flow.provenance.record_batch("REPLAY", ffs, self.name,
                                     details=f"redrive->{target}")
        offered = 0
        stalled = 0.0
        wait = min(1.0, max(stall_timeout, 0.01))
        while offered < len(ffs):
            n = conn.offer_batch(ffs[offered:], block=True, timeout=wait)
            offered += n
            # a full connection that nothing drains (flow not running,
            # threshold too small) must not hang the redrive forever —
            # bail out WITHOUT saving state (see redrive docstring)
            stalled = 0.0 if n else stalled + wait
            if offered < len(ffs) and stalled >= stall_timeout:
                raise RuntimeError(
                    f"redrive stalled: connection {conn.name!r} stayed "
                    f"full for {stall_timeout:g}s ({len(ffs) - offered} "
                    "records unoffered); is the flow running?")


class FileSink(Processor):
    """HDFS-like landing zone: one file per FlowFile named by uuid
    (reproduces the paper's Fig. 3 listing)."""

    def __init__(self, name: str, directory: str | Path) -> None:
        super().__init__(name)
        self.directory = Path(directory)
        self.directory.mkdir(parents=True, exist_ok=True)
        self.written = 0

    def process(self, ff: FlowFile):
        (self.directory / ff.uuid).write_bytes(ff.content)
        self.written += 1
        return ()


class CollectSink(Processor):
    """In-memory sink for tests/benchmarks."""

    def __init__(self, name: str = "collect") -> None:
        super().__init__(name)
        self.items: list[FlowFile] = []

    def process(self, ff: FlowFile):
        self.items.append(ff)
        return ()
