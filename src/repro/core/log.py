"""Durable partitioned pub-sub log — the Kafka analogue (paper §III.C).

The distribution layer of the framework: producers append FlowFile records to
topic partitions; any number of consumers read by offset, so consumers can be
added or removed "at any time without changing the data ingestion pipeline"
(paper's key NiFi→Kafka property). Records are durable, ordered per
partition, and replayable.

Storage layout::

    root/<topic>/<partition>/<base_offset 20 digits>.seg

Segment record wire format (little-endian):

    crc32(u32) | key_len(u32) | val_len(u32) | key | value

where crc32 covers ``key_len|val_len|key|value``. On open, the tail segment is
scanned and any torn/corrupt suffix (partial write at crash) is truncated —
the crash-recovery property the paper requires of the FlowFile repository.

Batched hot path
----------------
``append_batch(topic, records)`` packs a whole batch of ``(key, value)``
records into one contiguous buffer per partition — one CRC pass per record,
one ``write(2)``, one index extension, and one amortized segment-roll check
per batch (the wire format is unchanged: a batch is byte-identical to the
same records appended one at a time, so readers and torn-tail recovery are
oblivious to batching). Reads go through a persistent per-segment read
descriptor with a single ``pread(2)`` per range, parsed out of a
``memoryview`` — no per-record ``open``/``seek``/triple-``read``.

Group-flush knobs:

* ``fsync_every=N`` — fsync a partition after every N records appended *to
  that partition* (counted under the partition lock, so concurrent producers
  cannot lose counts).
* readers flush a partition's write buffer only when its flushed watermark
  trails the end offset — a caught-up consumer polling an idle partition
  costs no ``flush()`` at all.
"""
from __future__ import annotations

import os
import struct
import threading
import zlib
from pathlib import Path
from typing import Sequence

from . import faults
from .logstore import LogRecord, LogStore, ProducerDedupTable

__all__ = ["CorruptRecord", "LogRecord", "PartitionedLog",
           "DEFAULT_SEGMENT_BYTES", "route_partition"]

_HEADER = struct.Struct("<III")  # crc, key_len, val_len
DEFAULT_SEGMENT_BYTES = 8 << 20  # 8 MiB segments


class CorruptRecord(Exception):
    pass


def route_partition(key: bytes, num_partitions: int) -> int:
    """The key→partition routing rule shared by every LogStore
    implementation (keyless records land on partition 0)."""
    return zlib.crc32(key) % num_partitions if key else 0


def _crc(key: bytes, value: bytes) -> int:
    c = zlib.crc32(struct.pack("<II", len(key), len(value)))
    c = zlib.crc32(key, c)
    return zlib.crc32(value, c)


class _Segment:
    """One append-only segment file with an in-memory offset index."""

    def __init__(self, path: Path, base_offset: int) -> None:
        self.path = path
        self.base_offset = base_offset
        self.positions: list[int] = []     # file pos of record i
        self.next_pos = 0
        self._recover()
        self._fh: object | None = open(path, "ab")
        # Persistent read descriptor; reads use pread(2), which is positionless
        # and therefore safe under concurrent readers without a lock. Readers
        # pin the segment so retention cannot close the fd (and recycle the fd
        # number onto an unrelated file) under an in-flight pread.
        self._rfd = os.open(path, os.O_RDONLY)
        self._pin_lock = threading.Lock()
        self._pins = 0
        self._closed = False

    # -- reader pinning (close-vs-pread safety) ------------------------------
    def pin(self) -> bool:
        """Take a read lease; False if the segment is already closed
        (retention-evicted) — its records are gone, skip it."""
        with self._pin_lock:
            if self._closed:
                return False
            self._pins += 1
            return True

    def unpin(self) -> None:
        with self._pin_lock:
            self._pins -= 1
            if self._closed and self._pins == 0:
                self._close_fds_locked()

    def _close_fds_locked(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None
        if self._rfd >= 0:
            os.close(self._rfd)
            self._rfd = -1

    # Scan existing records, truncating a torn tail.
    def _recover(self) -> None:
        if not self.path.exists():
            self.path.touch()
            return
        size = self.path.stat().st_size
        good_end = 0
        with open(self.path, "rb") as f:
            pos = 0
            while pos + _HEADER.size <= size:
                f.seek(pos)
                crc, klen, vlen = _HEADER.unpack(f.read(_HEADER.size))
                end = pos + _HEADER.size + klen + vlen
                if end > size:
                    break                       # torn write
                key = f.read(klen)
                value = f.read(vlen)
                if _crc(key, value) != crc:
                    break                       # corrupt tail
                self.positions.append(pos)
                good_end = end
                pos = end
        if good_end != size:
            with open(self.path, "r+b") as f:
                f.truncate(good_end)
        self.next_pos = good_end

    @property
    def count(self) -> int:
        return len(self.positions)

    @property
    def bytes(self) -> int:
        return self.next_pos

    def append(self, key: bytes, value: bytes) -> int:
        rec = _HEADER.pack(_crc(key, value), len(key), len(value)) + key + value
        self.positions.append(self.next_pos)
        self._fh.write(rec)
        self.next_pos += len(rec)
        return self.base_offset + len(self.positions) - 1

    def append_batch(self, records: Sequence[tuple[bytes, bytes]]) -> None:
        """Pack all records into one contiguous buffer and write once.

        Byte-identical on disk to appending the records one at a time."""
        buf = bytearray()
        pos = self.next_pos
        offsets = []
        pack, hsize = _HEADER.pack, _HEADER.size
        for key, value in records:
            offsets.append(pos)
            buf += pack(_crc(key, value), len(key), len(value))
            buf += key
            buf += value
            pos += hsize + len(key) + len(value)
        # fault site: a "crash"/callable armed here dies with the packed
        # buffer (fully or partially) unwritten — the torn-tail scenario
        # recovery must truncate away. Fired before any index mutation so a
        # "raise" action leaves the in-memory segment state untouched.
        faults.fire("log.segment.append_batch", segment=self, buf=buf,
                    records=records)
        self._fh.write(buf)
        self.positions.extend(offsets)
        self.next_pos = pos

    def seal(self) -> None:
        """Called when the segment stops being the active one: flush and drop
        the write handle (sealed segments are read-only; keeping one fd per
        segment instead of two halves long-run fd consumption)."""
        if self._fh is not None:
            self._fh.flush()
            self._fh.close()
            self._fh = None

    def flush(self, fsync: bool = False) -> None:
        if self._fh is None:
            return                          # sealed: already flushed
        self._fh.flush()
        if fsync:
            os.fsync(self._fh.fileno())

    def read(self, rel_index: int) -> tuple[bytes, bytes]:
        recs = self.read_range(rel_index, 1)
        if not recs:
            raise CorruptRecord(f"{self.path}@{rel_index}: out of range")
        return recs[0]

    def read_range(self, rel_start: int, max_records: int,
                   count: int | None = None, end_pos: int | None = None
                   ) -> list[tuple[bytes, bytes]]:
        """Batched sequential read — one ``pread`` for the whole range,
        parsed from a memoryview (no per-record syscalls).

        ``count``/``end_pos`` let the caller pin a consistent snapshot taken
        under the partition lock (appends may be racing this read)."""
        if count is None:
            count = len(self.positions)
        if end_pos is None:
            end_pos = self.next_pos
        if rel_start >= count:
            return []
        n = min(max_records, count - rel_start)
        start = self.positions[rel_start]
        stop = (self.positions[rel_start + n]
                if rel_start + n < count else end_pos)
        data = os.pread(self._rfd, stop - start, start)
        if len(data) != stop - start:
            raise CorruptRecord(
                f"{self.path}: short read {len(data)} != {stop - start}")
        mv = memoryview(data)
        out: list[tuple[bytes, bytes]] = []
        unpack_from, hsize = _HEADER.unpack_from, _HEADER.size
        pos = 0
        for _ in range(n):
            crc, klen, vlen = unpack_from(mv, pos)
            ks = pos + hsize
            vs = ks + klen
            ve = vs + vlen
            if ve > len(mv):
                raise CorruptRecord(str(self.path))
            # crc covers key_len|val_len|key|value == bytes [pos+4, ve)
            if zlib.crc32(mv[pos + 4:ve]) != crc:
                raise CorruptRecord(str(self.path))
            out.append((bytes(mv[ks:vs]), bytes(mv[vs:ve])))
            pos = ve
        return out

    def close(self) -> None:
        with self._pin_lock:
            self._closed = True
            if self._pins == 0:
                self._close_fds_locked()


class _Partition:
    def __init__(self, path: Path, segment_bytes: int,
                 fsync_every: int = 0) -> None:
        self.path = path
        self.segment_bytes = segment_bytes
        self.fsync_every = fsync_every
        self.lock = threading.Lock()
        path.mkdir(parents=True, exist_ok=True)
        bases = sorted(int(p.stem) for p in path.glob("*.seg"))
        self.segments: list[_Segment] = []
        expected_base = 0
        for b in bases:
            seg = _Segment(path / f"{b:020d}.seg", b)
            # (gap would mean a deleted-by-retention prefix; allowed)
            self.segments.append(seg)
            expected_base = b + seg.count
        if not self.segments:
            self.segments.append(_Segment(path / f"{0:020d}.seg", 0))
        for seg in self.segments[:-1]:
            seg.seal()                      # only the active segment writes
        self._appended_since_sync = 0
        # everything recovered from disk is, by definition, flushed
        self._flushed_end = self.end_offset

    @property
    def active(self) -> _Segment:
        return self.segments[-1]

    @property
    def begin_offset(self) -> int:
        return self.segments[0].base_offset

    @property
    def end_offset(self) -> int:
        a = self.active
        return a.base_offset + a.count

    def _roll_locked(self) -> None:
        self.active.seal()
        base = self.end_offset
        self._flushed_end = max(self._flushed_end, base)
        self.segments.append(_Segment(self.path / f"{base:020d}.seg", base))

    def _count_appended_locked(self, n: int) -> None:
        if self.fsync_every:
            self._appended_since_sync += n
            if self._appended_since_sync >= self.fsync_every:
                self.active.flush(fsync=True)
                self._flushed_end = self.end_offset
                self._appended_since_sync = 0

    def append(self, key: bytes, value: bytes) -> int:
        with self.lock:
            if self.active.bytes >= self.segment_bytes:
                self._roll_locked()
            off = self.active.append(key, value)
            self._count_appended_locked(1)
            return off

    def append_batch(self, records: Sequence[tuple[bytes, bytes]]) -> int:
        """Append many records under one lock acquisition; the segment-roll
        check runs once per written chunk, not once per record. Returns the
        first assigned offset (records get consecutive offsets)."""
        with self.lock:
            first = self.end_offset
            i, n = 0, len(records)
            hsize = _HEADER.size
            while i < n:
                if self.active.bytes >= self.segment_bytes:
                    self._roll_locked()
                # records that keep this segment under its size limit at the
                # moment each is written (same growth rule as append())
                cap = self.segment_bytes - self.active.bytes
                j, size = i, 0
                while j < n and size < cap:
                    k, v = records[j]
                    size += hsize + len(k) + len(v)
                    j += 1
                self.active.append_batch(records[i:j])
                i = j
            self._count_appended_locked(n)
            return first

    def flush(self, fsync: bool = False) -> None:
        with self.lock:
            self.active.flush(fsync)
            self._flushed_end = self.end_offset

    def read(self, offset: int, max_records: int) -> list[tuple[int, bytes, bytes]]:
        with self.lock:
            end = self.end_offset
            if offset >= end:
                return []
            # group-flush: make buffered appends visible only when a reader
            # actually trails the append watermark
            if self._flushed_end < end:
                self.active.flush()
                self._flushed_end = end
            segs = [(s, s.count, s.bytes) for s in self.segments]
        out: list[tuple[int, bytes, bytes]] = []
        for seg, count, end_pos in segs:
            if not out and offset >= seg.base_offset + count:
                continue
            rel = max(0, offset - seg.base_offset)
            if not seg.pin():
                continue                    # evicted by retention mid-read
            try:
                recs = seg.read_range(rel, max_records - len(out),
                                      count, end_pos)
            finally:
                seg.unpin()
            for key, value in recs:
                out.append((seg.base_offset + rel, key, value))
                rel += 1
            if len(out) >= max_records:
                break
        return out

    def enforce_retention(self, retention_bytes: int) -> int:
        """Drop oldest whole segments beyond the size budget. Returns the
        number of segments deleted (paper §I: 'delete the portions that are
        not useful')."""
        deleted = 0
        with self.lock:
            total = sum(s.bytes for s in self.segments)
            while len(self.segments) > 1 and total > retention_bytes:
                victim = self.segments.pop(0)
                total -= victim.bytes
                victim.close()
                victim.path.unlink(missing_ok=True)
                deleted += 1
        return deleted

    def drop_segments_below(self, offset: int) -> int:
        """Drop leading whole segments whose every record sits below
        ``offset`` (offset-targeted retention — the WAL frontier GC). The
        active segment is never dropped."""
        deleted = 0
        with self.lock:
            while (len(self.segments) > 1
                   and self.segments[0].base_offset + self.segments[0].count
                       <= offset):
                victim = self.segments.pop(0)
                victim.close()
                victim.path.unlink(missing_ok=True)
                deleted += 1
        return deleted

    def reset(self, base_offset: int = 0) -> None:
        """Discard every record and restart the partition empty at
        ``base_offset`` — the follower-resync primitive: a replica rejoining
        a replicated set is rebuilt by reset-to-the-leader's-begin_offset
        followed by contiguous range shipping, so its offsets stay aligned
        with the leader's even after leader-side retention."""
        with self.lock:
            for s in self.segments:
                s.close()
                s.path.unlink(missing_ok=True)
            self.segments = [
                _Segment(self.path / f"{base_offset:020d}.seg", base_offset)]
            self._appended_since_sync = 0
            self._flushed_end = base_offset

    def close(self) -> None:
        with self.lock:
            for s in self.segments:
                s.close()


class PartitionedLog(LogStore):
    """Multi-topic durable log — the single-host :class:`LogStore`.

    Thread-safe. ``append`` is at-least-once from the producer's view (the
    producer retries on timeout; dedup upstream or idempotent consumers
    downstream handle repeats — paper §III.B.1).

    Batching knobs: ``append_batch`` is the high-throughput producer entry
    point (see module docstring); ``fsync_every`` counts per partition under
    the partition lock. ``delivery.Producer`` provides a size/time-bounded
    accumulator that drains through ``append_batch``.
    """

    def __init__(self, root: str | Path,
                 segment_bytes: int = DEFAULT_SEGMENT_BYTES,
                 fsync_every: int = 0) -> None:
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.segment_bytes = segment_bytes
        self.fsync_every = fsync_every
        self._topics: dict[str, list[_Partition]] = {}
        self._dedup = ProducerDedupTable()
        self._lock = threading.Lock()
        # re-open any topics already on disk (crash recovery)
        for tdir in sorted(self.root.iterdir()) if self.root.exists() else []:
            if tdir.is_dir():
                parts = sorted(int(p.name) for p in tdir.iterdir() if p.is_dir())
                if parts:
                    self._topics[tdir.name] = [
                        _Partition(tdir / str(i), segment_bytes, fsync_every)
                        for i in range(max(parts) + 1)]

    # -- topic admin ----------------------------------------------------------
    def create_topic(self, topic: str, partitions: int = 1) -> None:
        with self._lock:
            if topic in self._topics:
                if len(self._topics[topic]) != partitions:
                    raise ValueError(
                        f"topic {topic!r} exists with "
                        f"{len(self._topics[topic])} partitions")
                return
            self._topics[topic] = [
                _Partition(self.root / topic / str(i), self.segment_bytes,
                           self.fsync_every)
                for i in range(partitions)]

    def topics(self) -> list[str]:
        with self._lock:
            return sorted(self._topics)

    def num_partitions(self, topic: str) -> int:
        return len(self._part_list(topic))

    def _part_list(self, topic: str) -> list[_Partition]:
        with self._lock:
            if topic not in self._topics:
                raise KeyError(f"unknown topic {topic!r}")
            return self._topics[topic]

    # -- producer --------------------------------------------------------------
    def append(self, topic: str, key: bytes, value: bytes,
               partition: int | None = None) -> tuple[int, int]:
        parts = self._part_list(topic)
        if partition is None:
            partition = route_partition(key, len(parts))
        off = parts[partition].append(key, value)
        return partition, off

    def append_batch(self, topic: str,
                     records: Sequence[tuple[bytes, bytes]],
                     partition: int | None = None, *,
                     producer_id: str | None = None,
                     base_seq: int | None = None
                     ) -> list[tuple[int, int]]:
        """Append a batch of ``(key, value)`` records with one lock
        acquisition / buffer pack / write per touched partition.

        With ``partition=None`` each record is routed by key hash (the same
        rule as ``append``) and the batch is regrouped per partition, order
        preserved within each partition. Returns ``(partition, offset)`` per
        record, in input order.

        With ``producer_id``/``base_seq`` (explicit partition required) the
        batch is idempotent: a resend of the last accepted batch — e.g. a
        ``RemoteLogStore`` client retrying after an ambiguous connection
        drop — returns the original offsets instead of appending again."""
        if not records:
            return []
        parts = self._part_list(topic)
        if producer_id is not None:
            if partition is None or base_seq is None:
                raise ValueError("idempotent appends need an explicit "
                                 "partition and a base_seq")
            verdict, entry = self._dedup.classify(
                topic, partition, producer_id, base_seq, len(records))
            if verdict == "retry":
                # the first attempt landed (the entry is only recorded
                # after a successful append): ack with the original offsets
                return [(partition, entry.first_offset + i)
                        for i in range(len(records))]
            first = parts[partition].append_batch(records)
            self._dedup.record(topic, partition, producer_id, base_seq,
                               len(records), first)
            return [(partition, first + i) for i in range(len(records))]
        if partition is not None:
            first = parts[partition].append_batch(records)
            return [(partition, first + i) for i in range(len(records))]
        groups: dict[int, list[tuple[bytes, bytes]]] = {}
        indices: dict[int, list[int]] = {}
        nparts = len(parts)
        for i, rec in enumerate(records):
            p = route_partition(rec[0], nparts)
            groups.setdefault(p, []).append(rec)
            indices.setdefault(p, []).append(i)
        out: list[tuple[int, int] | None] = [None] * len(records)
        for p, recs in groups.items():
            first = parts[p].append_batch(recs)
            for j, i in enumerate(indices[p]):
                out[i] = (p, first + j)
        return out  # type: ignore[return-value]

    def flush(self, fsync: bool = True) -> None:
        with self._lock:
            topics = list(self._topics.values())
        for parts in topics:
            for p in parts:
                p.flush(fsync)

    def flush_topic(self, topic: str, fsync: bool = True) -> None:
        """Flush one topic's partitions — producers that own a single topic
        should prefer this over ``flush`` (fsync(2) is expensive; syncing
        unrelated topics' partitions on every producer stop adds up)."""
        for p in self._part_list(topic):
            p.flush(fsync)

    # -- consumer --------------------------------------------------------------
    def read(self, topic: str, partition: int, offset: int,
             max_records: int = 512) -> list[LogRecord]:
        # the partition makes appended-but-unflushed records visible to
        # readers on demand (no flush when the reader is caught up)
        part = self._part_list(topic)[partition]
        return [LogRecord(topic, partition, off, k, v)
                for off, k, v in part.read(offset, max_records)]

    # iter_records / end_offsets come from the LogStore base class.

    def begin_offset(self, topic: str, partition: int) -> int:
        return self._part_list(topic)[partition].begin_offset

    def end_offset(self, topic: str, partition: int) -> int:
        return self._part_list(topic)[partition].end_offset

    def enforce_retention(self, topic: str, retention_bytes: int) -> int:
        return sum(p.enforce_retention(retention_bytes)
                   for p in self._part_list(topic))

    def drop_segments_below(self, topic: str, partition: int,
                            offset: int) -> int:
        return self._part_list(topic)[partition].drop_segments_below(offset)

    def reset_partition(self, topic: str, partition: int,
                        base_offset: int = 0) -> None:
        """Wipe one partition and restart it empty at ``base_offset`` (the
        replica-resync primitive — see ``_Partition.reset``)."""
        self._part_list(topic)[partition].reset(base_offset)

    def close(self) -> None:
        with self._lock:
            for parts in self._topics.values():
                for p in parts:
                    p.close()
            self._topics.clear()
