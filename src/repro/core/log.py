"""Durable partitioned pub-sub log — the Kafka analogue (paper §III.C).

The distribution layer of the framework: producers append FlowFile records to
topic partitions; any number of consumers read by offset, so consumers can be
added or removed "at any time without changing the data ingestion pipeline"
(paper's key NiFi→Kafka property). Records are durable, ordered per
partition, and replayable.

Storage layout::

    root/<topic>/<partition>/<base_offset 20 digits>.seg

Segment record wire format (little-endian):

    crc32(u32) | key_len(u32) | val_len(u32) | key | value

where crc32 covers ``key_len|val_len|key|value``. On open, the tail segment is
scanned and any torn/corrupt suffix (partial write at crash) is truncated —
the crash-recovery property the paper requires of the FlowFile repository.
"""
from __future__ import annotations

import os
import struct
import threading
import zlib
from dataclasses import dataclass
from pathlib import Path
from typing import Iterator

_HEADER = struct.Struct("<III")  # crc, key_len, val_len
DEFAULT_SEGMENT_BYTES = 8 << 20  # 8 MiB segments


class CorruptRecord(Exception):
    pass


@dataclass(frozen=True, slots=True)
class LogRecord:
    topic: str
    partition: int
    offset: int
    key: bytes
    value: bytes

    @property
    def size(self) -> int:
        return len(self.key) + len(self.value)


def _crc(key: bytes, value: bytes) -> int:
    c = zlib.crc32(struct.pack("<II", len(key), len(value)))
    c = zlib.crc32(key, c)
    return zlib.crc32(value, c)


class _Segment:
    """One append-only segment file with an in-memory offset index."""

    def __init__(self, path: Path, base_offset: int) -> None:
        self.path = path
        self.base_offset = base_offset
        self.positions: list[int] = []     # file pos of record i
        self.next_pos = 0
        self._recover()
        self._fh = open(path, "ab")

    # Scan existing records, truncating a torn tail.
    def _recover(self) -> None:
        if not self.path.exists():
            self.path.touch()
            return
        size = self.path.stat().st_size
        good_end = 0
        with open(self.path, "rb") as f:
            pos = 0
            while pos + _HEADER.size <= size:
                f.seek(pos)
                crc, klen, vlen = _HEADER.unpack(f.read(_HEADER.size))
                end = pos + _HEADER.size + klen + vlen
                if end > size:
                    break                       # torn write
                key = f.read(klen)
                value = f.read(vlen)
                if _crc(key, value) != crc:
                    break                       # corrupt tail
                self.positions.append(pos)
                good_end = end
                pos = end
        if good_end != size:
            with open(self.path, "r+b") as f:
                f.truncate(good_end)
        self.next_pos = good_end

    @property
    def count(self) -> int:
        return len(self.positions)

    @property
    def bytes(self) -> int:
        return self.next_pos

    def append(self, key: bytes, value: bytes) -> int:
        rec = _HEADER.pack(_crc(key, value), len(key), len(value)) + key + value
        self.positions.append(self.next_pos)
        self._fh.write(rec)
        self.next_pos += len(rec)
        return self.base_offset + len(self.positions) - 1

    def flush(self, fsync: bool = False) -> None:
        self._fh.flush()
        if fsync:
            os.fsync(self._fh.fileno())

    def read(self, rel_index: int) -> tuple[bytes, bytes]:
        pos = self.positions[rel_index]
        with open(self.path, "rb") as f:
            f.seek(pos)
            crc, klen, vlen = _HEADER.unpack(f.read(_HEADER.size))
            key = f.read(klen)
            value = f.read(vlen)
        if _crc(key, value) != crc:
            raise CorruptRecord(f"{self.path}@{pos}")
        return key, value

    def read_range(self, rel_start: int, max_records: int
                   ) -> list[tuple[bytes, bytes]]:
        """Batched sequential read — one open/seek for the whole range."""
        out: list[tuple[bytes, bytes]] = []
        if rel_start >= len(self.positions):
            return out
        with open(self.path, "rb") as f:
            f.seek(self.positions[rel_start])
            for _ in range(min(max_records, len(self.positions) - rel_start)):
                crc, klen, vlen = _HEADER.unpack(f.read(_HEADER.size))
                key = f.read(klen)
                value = f.read(vlen)
                if _crc(key, value) != crc:
                    raise CorruptRecord(str(self.path))
                out.append((key, value))
        return out

    def close(self) -> None:
        self._fh.close()


class _Partition:
    def __init__(self, path: Path, segment_bytes: int) -> None:
        self.path = path
        self.segment_bytes = segment_bytes
        self.lock = threading.Lock()
        path.mkdir(parents=True, exist_ok=True)
        bases = sorted(int(p.stem) for p in path.glob("*.seg"))
        self.segments: list[_Segment] = []
        expected_base = 0
        for b in bases:
            seg = _Segment(path / f"{b:020d}.seg", b)
            # (gap would mean a deleted-by-retention prefix; allowed)
            self.segments.append(seg)
            expected_base = b + seg.count
        if not self.segments:
            self.segments.append(_Segment(path / f"{0:020d}.seg", 0))

    @property
    def active(self) -> _Segment:
        return self.segments[-1]

    @property
    def begin_offset(self) -> int:
        return self.segments[0].base_offset

    @property
    def end_offset(self) -> int:
        a = self.active
        return a.base_offset + a.count

    def append(self, key: bytes, value: bytes) -> int:
        with self.lock:
            if self.active.bytes >= self.segment_bytes:
                self.active.flush()
                base = self.end_offset
                self.segments.append(
                    _Segment(self.path / f"{base:020d}.seg", base))
            return self.active.append(key, value)

    def flush(self, fsync: bool = False) -> None:
        with self.lock:
            self.active.flush(fsync)

    def read(self, offset: int, max_records: int) -> list[tuple[int, bytes, bytes]]:
        with self.lock:
            segs = list(self.segments)
        out: list[tuple[int, bytes, bytes]] = []
        for seg in segs:
            if not out and offset >= seg.base_offset + seg.count:
                continue
            rel = max(0, offset - seg.base_offset)
            for key, value in seg.read_range(rel, max_records - len(out)):
                out.append((seg.base_offset + rel, key, value))
                rel += 1
            if len(out) >= max_records:
                break
        return out

    def enforce_retention(self, retention_bytes: int) -> int:
        """Drop oldest whole segments beyond the size budget. Returns the
        number of segments deleted (paper §I: 'delete the portions that are
        not useful')."""
        deleted = 0
        with self.lock:
            total = sum(s.bytes for s in self.segments)
            while len(self.segments) > 1 and total > retention_bytes:
                victim = self.segments.pop(0)
                total -= victim.bytes
                victim.close()
                victim.path.unlink(missing_ok=True)
                deleted += 1
        return deleted

    def close(self) -> None:
        with self.lock:
            for s in self.segments:
                s.close()


class PartitionedLog:
    """Multi-topic durable log.

    Thread-safe. ``append`` is at-least-once from the producer's view (the
    producer retries on timeout; dedup upstream or idempotent consumers
    downstream handle repeats — paper §III.B.1).
    """

    def __init__(self, root: str | Path,
                 segment_bytes: int = DEFAULT_SEGMENT_BYTES,
                 fsync_every: int = 0) -> None:
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.segment_bytes = segment_bytes
        self.fsync_every = fsync_every
        self._topics: dict[str, list[_Partition]] = {}
        self._lock = threading.Lock()
        self._appended_since_sync = 0
        # re-open any topics already on disk (crash recovery)
        for tdir in sorted(self.root.iterdir()) if self.root.exists() else []:
            if tdir.is_dir():
                parts = sorted(int(p.name) for p in tdir.iterdir() if p.is_dir())
                if parts:
                    self._topics[tdir.name] = [
                        _Partition(tdir / str(i), segment_bytes)
                        for i in range(max(parts) + 1)]

    # -- topic admin ----------------------------------------------------------
    def create_topic(self, topic: str, partitions: int = 1) -> None:
        with self._lock:
            if topic in self._topics:
                if len(self._topics[topic]) != partitions:
                    raise ValueError(
                        f"topic {topic!r} exists with "
                        f"{len(self._topics[topic])} partitions")
                return
            self._topics[topic] = [
                _Partition(self.root / topic / str(i), self.segment_bytes)
                for i in range(partitions)]

    def topics(self) -> list[str]:
        with self._lock:
            return sorted(self._topics)

    def num_partitions(self, topic: str) -> int:
        return len(self._part_list(topic))

    def _part_list(self, topic: str) -> list[_Partition]:
        with self._lock:
            if topic not in self._topics:
                raise KeyError(f"unknown topic {topic!r}")
            return self._topics[topic]

    # -- producer --------------------------------------------------------------
    def append(self, topic: str, key: bytes, value: bytes,
               partition: int | None = None) -> tuple[int, int]:
        parts = self._part_list(topic)
        if partition is None:
            partition = zlib.crc32(key) % len(parts) if key else 0
        off = parts[partition].append(key, value)
        if self.fsync_every:
            self._appended_since_sync += 1
            if self._appended_since_sync >= self.fsync_every:
                parts[partition].flush(fsync=True)
                self._appended_since_sync = 0
        return partition, off

    def flush(self, fsync: bool = True) -> None:
        with self._lock:
            topics = list(self._topics.values())
        for parts in topics:
            for p in parts:
                p.flush(fsync)

    # -- consumer --------------------------------------------------------------
    def read(self, topic: str, partition: int, offset: int,
             max_records: int = 512) -> list[LogRecord]:
        # make appended-but-unflushed records visible to readers
        part = self._part_list(topic)[partition]
        part.flush(fsync=False)
        return [LogRecord(topic, partition, off, k, v)
                for off, k, v in part.read(offset, max_records)]

    def begin_offset(self, topic: str, partition: int) -> int:
        return self._part_list(topic)[partition].begin_offset

    def end_offset(self, topic: str, partition: int) -> int:
        return self._part_list(topic)[partition].end_offset

    def end_offsets(self, topic: str) -> list[int]:
        return [p.end_offset for p in self._part_list(topic)]

    def enforce_retention(self, topic: str, retention_bytes: int) -> int:
        return sum(p.enforce_retention(retention_bytes)
                   for p in self._part_list(topic))

    def close(self) -> None:
        with self._lock:
            for parts in self._topics.values():
                for p in parts:
                    p.close()
            self._topics.clear()
