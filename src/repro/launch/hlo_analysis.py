"""Post-SPMD HLO analysis: collective-byte accounting for the roofline.

``cost_analysis()`` doesn't expose collective traffic, so we parse the
compiled per-device HLO text and sum the output-operand sizes of every
collective op, weighted by ring-cost multipliers derived from the parsed
``replica_groups=[G,S]<=[N]`` group size S:

  all-gather          bytes × (S-1)/S      (each device receives S-1 shards)
  reduce-scatter      bytes × (S-1)        (input = S × output)
  all-reduce          bytes × 2(S-1)/S     (ring RS + AG)
  all-to-all          bytes × (S-1)/S
  collective-permute  bytes × 1

Shapes in the post-SPMD module are PER-DEVICE, so the resulting byte count
is per-chip traffic; the roofline divides by per-link bandwidth.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %all-gather.3 = bf16[16,512,448]{2,1,0} all-gather(...)
_OP_RE = re.compile(
    r"=\s*(?P<outs>\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"(?P<dt>[a-z0-9]+)\[(?P<dims>[0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(?P<g>\d+),(?P<s>\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group("s"))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return default


def _multiplier(op: str, s: int) -> float:
    if s <= 1:
        return 0.0
    if op == "all-gather":
        return (s - 1) / s
    if op == "reduce-scatter":
        return float(s - 1)
    if op == "all-reduce":
        return 2 * (s - 1) / s
    if op == "all-to-all":
        return (s - 1) / s
    return 1.0                     # collective-permute


# computation headers: "%region_0.24 (arg: (s32[], ...)) -> ... {" — the arg
# list may nest parens, so match only the leading name and the trailing "{".
_COMP_HEADER_RE = re.compile(
    r"^\s*(?:ENTRY\s+)?(%[\w.\-]+)\s*\(.*->.*\{\s*$")
_WHILE_BODY_RE = re.compile(r"\bwhile\(.*?body=(%[\w.\-]+)")


def _computation_depths(hlo_text: str) -> dict[str, int]:
    """Map computation name -> while-nesting depth (entry = 0). A computation
    referenced as a while body sits one level below the computation holding
    the while op."""
    current = None
    body_parent: dict[str, str] = {}
    comp_lines: dict[str, list[str]] = {}
    entry = None
    for line in hlo_text.splitlines():
        m = _COMP_HEADER_RE.match(line)
        if m:
            current = m.group(1)
            comp_lines[current] = []
            if line.lstrip().startswith("ENTRY"):
                entry = current
            continue
        if current is not None:
            comp_lines[current].append(line)
            wm = _WHILE_BODY_RE.search(line)
            if wm:
                body_parent[wm.group(1)] = current
    depths: dict[str, int] = {}

    def depth_of(comp: str, seen=()) -> int:
        if comp in depths:
            return depths[comp]
        if comp in seen:
            return 0
        parent = body_parent.get(comp)
        if parent is None:
            d = 0                      # entry or non-loop computation
        else:
            d = depth_of(parent, seen + (comp,)) + 1
        depths[comp] = d
        return d

    for comp in comp_lines:
        depth_of(comp)
    return depths


def collective_bytes(hlo_text: str, n_devices: int,
                     trip_table: dict[int, float] | None = None) -> dict:
    """Per-chip collective traffic, ring-weighted and TRIP-COUNT-CORRECTED.

    XLA's cost/byte analyses count while bodies once; ``trip_table`` maps
    while-nesting depth -> per-body trip count (from the known scan
    structure: launch/jaxpr_cost.loop_trip_table). A collective at depth d
    is multiplied by the product of trips at depths 1..d.
    """
    trip_table = trip_table or {}
    depths = _computation_depths(hlo_text)

    def trips_for(depth: int) -> float:
        mult = 1.0
        for d in range(1, depth + 1):
            mult *= trip_table.get(d, 1.0)
        return mult

    ops = defaultdict(lambda: {"count": 0, "bytes": 0, "weighted": 0.0})
    examples = []
    current = None
    for line in hlo_text.splitlines():
        hm = _COMP_HEADER_RE.match(line)
        if hm:
            current = hm.group(1)
            continue
        if "-done(" in line:          # paired with -start; count once
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        nbytes = _shape_bytes(m.group("outs"))
        s = _group_size(line, n_devices)
        depth = depths.get(current, 0)
        trips = trips_for(depth)
        w = nbytes * _multiplier(op, s) * trips
        ops[op]["count"] += 1
        ops[op]["bytes"] += nbytes
        ops[op]["weighted"] += w
        if len(examples) < 40:
            examples.append({"op": op, "bytes": nbytes, "group": s,
                             "depth": depth, "trips": trips,
                             "line": line.strip()[:160]})
    total_w = sum(v["weighted"] for v in ops.values())
    total_raw = sum(v["bytes"] for v in ops.values())
    return {"total_bytes": total_w, "raw_bytes": total_raw,
            "ops": {k: dict(v) for k, v in ops.items()},
            "examples": examples}


def memory_stats(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:          # backend without memory analysis
        return {"error": str(e)}
    fields = ("generated_code_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "alias_size_in_bytes",
              "temp_size_in_bytes")
    out = {f: int(getattr(ma, f, 0) or 0) for f in fields}
    out["total_hbm_bytes"] = (out["argument_size_in_bytes"]
                              + out["output_size_in_bytes"]
                              - out["alias_size_in_bytes"]
                              + out["temp_size_in_bytes"])
    return out


def cost_stats(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception as e:
        return {"error": str(e)}
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            "transcendentals": float(ca.get("transcendentals", 0.0))}
