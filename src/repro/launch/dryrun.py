import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init). 512 placeholder host devices back the production
# meshes: 16x16 single pod and 2x16x16 multi-pod.
"""Multi-pod dry-run: .lower().compile() every (architecture × input-shape ×
mesh) cell, print memory/cost analysis, and dump roofline inputs as JSON.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --mesh multi    # 512-chip pass
  PYTHONPATH=src python -m repro.launch.dryrun --list

Artifacts: artifacts/dryrun/<mesh>/<arch>__<shape>.json
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import configs
from ..configs.shapes import SHAPES, applicable, input_specs
from ..launch.hlo_analysis import collective_bytes, cost_stats, memory_stats
from ..launch.jaxpr_cost import loop_trip_table, traced_cost
from ..launch.mesh import make_production_mesh
from ..models import Model
from ..models.common import dp_axes, param_template, unflatten
from ..models.lm import _hybrid_plan
from ..optim import OptConfig, opt_state_specs
from ..runtime.train_loop import make_train_step

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

# grad-accumulation dtype: bf16 for >=10B params so the accumulation buffer
# fits the 16 GB/chip budget alongside fp32 optimizer state (see DESIGN.md)
BF16_ACCUM_THRESHOLD = 10e9


def abstract_opt_state(cfg, mesh, parallelism: str = "tp"):
    """ShapeDtypeStructs for the AdamW state with ZeRO-1 shardings."""
    from ..models.common import resolved_spec
    from ..optim import zero_spec
    defs = param_template(cfg)
    zspecs = {path: zero_spec(d.shape, resolved_spec(d, mesh, parallelism),
                              mesh.shape["data"])
              for path, d in defs.items()}

    def tree():
        return unflatten({
            path: jax.ShapeDtypeStruct(
                d.shape, jnp.float32,
                sharding=NamedSharding(mesh, zspecs[path]))
            for path, d in defs.items()})

    count = jax.ShapeDtypeStruct((), jnp.int32,
                                 sharding=NamedSharding(mesh, P()))
    return {"m": tree(), "v": tree(), "master": tree(), "count": count}


def _layers_per_scan(cfg) -> float:
    """Average trips of one layer-scan body (hybrid splits the stack into
    full/SWA segment scans)."""
    if cfg.family == "hybrid":
        plan = _hybrid_plan(cfg)
        return cfg.num_layers / max(1, len(plan))
    return float(cfg.num_layers)


def lower_cell(arch: str, shape_name: str, mesh, *,
               num_microbatches: int | None = None,
               parallelism: str = "tp", kv_quant: bool = False,
               moe_chunked: bool = False):
    """Returns (lowered, jaxpr_cost_fn, n_devices, meta) for one cell."""
    import dataclasses
    cfg = configs.get(arch)
    if kv_quant:
        cfg = dataclasses.replace(cfg, kv_quant=True)
    if moe_chunked:
        cfg = dataclasses.replace(cfg, moe_chunk_dispatch=True)
    shape = SHAPES[shape_name]
    model = Model(cfg, mesh, parallelism=parallelism)
    inputs = input_specs(cfg, shape, mesh, parallelism=parallelism)
    dp_total = 1
    for a in dp_axes(mesh):
        dp_total *= mesh.shape[a]
    if parallelism == "fsdp":
        dp_total *= mesh.shape["model"]
        if shape.kind == "train" and shape.global_batch % dp_total != 0:
            dp_total //= mesh.shape["model"]   # hybrid FSDP: batch on data only

    if shape.kind == "train":
        if num_microbatches is None:
            num_microbatches = max(1, shape.global_batch // dp_total)
        accum = (jnp.bfloat16 if cfg.param_count() >= BF16_ACCUM_THRESHOLD
                 else jnp.float32)
        step = make_train_step(model, OptConfig(),
                               num_microbatches=num_microbatches,
                               accum_dtype=accum, donate=True)
        params = model.abstract_params()
        opt = abstract_opt_state(cfg, mesh, parallelism)
        step_idx = jax.ShapeDtypeStruct((), jnp.int32,
                                        sharding=NamedSharding(mesh, P()))
        with jax.set_mesh(mesh):
            lowered = step.lower(params, opt, inputs, step_idx)
        cost_fn = lambda: traced_cost(step, params, opt, inputs, step_idx)
        meta = {"kind": "train", "num_microbatches": num_microbatches,
                "accum_dtype": str(np.dtype("bfloat16") if accum == jnp.bfloat16
                                   else np.dtype("float32"))}
        trip_table = loop_trip_table(
            "train", num_layers=_layers_per_scan(cfg),
            num_microbatches=num_microbatches,
            kv_blocks=max(1, shape.seq_len // (cfg.ssm_chunk or 512))
            if cfg.family in ("ssm", "hybrid") else 1)
    elif shape.kind == "prefill":
        params = model.abstract_params()
        fn = jax.jit(lambda p, b: model.prefill(p, b))
        with jax.set_mesh(mesh):
            lowered = fn.lower(params, inputs)
        cost_fn = lambda: traced_cost(fn, params, inputs)
        meta = {"kind": "prefill"}
        kvb = max(shape.seq_len // 512,
                  (shape.seq_len // cfg.ssm_chunk)
                  if cfg.family in ("ssm", "hybrid") else 1)
        trip_table = loop_trip_table("prefill",
                                     num_layers=_layers_per_scan(cfg),
                                     kv_blocks=kvb)
    else:  # decode
        params = model.abstract_params()
        cache = model.abstract_cache(shape.global_batch, shape.seq_len)
        fn = jax.jit(model.decode_step, donate_argnums=(1,))
        with jax.set_mesh(mesh):
            lowered = fn.lower(params, cache, inputs["tokens"])
        cost_fn = lambda: traced_cost(fn, params, cache, inputs["tokens"])
        meta = {"kind": "decode"}
        trip_table = loop_trip_table("decode",
                                     num_layers=_layers_per_scan(cfg))
    meta["trip_table"] = {str(k): v for k, v in trip_table.items()}
    return lowered, cost_fn, trip_table, mesh.devices.size, meta, cfg, shape


def run_cell(arch: str, shape_name: str, mesh_name: str, mesh,
             out_dir: Path, parallelism: str = "tp",
             kv_quant: bool = False, moe_chunked: bool = False) -> dict:
    t0 = time.monotonic()
    lowered, cost_fn, trip_table, n_dev, meta, cfg, shape = lower_cell(
        arch, shape_name, mesh, parallelism=parallelism, kv_quant=kv_quant,
        moe_chunked=moe_chunked)
    meta["parallelism"] = parallelism
    meta["kv_quant"] = kv_quant
    t_lower = time.monotonic() - t0
    t0 = time.monotonic()
    compiled = lowered.compile()
    t_compile = time.monotonic() - t0

    mem = memory_stats(compiled)
    cost = cost_stats(compiled)                 # raw XLA (loops counted once)
    jcost = cost_fn().as_dict()                 # exact trip-count-aware, GLOBAL
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    coll = collective_bytes(hlo, n_dev, trip_table)

    art = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "n_devices": n_dev, "meta": meta,
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "kind": shape.kind,
        "lower_sec": round(t_lower, 2), "compile_sec": round(t_compile, 2),
        "memory": mem,
        "cost_xla_raw": cost,                   # documented undercount
        "cost_traced_global": jcost,            # divide by n_devices per chip
        "collectives": {k: v for k, v in coll.items() if k != "examples"},
        "collective_examples": coll["examples"][:12],
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{arch}__{shape_name}.json").write_text(json.dumps(art, indent=1))

    print(f"[{mesh_name}] {arch} × {shape_name}: compile {t_compile:.1f}s | "
          f"per-chip flops {jcost['flops']/n_dev:.3e} | "
          f"hbm {mem.get('total_hbm_bytes', 0)/2**30:.2f} GiB | "
          f"collective {coll['total_bytes']/2**20:.1f} MiB/chip")
    print(f"  memory_analysis: {mem}")
    print(f"  cost_analysis(raw xla): {cost}")
    return art


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="both")
    ap.add_argument("--parallelism", choices=("tp", "fsdp"), default="tp")
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8 KV cache for decode cells")
    ap.add_argument("--moe-chunked", action="store_true",
                    help="all-to-all MoE dispatch (per-data-shard capacity)")
    ap.add_argument("--suffix", default="",
                    help="artifact directory suffix (e.g. -fsdp)")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out", default=str(ARTIFACTS))
    ap.add_argument("--cache-dir", default="/tmp/jax_cache")
    args = ap.parse_args()

    jax.config.update("jax_compilation_cache_dir", args.cache_dir)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 1.0)

    cells = []
    for arch in (configs.ARCHS if args.arch is None else [args.arch]):
        cfg = configs.get(arch)
        for shape_name in (SHAPES if args.shape is None else [args.shape]):
            ok, why = applicable(cfg, shape_name)
            cells.append((arch, shape_name, ok, why))
    if args.list:
        for c in cells:
            print(c)
        return 0

    meshes = {"single": make_production_mesh(multi_pod=False),
              "multi": make_production_mesh(multi_pod=True)}
    if args.mesh != "both":
        meshes = {args.mesh: meshes[args.mesh]}

    failures, skipped, passed = [], [], []
    for mesh_name, mesh in meshes.items():
        out_dir = Path(args.out) / (mesh_name + args.suffix)
        for arch, shape_name, ok, why in cells:
            if not ok:
                skipped.append((mesh_name, arch, shape_name, why))
                print(f"[{mesh_name}] {arch} × {shape_name}: SKIP ({why})")
                # record the skip as an artifact for the roofline table
                out_dir.mkdir(parents=True, exist_ok=True)
                (out_dir / f"{arch}__{shape_name}.json").write_text(
                    json.dumps({"arch": arch, "shape": shape_name,
                                "mesh": mesh_name, "skipped": why}))
                continue
            try:
                run_cell(arch, shape_name, mesh_name, mesh, out_dir,
                         parallelism=args.parallelism,
                         kv_quant=args.kv_quant,
                         moe_chunked=args.moe_chunked)
                passed.append((mesh_name, arch, shape_name))
            except Exception as e:   # noqa: BLE001 — report, keep going
                traceback.print_exc()
                failures.append((mesh_name, arch, shape_name, repr(e)[:200]))

    print(f"\n=== dry-run summary: {len(passed)} passed, "
          f"{len(skipped)} skipped (documented), {len(failures)} FAILED ===")
    for f in failures:
        print("FAILED:", f)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
