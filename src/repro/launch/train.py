"""Production training launcher.

On a real TPU cluster each host runs this under its own process (jax
distributed init), the mesh spans the pod(s), and the loader is one
consumer-group member per host. On this container it runs the same code on
one CPU device at reduced scale unless --dryrun-mesh is requested.

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --reduced --steps 20 --workdir /tmp/run1
  PYTHONPATH=src python -m repro.launch.train --resume --workdir /tmp/run1
"""
from __future__ import annotations

import argparse
import tempfile
from pathlib import Path

from .. import configs
from ..core import PartitionedLog, make_flowfile
from ..core.sources import corpus_documents
from ..data.pipeline import attach_training_loader
from ..models import Model
from ..optim import OptConfig
from ..runtime import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b",
                    choices=list(configs.ARCHS))
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-scale config (required on this container)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--docs", type=int, default=30_000)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--workdir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at", type=int, default=-1,
                    help="inject a failure at this step (recovery drills)")
    args = ap.parse_args()

    root = Path(args.workdir or tempfile.mkdtemp(prefix="train_"))
    root.mkdir(parents=True, exist_ok=True)
    cfg = configs.get_reduced(args.arch) if args.reduced else configs.get(args.arch)

    log = PartitionedLog(root / "log")
    if "articles" not in log.topics():
        log.create_topic("articles", partitions=8)
        batch = [make_flowfile(doc, text=doc).to_record()
                 for doc in corpus_documents(args.docs)]
        for p in range(8):
            log.append_batch("articles", batch[p::8], partition=p)
        log.flush(fsync=False)

    grp, loader = attach_training_loader(log, batch_size=args.batch,
                                         seq_len=args.seq)
    model = Model(cfg)
    trainer = Trainer(
        model, loader,
        OptConfig(peak_lr=1e-3, warmup_steps=20, total_steps=args.steps),
        TrainerConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                      ckpt_dir=str(root / "ckpt"), log_every=10,
                      fail_at_step=args.fail_at))
    if args.resume:
        resumed = trainer.resume()
        print(f"resume: {'ok, at step ' + str(trainer.step_idx) if resumed else 'no checkpoint found'}")
    out = trainer.run()
    for h in trainer.history[-5:]:
        print(h)
    print(out)
    log.close()


if __name__ == "__main__":
    main()
