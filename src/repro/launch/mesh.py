"""Production mesh factory.

Single pod: 16×16 = 256 chips (v5e pod), axes (data, model).
Multi-pod:  2×16×16 = 512 chips, axes (pod, data, model); 'pod' extends the
data-parallel dimension — the step functions never reference pod count, so
scaling to N pods is a mesh-shape change only.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary meshes (tests use small ones under forced host devices)."""
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
