"""Exact FLOP/byte accounting from the jaxpr (trip-count-aware).

``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified on this
container: a 10-iteration scan of matmuls reports 1 matmul of flops), so for
scan-over-layers + grad-accumulation models it under-counts by ~L×n_micro.
This walker traverses the closed jaxpr instead: ``scan`` carries an exact
``length`` parameter, so every nested loop is multiplied correctly, and the
remat-recompute inside backward scan bodies is explicit in the jaxpr.

Counted:
  dot_general      2·M·N·K·batch flops; operand+output bytes (HBM model)
  conv             2·spatial·Cin·Cout·K flops
  gather/scatter   output/update bytes (index traffic model)
  elementwise      1 flop/element (exp/log/… tallied as transcendentals too)

The result is GLOBAL (pre-SPMD) — divide by chip count for per-chip values.
Padding waste introduced by uneven GSPMD tilings is NOT visible here (it
would be in the per-device HLO); we avoid uneven shardings by construction.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax
import numpy as np

_ELEMENTWISE = {
    "add", "sub", "mul", "div", "max", "min", "neg", "abs", "floor",
    "ceil", "round", "sign", "and", "or", "not", "xor", "select_n",
    "ge", "gt", "le", "lt", "eq", "ne", "rem", "pow", "integer_pow",
    "clamp", "nextafter", "real", "imag", "conj", "square",
}
_TRANSCENDENTAL = {
    "exp", "log", "log1p", "expm1", "sin", "cos", "tan", "tanh", "erf",
    "erfc", "erf_inv", "logistic", "rsqrt", "sqrt", "cbrt", "exp2", "atan2",
}
_REDUCTION = {"reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
              "reduce_and", "reduce_or", "argmax", "argmin",
              "cumsum", "cumlogsumexp", "cummax", "cummin", "cumprod"}
_CALL_PRIMS = {"jit", "pjit", "closed_call", "core_call", "remat_call",
               "xla_call", "custom_jvp_call", "custom_vjp_call",
               "custom_vjp_call_jaxpr", "checkpoint", "remat", "remat2",
               "custom_jvp_call_jaxpr"}


@dataclass
class Cost:
    flops: float = 0.0
    dot_flops: float = 0.0
    bytes: float = 0.0            # dot/gather/scatter HBM-traffic model
    transcendentals: float = 0.0

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.dot_flops += o.dot_flops
        self.bytes += o.bytes
        self.transcendentals += o.transcendentals
        return self

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.dot_flops * k, self.bytes * k,
                    self.transcendentals * k)

    def as_dict(self) -> dict:
        return {"flops": self.flops, "dot_flops": self.dot_flops,
                "bytes": self.bytes, "transcendentals": self.transcendentals}


def _size(aval) -> int:
    try:
        return int(np.prod(aval.shape)) if aval.shape else 1
    except Exception:
        return 0


def _bytes(aval) -> int:
    try:
        return _size(aval) * np.dtype(aval.dtype).itemsize
    except Exception:
        return 0


def _dot_cost(eqn, taint=None) -> Cost:
    """taint: var -> bytes/element for tensors whose HBM STORAGE is narrower
    than their compute dtype (e.g. int8 KV dequantized on the fly — the TPU
    kernel streams int8 from HBM and dequantizes in VMEM)."""
    dn = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dn
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    out = eqn.outvars[0].aval
    k = 1
    for d in lc:
        k *= lhs.shape[d]
    flops = 2.0 * _size(out) * k

    def opbytes(var):
        if taint is not None and var in taint:
            return _size(var.aval) * taint[var]
        return _bytes(var.aval)

    by = opbytes(eqn.invars[0]) + opbytes(eqn.invars[1]) + _bytes(out)
    return Cost(flops=flops, dot_flops=flops, bytes=by)


def _conv_cost(eqn) -> Cost:
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    out = eqn.outvars[0].aval
    # flops = 2 * out_elems * (K_spatial * C_in / groups)
    kern_elems = _size(rhs) / max(1, rhs.shape[-1] if rhs.shape else 1)
    flops = 2.0 * _size(out) * kern_elems
    return Cost(flops=flops, dot_flops=flops,
                bytes=_bytes(lhs) + _bytes(rhs) + _bytes(out))


_TAINT_PROP = {"reshape", "transpose", "broadcast_in_dim", "squeeze",
               "slice", "dynamic_slice", "rev", "mul", "add", "sub",
               "convert_element_type", "concatenate"}


def jaxpr_cost(jaxpr) -> Cost:
    """Recursively accumulate cost over a (closed) jaxpr."""
    total = Cost()
    inner = getattr(jaxpr, "jaxpr", jaxpr)
    taint: dict = {}          # narrow-storage provenance (int8 dequant chains)
    for eqn in inner.eqns:
        name = eqn.primitive.name
        # propagate narrow-storage taint: convert-from-int8 (and elementwise
        # chains of it, e.g. ×scale) keeps the 1-byte HBM cost
        if name in _TAINT_PROP and eqn.outvars:
            src = None
            for iv in eqn.invars:
                if hasattr(iv, "aval") and not hasattr(iv, "val"):
                    if iv in taint and _size(iv.aval) == _size(eqn.outvars[0].aval):
                        src = taint[iv]
                        break
                    if (name == "convert_element_type"
                            and str(iv.aval.dtype) in ("int8", "int4", "uint8")
                            and _size(iv.aval) == _size(eqn.outvars[0].aval)):
                        src = 1
                        break
            if src is not None:
                taint[eqn.outvars[0]] = src
        if name == "dot_general":
            total += _dot_cost(eqn, taint)
        elif name.startswith("conv_general"):
            total += _conv_cost(eqn)
        elif name == "scan":
            body = jaxpr_cost(eqn.params["jaxpr"])
            total += body.scaled(int(eqn.params["length"]))
        elif name == "while":
            # not used by our models (scan everywhere); count body once
            total += jaxpr_cost(eqn.params["body_jaxpr"])
        elif name == "cond":
            branches = [jaxpr_cost(b) for b in eqn.params["branches"]]
            if branches:
                total += max(branches, key=lambda c: c.flops)
        elif name in _CALL_PRIMS:
            for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
                if key in eqn.params:
                    total += jaxpr_cost(eqn.params[key])
                    break
        elif name in ("gather", "take", "dynamic_slice"):
            total += Cost(bytes=sum(_bytes(o.aval) for o in eqn.outvars))
        elif name in ("scatter", "scatter-add", "scatter_add",
                      "dynamic_update_slice"):
            upd = eqn.invars[-1].aval if eqn.invars else None
            total += Cost(bytes=_bytes(upd) if upd is not None else 0)
        elif name in _TRANSCENDENTAL:
            n = sum(_size(o.aval) for o in eqn.outvars)
            total += Cost(flops=float(n), transcendentals=float(n))
        elif name in _ELEMENTWISE or name in _REDUCTION:
            total += Cost(flops=float(sum(_size(o.aval) for o in eqn.outvars)))
        elif name == "custom_vjp_call":
            if "call_jaxpr" in eqn.params:
                total += jaxpr_cost(eqn.params["call_jaxpr"])
        # everything else (reshape/transpose/broadcast/convert/iota/…): free
    return total


def traced_cost(fn, *abstract_args, **kw) -> Cost:
    closed = jax.make_jaxpr(fn, **kw)(*abstract_args)
    return jaxpr_cost(closed)


# ---------------------------------------------------------------------------
# Known loop-structure multipliers for HLO collective attribution
# ---------------------------------------------------------------------------
def loop_trip_table(kind: str, *, num_layers: int, num_microbatches: int = 1,
                    kv_blocks: int = 1) -> dict[int, float]:
    """Expected trip count multiplier by while-nesting depth in the compiled
    HLO, from the scan structure we built:
      train:   d1 = grad-accum scans (fwd+bwd, n_micro each),
               d2 = layer scans (L per microbatch)
      prefill: d1 = layer scan (L), d2 = attention KV-block scan
      decode:  d1 = layer scan (L)
    Multiple sibling bodies at a depth (hybrid segments, fwd/bwd pairs) share
    the depth's PER-BODY multiplier — totals stay correct because each body
    contributes once per surrounding iteration.
    """
    if kind == "train":
        if num_microbatches > 1:
            return {1: float(num_microbatches),
                    2: float(num_layers),
                    3: float(kv_blocks)}
        return {1: float(num_layers), 2: float(kv_blocks)}
    if kind == "prefill":
        return {1: float(num_layers), 2: float(kv_blocks)}
    return {1: float(num_layers)}
