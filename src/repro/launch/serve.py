"""Serving launcher: consume 'requests' topic, publish 'completions'.

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --reduced --requests 16
"""
from __future__ import annotations

import argparse
import json
import tempfile
from pathlib import Path

import jax

from .. import configs
from ..core import ConsumerGroup, PartitionedLog
from ..core.sources import corpus_documents
from ..models import Model
from ..runtime import ServeConfig, Server


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b",
                    choices=list(configs.ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--workdir", default=None)
    args = ap.parse_args()

    root = Path(args.workdir or tempfile.mkdtemp(prefix="serve_"))
    cfg = configs.get_reduced(args.arch) if args.reduced else configs.get(args.arch)
    log = PartitionedLog(root / "log")
    log.create_topic("requests", partitions=4)
    log.create_topic("completions", partitions=4)
    log.append_batch("requests", [
        (str(i).encode(), json.dumps({"id": i, "prompt": doc[:80]}).encode())
        for i, doc in enumerate(corpus_documents(args.requests, seed=11))])

    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    grp = ConsumerGroup(log, "requests", "servers")
    server = Server(model, params, grp.add_member("srv0"), log,
                    ServeConfig(batch_size=args.batch,
                                prompt_len=args.prompt_len,
                                max_new_tokens=args.max_new))
    while server.serve_once():
        pass
    done = sum(log.end_offsets("completions"))
    print(f"served {server.served}, completions landed: {done}")
    log.close()


if __name__ == "__main__":
    main()
