"""Fault-tolerant checkpointing.

Design points (sized for 1000+-node deployments, exercised single-process):

* **Atomicity** — write to ``step_N.tmp`` then ``os.replace`` to ``step_N``;
  a crash mid-save never produces a checkpoint that loads.
* **Integrity** — every tensor file carries a sha256 in the manifest;
  ``restore`` verifies and *falls back to the newest intact checkpoint* if
  the latest is corrupt (disk bitrot / torn writes).
* **Exactly-once data** — the StreamingDataLoader state (consumer offsets +
  packer carry) is stored inside the checkpoint, so optimizer state and
  stream position restore in lock-step (paper §II.B made end-to-end).
* **Mesh-agnostic** — tensors are saved as full logical arrays (per-tensor
  .npy), so a restore may target a different mesh/sharding (elastic
  rescale). In a true multi-host job this becomes per-shard saving with the
  same manifest format; the single-process container exercises the logical
  path.
* **Async** — device→host snapshot is synchronous (consistency), file I/O
  happens on a background thread; ``wait()`` joins before the next save.
* **Retention** — keep the newest ``keep`` checkpoints.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any

import jax
import ml_dtypes
import numpy as np

#: numpy can't round-trip ml_dtypes through .npy without pickling; store a
#: same-width unsigned view and record the logical dtype in the manifest.
_EXTENDED_DTYPES = {
    "bfloat16": (ml_dtypes.bfloat16, np.uint16),
    "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
    "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8),
}


def _to_savable(arr: np.ndarray) -> tuple[np.ndarray, str]:
    name = arr.dtype.name
    if name in _EXTENDED_DTYPES:
        return arr.view(_EXTENDED_DTYPES[name][1]), name
    return arr, name


def _from_savable(arr: np.ndarray, logical_dtype: str) -> np.ndarray:
    if logical_dtype in _EXTENDED_DTYPES:
        return arr.view(_EXTENDED_DTYPES[logical_dtype][0])
    return arr


class CorruptCheckpoint(Exception):
    pass


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}/{k}" if prefix else str(k)))
    else:
        out[prefix] = tree
    return out


def _unflatten(flat: dict):
    root: dict = {}
    for path, v in flat.items():
        parts = path.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return root


def _sha256(path: Path) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3,
                 async_save: bool = True) -> None:
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    # ------------------------------------------------------------------
    def save(self, step: int, trees: dict[str, Any],
             meta: dict | None = None) -> None:
        """trees: name -> pytree of arrays (e.g. {'params':…, 'opt':…});
        meta: JSON-serializable (loader state, rng seeds, shape suite…)."""
        self.wait()
        # snapshot to host synchronously — the training step may mutate
        # buffers (donation) as soon as we return
        host: dict[str, np.ndarray] = {}
        for name, tree in trees.items():
            for path, leaf in _flatten(tree, name).items():
                host[path] = np.asarray(jax.device_get(leaf))
        meta = dict(meta or {})

        def write():
            try:
                tmp = self.dir / f"step_{step:010d}.tmp"
                final = self.dir / f"step_{step:010d}"
                if tmp.exists():
                    shutil.rmtree(tmp)
                tmp.mkdir(parents=True)
                manifest = {"step": step, "meta": meta, "tensors": {}}
                for path, arr in host.items():
                    fname = path.replace("/", "__") + ".npy"
                    savable, logical = _to_savable(arr)
                    np.save(tmp / fname, savable, allow_pickle=False)
                    manifest["tensors"][path] = {
                        "file": fname, "shape": list(arr.shape),
                        "dtype": logical, "sha256": _sha256(tmp / fname)}
                (tmp / "manifest.json").write_text(json.dumps(manifest))
                if final.exists():
                    shutil.rmtree(final)
                os.replace(tmp, final)
                self._enforce_retention()
            except BaseException as e:      # surfaced on next wait()
                self._error = e

        if self.async_save:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # ------------------------------------------------------------------
    def steps(self) -> list[int]:
        return sorted(int(p.name.split("_")[1]) for p in self.dir.glob("step_*")
                      if p.is_dir() and not p.name.endswith(".tmp"))

    def _enforce_retention(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    # ------------------------------------------------------------------
    def _load_verified(self, step: int) -> tuple[dict, dict]:
        d = self.dir / f"step_{step:010d}"
        manifest = json.loads((d / "manifest.json").read_text())
        flat: dict[str, np.ndarray] = {}
        for path, info in manifest["tensors"].items():
            f = d / info["file"]
            if not f.exists() or _sha256(f) != info["sha256"]:
                raise CorruptCheckpoint(f"{f} integrity check failed")
            flat[path] = _from_savable(np.load(f, allow_pickle=False),
                                       info["dtype"])
        return flat, manifest

    def restore(self, step: int | None = None) -> tuple[int, dict, dict]:
        """Returns (step, trees, meta). Falls back to older checkpoints when
        the newest is corrupt; raises if none are intact."""
        self.wait()
        candidates = self.steps()
        if step is not None:
            candidates = [s for s in candidates if s == step]
        if not candidates:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        last_err: Exception | None = None
        for s in reversed(candidates):
            try:
                flat, manifest = self._load_verified(s)
                root = _unflatten(flat)
                return s, root, manifest["meta"]
            except (CorruptCheckpoint, ValueError, OSError, KeyError) as e:
                last_err = e
                continue
        raise CorruptCheckpoint(
            f"all checkpoints corrupt under {self.dir}: {last_err}")

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None


def to_device(tree, specs=None, mesh=None):
    """Put a host pytree onto devices, optionally with NamedShardings built
    from a matching spec tree (elastic re-mesh restore path)."""
    import jax.numpy as jnp
    from jax.sharding import NamedSharding

    if specs is None or mesh is None:
        return jax.tree.map(jnp.asarray, tree)
    return jax.tree.map(
        lambda x, sp: jax.device_put(x, NamedSharding(mesh, sp)), tree, specs)
