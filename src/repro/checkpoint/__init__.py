from .manager import CheckpointManager, CorruptCheckpoint, to_device

__all__ = ["CheckpointManager", "CorruptCheckpoint", "to_device"]
