"""Public flash-attention op with backend dispatch ('xla' uses the blockwise
jnp path in models/attention.py; 'pallas' the TPU kernel; 'interpret' the
kernel body on CPU for validation)."""
from __future__ import annotations

from .kernel import flash_attention
from .ref import attention_reference


def flash(q, k, v, *, causal=True, window=0, backend: str = "pallas", **kw):
    if backend == "xla":
        return attention_reference(q, k, v, causal=causal, window=window)
    return flash_attention(q, k, v, causal=causal, window=window,
                           interpret=(backend == "interpret"), **kw)
