"""Pure-jnp oracle for flash attention: naive masked softmax attention in
fp32, GQA by repeating KV heads."""
from __future__ import annotations

import jax.numpy as jnp
import jax


def attention_reference(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B,Hq,Sq,d); k/v: (B,Hkv,Skv,d) → (B,Hq,Sq,d) fp32-accurate."""
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    if hq != hkv:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) * (d ** -0.5)
    qi = jnp.arange(sq)[:, None]
    kj = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= qi >= kj
    if window:
        mask &= (qi - kj) < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q.dtype)
