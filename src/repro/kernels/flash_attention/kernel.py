"""Pallas TPU flash attention (forward), GQA-aware, causal or sliding-window.

Tiling: grid = (batch, q_heads, Sq/BQ, Skv/BK); the innermost KV dimension is
sequential ('arbitrary') so the (BQ, d) fp32 accumulator and the (BQ,)
running max/denominator live in VMEM scratch across KV blocks — the
FlashAttention-2 schedule mapped onto the MXU:

  q block   (BQ, d)    VMEM   (revisited across KV blocks)
  k,v block (BK, d)    VMEM   (streamed HBM→VMEM per grid step)
  acc       (BQ, d)    VMEM scratch fp32
  m, l      (BQ, 128)  VMEM scratch fp32 (lane-replicated statistics)

BQ=BK=128 by default: d∈{64,128,160} keeps every matmul dim a multiple of
the 128-lane MXU tile (160 pads one dim — acceptable), and the working set
(q+k+v+acc+out ≈ 5·128·d·4B ≤ 410 KiB at d=160) fits VMEM with
double-buffering headroom.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int, bq: int, bk: int,
                  nk: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)              # (BQ, d)
    k = k_ref[0, 0].astype(jnp.float32)              # (BK, d)
    v = v_ref[0, 0]                                   # (BK, d)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    q_idx = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_idx = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= q_idx >= k_idx
    if window:
        mask &= (q_idx - k_idx) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[:, 0]                              # (BQ,)
    m_cur = jnp.maximum(m_prev, s.max(axis=1))
    corr = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_scr[:, 0] = l_scr[:, 0] * corr + p.sum(axis=1)
    m_scr[:, 0] = m_cur
    pv = jax.lax.dot_general(p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_scr[...] = acc_scr[...] * corr[:, None] + pv

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[:, 0], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                    interpret: bool = False):
    """q: (B, Hq, Sq, d); k, v: (B, Hkv, Skv, d) with Hq % Hkv == 0.
    Returns (B, Hq, Sq, d), same dtype as q."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0
    g = hq // hkv
    bq = min(bq, sq)
    bk = min(bk, skv)
    assert sq % bq == 0 and skv % bk == 0, "pad sequences to block multiples"
    nq, nk = sq // bq, skv // bk
    scale = d ** -0.5

    return pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          window=window, bq=bq, bk=bk, nk=nk),
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bi, hi, qi, ki: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bi, hi, qi, ki: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),       # running max
            pltpu.VMEM((bq, 128), jnp.float32),       # running denominator
            pltpu.VMEM((bq, d), jnp.float32),         # output accumulator
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
