"""Public fused rmsnorm op with backend dispatch."""
from .kernel import fused_residual_rmsnorm
from .ref import fused_residual_rmsnorm_reference


def rmsnorm_residual(x, residual, scale, *, eps: float = 1e-5,
                     backend: str = "pallas", **kw):
    if backend == "xla":
        return fused_residual_rmsnorm_reference(x, residual, scale, eps)
    return fused_residual_rmsnorm(x, residual, scale, eps=eps,
                                  interpret=(backend == "interpret"), **kw)
