"""Pallas TPU fused residual-add + RMSNorm.

y, res = rmsnorm(x + r) — the residual write and the normalization share one
HBM round-trip (the unfused lowering reads/writes the (R, D) activation
three times; fused does one read of x, one of r, one write each of y and
res). Grid over row blocks; (BR, D) tiles in VMEM, statistics in fp32.

BR=256 rows, D up to 8K: 256·8192·2 B = 4 MiB per operand tile — within a
16 MiB VMEM budget for x/r/y/res at D≤4096; the wrapper halves BR at larger
D to stay inside.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rmsnorm_kernel(x_ref, r_ref, s_ref, y_ref, res_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    r = r_ref[...].astype(jnp.float32)
    h = x + r
    ms = jnp.mean(h * h, axis=-1, keepdims=True)
    y = h * jax.lax.rsqrt(ms + eps) * s_ref[...].astype(jnp.float32)[None, :]
    res_ref[...] = h.astype(res_ref.dtype)
    y_ref[...] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def fused_residual_rmsnorm(x, residual, scale, *, eps: float = 1e-5,
                           block_rows: int = 256, interpret: bool = False):
    """x, residual: (R, D); scale: (D,) → (normed (R,D), new_residual (R,D))."""
    r_, d = x.shape
    br = block_rows
    while d * br * 2 * 4 > (12 << 20) and br > 8:     # stay under VMEM budget
        br //= 2
    br = min(br, r_)
    if r_ % br:
        pad = br - r_ % br
        y, res = fused_residual_rmsnorm(
            jnp.pad(x, ((0, pad), (0, 0))),
            jnp.pad(residual, ((0, pad), (0, 0))), scale, eps=eps,
            block_rows=block_rows, interpret=interpret)
        return y[:r_], res[:r_]

    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(r_ // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((br, d), lambda i: (i, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((r_, d), x.dtype),
                   jax.ShapeDtypeStruct((r_, d), x.dtype)],
        interpret=interpret,
    )(x, residual, scale)
