"""Oracle for fused residual+RMSNorm."""
import jax
import jax.numpy as jnp


def fused_residual_rmsnorm_reference(x, residual, scale, eps: float = 1e-5):
    h = x.astype(jnp.float32) + residual.astype(jnp.float32)
    ms = jnp.mean(h * h, axis=-1, keepdims=True)
    y = h * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)[None, :]
    return y.astype(x.dtype), h.astype(x.dtype)
