"""Oracle: masked single-token attention over a KV cache."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_reference(q, k_cache, v_cache, pos):
    b, hq, _, d = q.shape
    hkv, skv = k_cache.shape[1], k_cache.shape[2]
    if hq != hkv:
        rep = hq // hkv
        k_cache = jnp.repeat(k_cache, rep, axis=1)
        v_cache = jnp.repeat(v_cache, rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * (d ** -0.5)
    valid = jnp.arange(skv)[None, None, None, :] <= pos
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v_cache.astype(jnp.float32)).astype(q.dtype)
