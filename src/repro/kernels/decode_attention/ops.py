"""Public decode-attention op with backend dispatch."""
from .kernel import decode_attention
from .ref import decode_reference


def decode(q, k_cache, v_cache, pos, *, backend: str = "pallas", **kw):
    if backend == "xla":
        return decode_reference(q, k_cache, v_cache, pos)
    return decode_attention(q, k_cache, v_cache, pos,
                            interpret=(backend == "interpret"), **kw)
