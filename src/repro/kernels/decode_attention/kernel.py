"""Pallas TPU decode attention (flash-decode): one query token per sequence
against a long KV cache, GQA-aware, with a scalar-prefetched position bound.

Grid = (B, Hq, Skv/BK); the KV dimension is sequential, so the running
(max, denominator, accumulator) live in VMEM scratch — a split-KV
flash-decode. The current position arrives via scalar prefetch (SMEM), so
blocks wholly beyond ``pos`` skip their compute (the loads are still
scheduled by the pipeline, masked compute costs ~nothing on the VPU).

Blocks: q (1,1,1,d) VMEM · k/v (1,1,BK,d) VMEM · acc (8,d) fp32 scratch.
BK=512 default — decode is HBM-bandwidth-bound; larger KV tiles amortize
the grid overhead while staying ≤ 512·160·2·2 B ≈ 320 KiB of VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BK = 512
NEG_INF = -1e30


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, scale: float, bk: int, nk: int):
    ki = pl.program_id(2)
    pos = pos_ref[0]

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(ki * bk <= pos)                      # skip blocks past position
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)       # (1, d)
        k = k_ref[0, 0].astype(jnp.float32)       # (BK, d)
        v = v_ref[0, 0]                           # (BK, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        k_idx = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        s = jnp.where(k_idx <= pos, s, NEG_INF)   # (1, BK)
        m_prev = m_scr[0, 0]
        m_cur = jnp.maximum(m_prev, s.max())
        corr = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur)
        l_scr[0, 0] = l_scr[0, 0] * corr + p.sum()
        m_scr[0, 0] = m_cur
        pv = jax.lax.dot_general(p.astype(v.dtype), v,
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[0:1, :] = acc_scr[0:1, :] * corr + pv

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[0, 0], 1e-30)
        o_ref[0, 0] = (acc_scr[0:1, :] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def decode_attention(q, k_cache, v_cache, pos, *, bk: int = DEFAULT_BK,
                     interpret: bool = False):
    """q: (B,Hq,1,d); caches: (B,Hkv,S,d); pos: int32 scalar (last valid
    index). Returns (B,Hq,1,d)."""
    b, hq, _, d = q.shape
    _, hkv, skv, _ = k_cache.shape
    g = hq // hkv
    bk = min(bk, skv)
    assert skv % bk == 0
    nk = skv // bk
    scale = d ** -0.5
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, hq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, 1, d), lambda bi, hi, ki, pos: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, hi, ki, pos: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, hi, ki, pos: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, d),
                               lambda bi, hi, ki, pos: (bi, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((8, 128), jnp.float32),
            pltpu.VMEM((8, 128), jnp.float32),
            pltpu.VMEM((8, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, bk=bk, nk=nk),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hq, 1, d), q.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(pos_arr, q, k_cache, v_cache)
