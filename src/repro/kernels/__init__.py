"""Pallas TPU kernels for the perf-critical compute layers, each shipped as:

  kernel.py — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper with backend dispatch
              ('xla' = pure-jnp lowering used on the CPU dry-run,
               'pallas' = TPU kernel, 'interpret' = kernel body executed in
               Python for CPU validation)
  ref.py    — pure-jnp oracle the tests sweep shapes/dtypes against

Kernels: flash_attention (train/prefill), decode_attention (KV-cache decode),
ssd (Mamba-2 state-space-dual chunk scan), rmsnorm (fused residual+norm).
"""
