"""Pure-jnp oracles for the Mamba-2 SSD (state-space dual) scan.

``ssd_sequential``  — literal per-timestep recurrence (ground truth).
``ssd_chunked``     — the chunked SSD algorithm (Mamba-2 paper §6): quadratic
                      attention-like compute inside chunks, linear state
                      passing between chunks. This is what the model lowers
                      on the dry-run and what the Pallas kernel implements.

Shapes (already projected/conv'd by the caller):
  x  (B, S, H, P)   head channels
  dt (B, S, H)      post-softplus step sizes
  A  (H,)           negative decay rates
  B  (B, S, H, N)   input maps (groups already broadcast to heads)
  C  (B, S, H, N)   output maps
returns y (B, S, H, P), final_state (B, H, N, P)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_sequential(x, dt, A, B, C, initial_state=None):
    b, s, h, p = x.shape
    n = B.shape[-1]
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    Bf, Cf, Af = B.astype(jnp.float32), C.astype(jnp.float32), A.astype(jnp.float32)
    state = (jnp.zeros((b, h, n, p), jnp.float32) if initial_state is None
             else initial_state.astype(jnp.float32))

    def step(state, inp):
        xt, dtt, Bt, Ct = inp                     # (b,h,p),(b,h),(b,h,n)
        decay = jnp.exp(dtt * Af)                 # (b,h)
        upd = jnp.einsum("bhn,bhp->bhnp", Bt * dtt[..., None], xt)
        state = state * decay[..., None, None] + upd
        y = jnp.einsum("bhn,bhnp->bhp", Ct, state)
        return state, y

    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0))
    state, ys = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), state


def ssd_chunked(x, dt, A, B, C, chunk: int = 64, initial_state=None):
    b, s, h, p = x.shape
    n = B.shape[-1]
    if s % chunk:            # pad to a chunk multiple; dt=0 ⇒ padded steps
        pad = chunk - s % chunk  # are identity on the state and emit y=0
        padder = lambda t: jnp.pad(t, [(0, 0), (0, pad)] +
                                   [(0, 0)] * (t.ndim - 2))
        y, state = ssd_chunked(padder(x), padder(dt), A, padder(B),
                               padder(C), chunk, initial_state)
        return y[:, :s], state
    nc, q = s // chunk, chunk
    xf = x.astype(jnp.float32).reshape(b, nc, q, h, p)
    dtf = dt.astype(jnp.float32).reshape(b, nc, q, h)
    Bf = B.astype(jnp.float32).reshape(b, nc, q, h, n)
    Cf = C.astype(jnp.float32).reshape(b, nc, q, h, n)
    Af = A.astype(jnp.float32)

    a = dtf * Af                                   # (b,c,q,h) negative
    cum = jnp.cumsum(a, axis=2)                    # inclusive

    # ---- intra-chunk (quadratic within chunk) -----------------------------
    scores = jnp.einsum("bcihn,bcjhn->bchij", Cf, Bf)
    li = cum[:, :, :, :, ]                         # (b,c,i,h)
    L = jnp.exp(li.transpose(0, 1, 3, 2)[..., :, None]
                - cum.transpose(0, 1, 3, 2)[..., None, :])   # (b,c,h,i,j)
    iq = jnp.arange(q)
    L = jnp.where(iq[:, None] >= iq[None, :], L, 0.0)
    M = scores * L * dtf.transpose(0, 1, 3, 2)[..., None, :]  # dt_j
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", M, xf)

    # ---- chunk summaries ----------------------------------------------------
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)            # (b,c,j,h)
    Bx = jnp.einsum("bcjhn,bcjhp->bchnp",
                    Bf * (dtf * decay_to_end)[..., None], xf)  # per-chunk state inject
    chunk_decay = jnp.exp(cum[:, :, -1, :])                    # (b,c,h)
    state0 = (jnp.zeros((b, h, n, p), jnp.float32) if initial_state is None
              else initial_state.astype(jnp.float32))

    def step(state, inp):
        bx_c, cd_c, c_c, cum_c = inp
        # y from carried-in state
        cin = c_c * jnp.exp(cum_c)[..., None]                  # (b,i,h,n)
        y_inter = jnp.einsum("bihn,bhnp->bihp", cin, state)
        state = state * cd_c[:, :, None, None] + bx_c
        return state, y_inter

    xs = (jnp.moveaxis(Bx, 1, 0), jnp.moveaxis(chunk_decay, 1, 0),
          jnp.moveaxis(Cf, 1, 0), jnp.moveaxis(cum, 1, 0))
    state, y_inter = jax.lax.scan(step, state0, xs)
    y = y_intra + jnp.moveaxis(y_inter, 0, 1)
    return y.reshape(b, s, h, p).astype(x.dtype), state


def ssd_decode_step(state, x_t, dt_t, A, B_t, C_t):
    """One recurrent step. state (B,H,N,P) fp32; x_t (B,H,P); dt_t (B,H);
    B_t/C_t (B,H,N). Returns (y (B,H,P), new_state)."""
    dtf = dt_t.astype(jnp.float32)
    decay = jnp.exp(dtf * A.astype(jnp.float32))
    upd = jnp.einsum("bhn,bhp->bhnp",
                     B_t.astype(jnp.float32) * dtf[..., None],
                     x_t.astype(jnp.float32))
    state = state * decay[..., None, None] + upd
    y = jnp.einsum("bhn,bhnp->bhp", C_t.astype(jnp.float32), state)
    return y.astype(x_t.dtype), state
