"""Public SSD op with backend dispatch.

'xla'       — chunked pure-jnp lowering (default; what the dry-run compiles)
'pallas'    — TPU Pallas kernel (kernel.py)
'interpret' — Pallas kernel in interpret mode (CPU validation)
"""
from __future__ import annotations

from functools import partial

import jax

from . import ref

_BACKEND = "xla"


def set_backend(name: str) -> None:
    global _BACKEND
    assert name in ("xla", "pallas", "interpret")
    _BACKEND = name


@partial(jax.jit, static_argnames=("chunk", "backend"))
def ssd(x, dt, A, B, C, *, chunk: int = 64, backend: str | None = None):
    be = backend or _BACKEND
    if be == "xla":
        return ref.ssd_chunked(x, dt, A, B, C, chunk=chunk)
    from .kernel import ssd_pallas
    return ssd_pallas(x, dt, A, B, C, chunk=chunk,
                      interpret=(be == "interpret"))


def ssd_decode_step(state, x_t, dt_t, A, B_t, C_t):
    return ref.ssd_decode_step(state, x_t, dt_t, A, B_t, C_t)
