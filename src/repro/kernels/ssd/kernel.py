"""Pallas TPU kernel for the Mamba-2 SSD chunk scan.

Grid = (B, H, S/Q); the chunk dimension is sequential ('arbitrary'), and the
inter-chunk recurrent state (N, P) lives in fp32 VMEM scratch across chunks
— HBM traffic is exactly one read of (x, dt, dA, B, C) and one write of y
per token; the state never leaves VMEM until the final chunk emits it.

Per-chunk compute (all in VMEM, fp32 accumulation on the MXU):
  scores (Q,Q) = C·Bᵀ  → masked decay weighting → y_intra = M·x
  y_inter (Q,P) = (C ⊙ e^{cum})·state
  state   (N,P) = e^{cum_last}·state + (B ⊙ dt·e^{cum_last-cum})ᵀ·x

Q=128, N=128, P=64..128 keep every matmul MXU-aligned; worst-case VMEM
(Q·N inputs ×3 + Q·Q + state) ≈ 0.4 MB at Q=N=128, P=128.

Caller layout: (B, H, S, ·) — heads-major so one (b, h) grid cell streams a
contiguous sequence.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, da_ref, b_ref, c_ref, y_ref, state_ref,
                state_scr, *, nc: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, 0]                                   # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)             # (Q,)
    da = da_ref[0, 0].astype(jnp.float32)             # (Q,)  = dt * A(h)
    bm = b_ref[0, 0]                                   # (Q, N)
    cm = c_ref[0, 0]                                   # (Q, N)

    cum = jnp.cumsum(da)                               # (Q,)
    # intra-chunk
    scores = jax.lax.dot_general(cm.astype(jnp.float32),
                                 bm.astype(jnp.float32),
                                 (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (Q,Q)
    q = cum.shape[0]
    li = cum[:, None] - cum[None, :]
    tri = (jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (q, q), 1))
    decay = jnp.where(tri, jnp.exp(li), 0.0)
    m = scores * decay * dt[None, :]
    y_intra = jax.lax.dot_general(m.astype(x.dtype), x,
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    # inter-chunk from carried state
    cin = cm.astype(jnp.float32) * jnp.exp(cum)[:, None]          # (Q,N)
    y_inter = jax.lax.dot_general(cin, state_scr[...],
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y_ref[0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update
    dte = dt * jnp.exp(cum[-1] - cum)                              # (Q,)
    binj = bm.astype(jnp.float32) * dte[:, None]                   # (Q,N)
    bx = jax.lax.dot_general(binj, x.astype(jnp.float32),
                             (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (N,P)
    state_scr[...] = state_scr[...] * jnp.exp(cum[-1]) + bx

    @pl.when(ci == nc - 1)
    def _emit_state():
        state_ref[0, 0] = state_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_pallas(x, dt, A, B, C, *, chunk: int = 128, interpret: bool = False):
    """Same contract as ref.ssd_chunked: x (B,S,H,P), dt (B,S,H), A (H,),
    B/C (B,S,H,N) → (y (B,S,H,P), state (B,H,N,P))."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    if s % chunk:
        pad = chunk - s % chunk
        padder = lambda t: jnp.pad(t, [(0, 0), (0, pad)] +
                                   [(0, 0)] * (t.ndim - 2))
        y, state = ssd_pallas(padder(x), padder(dt), A, padder(B), padder(C),
                              chunk=chunk, interpret=interpret)
        return y[:, :s], state
    nc = s // chunk
    # heads-major layout so each (b,h) streams its sequence contiguously
    xh = jnp.moveaxis(x, 2, 1)                        # (B,H,S,P)
    dth = jnp.moveaxis(dt, 2, 1)                      # (B,H,S)
    dah = dth.astype(jnp.float32) * A.astype(jnp.float32)[None, :, None]
    bh = jnp.moveaxis(B, 2, 1)                        # (B,H,S,N)
    ch = jnp.moveaxis(C, 2, 1)

    y, state = pl.pallas_call(
        functools.partial(_ssd_kernel, nc=nc),
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk), lambda bi, hi, ci: (bi, hi, ci)),
            pl.BlockSpec((1, 1, chunk), lambda bi, hi, ci: (bi, hi, ci)),
            pl.BlockSpec((1, 1, chunk, n), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk, n), lambda bi, hi, ci: (bi, hi, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, n, p), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xh, dth, dah, bh, ch)
    return jnp.moveaxis(y, 1, 2), state
