"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attention + mamba heads per layer,
128 meta tokens (learnable KV prefix), sliding-window attention everywhere
except layers {0,15,31}. [arXiv:2411.13676; hf]
25/5 heads don't divide TP=16 → sequence sharding. subquadratic (SWA+SSM)."""
from repro.models import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="hymba-1.5b", family="hybrid", num_layers=32, d_model=1600,
        n_heads=25, n_kv_heads=5, d_head=64, d_ff=5504, vocab_size=32256,  # 32001 padded to /16 vocab shards
        ffn="swiglu", attn_shard="sequence", sliding_window=2048,
        full_attn_layers=(0, 15, 31), meta_tokens=128,
        ssm_state=16, ssm_headdim=64, ssm_expand=2, ssm_ngroups=1,
        ssm_chunk=256, ssm_conv=4, subquadratic=True)


def reduced() -> ArchConfig:
    return ArchConfig(
        name="hymba-1.5b-reduced", family="hybrid", num_layers=3, d_model=64,
        n_heads=4, n_kv_heads=2, d_head=16, d_ff=128, vocab_size=512,
        ffn="swiglu", attn_shard="sequence", sliding_window=8,
        full_attn_layers=(0, 2), meta_tokens=4,
        ssm_state=8, ssm_headdim=16, ssm_expand=2, ssm_ngroups=1,
        ssm_chunk=8, ssm_conv=4, subquadratic=True)
