"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H d_ff=1408 (per expert)
vocab=102400, MoE 64 routed experts top-6 + 2 shared — MLA kv_lora=512,
qk_nope=128 qk_rope=64 v_head=128. [arXiv:2405.04434; hf]
NOTE: assignment note says '2 shared+160 routed' (that is V2-236B); the
header says 64e — we follow the header (V2-Lite geometry): 64 routed + 2
shared, top-6. Flagged in DESIGN.md §Arch-applicability."""
from repro.models import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-v2-lite-16b", family="moe", num_layers=27,
        d_model=2048, n_heads=16, n_kv_heads=16, d_head=128, d_ff=1408,
        vocab_size=102400, ffn="swiglu", attn_shard="heads",
        n_experts=64, top_k=6, n_shared_experts=2, capacity_factor=1.25,
        kv_lora=512, qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128)


def reduced() -> ArchConfig:
    return ArchConfig(
        name="deepseek-v2-lite-16b-reduced", family="moe", num_layers=2,
        d_model=64, n_heads=4, n_kv_heads=4, d_head=16, d_ff=32,
        vocab_size=512, ffn="swiglu", attn_shard="heads", n_experts=8,
        top_k=2, n_shared_experts=1, capacity_factor=8.0,  # drop-free at smoke scale
        kv_lora=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16)
