"""mamba2-370m [ssm]: 48L d_model=1024 (attention-free) vocab=50280,
ssm_state=128 — SSD (state-space duality). [arXiv:2405.21060]
d_inner=2048 (expand 2), headdim 64 → 32 SSD heads, 1 group, conv k=4."""
from repro.models import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="mamba2-370m", family="ssm", num_layers=48, d_model=1024,
        n_heads=0, n_kv_heads=0, d_head=0, d_ff=0, vocab_size=50432,  # 50280 padded to /16 vocab shards
        ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_ngroups=1,
        ssm_chunk=256, ssm_conv=4, subquadratic=True)


def reduced() -> ArchConfig:
    return ArchConfig(
        name="mamba2-370m-reduced", family="ssm", num_layers=2, d_model=64,
        n_heads=0, n_kv_heads=0, d_head=0, d_ff=0, vocab_size=512,
        ssm_state=16, ssm_headdim=16, ssm_expand=2, ssm_ngroups=1,
        ssm_chunk=16, ssm_conv=4, subquadratic=True)
