"""Architecture registry: ``get(name)`` -> full ArchConfig,
``get_reduced(name)`` -> CPU-smoke-scale config of the same family.
"""
from __future__ import annotations

from importlib import import_module

ARCHS = (
    "llava-next-34b", "tinyllama-1.1b", "stablelm-12b", "nemotron-4-15b",
    "qwen3-8b", "mamba2-370m", "whisper-large-v3", "hymba-1.5b",
    "olmoe-1b-7b", "deepseek-v2-lite-16b",
)


def _module(name: str):
    return import_module(f".{name.replace('-', '_').replace('.', '_')}",
                         __package__)


def get(name: str):
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; options: {ARCHS}")
    return _module(name).config()


def get_reduced(name: str):
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; options: {ARCHS}")
    return _module(name).reduced()


def all_configs():
    return {n: get(n) for n in ARCHS}
