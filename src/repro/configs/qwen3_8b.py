"""qwen3-8b [dense]: 36L d_model=4096 32H (GQA kv=8) d_ff=12288
vocab=151936 — per-head qk-RMSNorm, GQA. [hf:Qwen/Qwen3-8B; hf]"""
from repro.models import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen3-8b", family="dense", num_layers=36, d_model=4096,
        n_heads=32, n_kv_heads=8, d_head=128, d_ff=12288, vocab_size=151936,
        ffn="swiglu", qk_norm=True, attn_shard="heads",
        rope_theta=1_000_000.0)


def reduced() -> ArchConfig:
    return ArchConfig(
        name="qwen3-8b-reduced", family="dense", num_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_head=16, d_ff=128, vocab_size=512,
        ffn="swiglu", qk_norm=True, attn_shard="heads")
