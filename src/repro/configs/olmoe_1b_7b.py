"""olmoe-1b-7b [moe]: 16L d_model=2048 16H (GQA kv=16) d_ff=1024 (per
expert) vocab=50304, 64 experts top-8. [arXiv:2409.02060; hf]"""
from repro.models import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="olmoe-1b-7b", family="moe", num_layers=16, d_model=2048,
        n_heads=16, n_kv_heads=16, d_head=128, d_ff=1024, vocab_size=50304,
        ffn="swiglu", attn_shard="heads", n_experts=64, top_k=8,
        capacity_factor=1.25)


def reduced() -> ArchConfig:
    return ArchConfig(
        name="olmoe-1b-7b-reduced", family="moe", num_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_head=16, d_ff=32, vocab_size=512,
        ffn="swiglu", attn_shard="heads", n_experts=8, top_k=2,
        capacity_factor=8.0)   # drop-free at smoke scale
