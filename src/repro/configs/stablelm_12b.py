"""stablelm-12b [dense]: 40L d_model=5120 32H (GQA kv=8) d_ff=13824
vocab=100352. [hf:stabilityai/stablelm-2-1_6b; hf]"""
from repro.models import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="stablelm-12b", family="dense", num_layers=40, d_model=5120,
        n_heads=32, n_kv_heads=8, d_head=160, d_ff=13824, vocab_size=100352,
        ffn="swiglu", attn_shard="heads")


def reduced() -> ArchConfig:
    return ArchConfig(
        name="stablelm-12b-reduced", family="dense", num_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_head=16, d_ff=128, vocab_size=512,
        ffn="swiglu", attn_shard="heads")
