"""llava-next-34b [vlm]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000 — anyres tiling (stub frontend supplies pre-tiled patch
embeddings). [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
Backbone geometry matches the assignment (Yi-34B-class decoder).
56 q-heads / 8 kv-heads don't divide TP=16 → sequence (context) sharding.
"""
from repro.models import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="llava-next-34b", family="vlm", num_layers=60, d_model=7168,
        n_heads=56, n_kv_heads=8, d_head=128, d_ff=20480, vocab_size=64000,
        ffn="swiglu", attn_shard="sequence",
        img_tokens=576, img_embed_dim=1024)


def reduced() -> ArchConfig:
    return ArchConfig(
        name="llava-next-34b-reduced", family="vlm", num_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_head=16, d_ff=128, vocab_size=512,
        ffn="swiglu", attn_shard="sequence", img_tokens=8, img_embed_dim=32)
