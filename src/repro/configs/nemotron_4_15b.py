"""nemotron-4-15b [dense]: 32L d_model=6144 48H (GQA kv=8) d_ff=24576
vocab=256000 — GQA, squared-ReLU FFN (no GLU gate). [arXiv:2402.16819]"""
from repro.models import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="nemotron-4-15b", family="dense", num_layers=32, d_model=6144,
        n_heads=48, n_kv_heads=8, d_head=128, d_ff=24576, vocab_size=256000,
        ffn="sq_relu", attn_shard="heads")


def reduced() -> ArchConfig:
    return ArchConfig(
        name="nemotron-4-15b-reduced", family="dense", num_layers=2,
        d_model=64, n_heads=4, n_kv_heads=2, d_head=16, d_ff=256,
        vocab_size=512, ffn="sq_relu", attn_shard="heads")
