"""The four assigned input-shape suites and ``input_specs`` builders.

  train_4k     seq=4,096   global_batch=256   (training)        → train_step
  prefill_32k  seq=32,768  global_batch=32    (inference)       → prefill_step
  decode_32k   seq=32,768  global_batch=128   (one new token)   → decode_step
  long_500k    seq=524,288 global_batch=1     (one new token)   → decode_step
               SSM/hybrid archs only (sub-quadratic requirement)

``input_specs`` returns ShapeDtypeStructs (weak-type-correct, shardable, no
allocation) for everything a step function consumes except params/cache,
which come from Model.abstract_params()/abstract_cache().
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.common import ArchConfig, ShapeConfig, dp_axes

SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def applicable(cfg: ArchConfig, shape_name: str) -> tuple[bool, str]:
    """Is this (arch, shape) cell runnable? Returns (ok, reason_if_not)."""
    if shape_name == "long_500k" and not cfg.subquadratic:
        return False, ("pure full-attention arch: long_500k requires "
                       "sub-quadratic sequence handling (per assignment)")
    return True, ""


def _sds(shape, dtype, mesh: Mesh | None, spec: P):
    if mesh is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def input_specs(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh | None = None,
                parallelism: str = "tp") -> dict:
    """Model inputs for one step of the given kind."""
    dp = dp_axes(mesh)
    if parallelism == "fsdp" and mesh is not None:
        dp = dp + ("model",)
    dp = dp or None
    gb, s = shape.global_batch, shape.seq_len
    dp_total = 1
    if mesh is not None and dp:
        for a in dp:
            dp_total *= mesh.shape[a]
    bspec = dp if (mesh is not None and gb % dp_total == 0 and gb >= dp_total) else None

    out: dict = {}
    if shape.kind == "train":
        out["tokens"] = _sds((gb, s + 1), jnp.int32, mesh, P(bspec, None))
    elif shape.kind == "prefill":
        out["tokens"] = _sds((gb, s), jnp.int32, mesh, P(bspec, None))
    else:  # decode: one new token; the cache of seq_len comes separately
        out["tokens"] = _sds((gb, 1), jnp.int32, mesh, P(bspec, None))

    if cfg.family == "vlm" and shape.kind != "decode":
        out["image_embeds"] = _sds((gb, cfg.img_tokens, cfg.img_embed_dim),
                                   jnp.bfloat16, mesh, P(bspec, None, None))
    if cfg.family == "encdec" and shape.kind != "decode":
        enc_seq_spec = "model" if parallelism == "tp" else None
        out["enc_frames"] = _sds((gb, cfg.enc_seq, cfg.d_model),
                                 jnp.bfloat16, mesh,
                                 P(bspec, enc_seq_spec, None))
    return out


def concrete_inputs(cfg: ArchConfig, kind: str, batch: int, seq: int, rng):
    """Small concrete batch for smoke tests (single device)."""
    ks = jax.random.split(rng, 3)
    ntok = seq + 1 if kind == "train" else seq
    out = {"tokens": jax.random.randint(ks[0], (batch, ntok), 0,
                                        cfg.vocab_size, jnp.int32)}
    if cfg.family == "vlm":
        out["image_embeds"] = jax.random.normal(
            ks[1], (batch, cfg.img_tokens, cfg.img_embed_dim), jnp.bfloat16)
    if cfg.family == "encdec":
        out["enc_frames"] = jax.random.normal(
            ks[2], (batch, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    return out
