"""whisper-large-v3 [audio]: 32L d_model=1280 20H d_ff=5120 vocab=51866 —
encoder-decoder; conv frontend is a STUB (input_specs supplies precomputed
frame embeddings, 1500 frames padded to 1536 for 16-way sequence sharding).
[arXiv:2212.04356] Adaptations: rope replaces learned positions; no biases;
RMSNorm replaces LayerNorm (see DESIGN.md)."""
from repro.models import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="whisper-large-v3", family="encdec", num_layers=32, d_model=1280,
        n_heads=20, n_kv_heads=20, d_head=64, d_ff=5120, vocab_size=51968,  # 51866 padded to /16 vocab shards
        ffn="gelu", attn_shard="sequence", enc_layers=32, enc_seq=1536)


def reduced() -> ArchConfig:
    return ArchConfig(
        name="whisper-large-v3-reduced", family="encdec", num_layers=2,
        d_model=64, n_heads=4, n_kv_heads=4, d_head=16, d_ff=128,
        vocab_size=512, ffn="gelu", attn_shard="sequence", enc_layers=2,
        enc_seq=16)
