"""Tests for the invariant analysis pass (``repro.analysis``): every rule
must fire on a violating fixture and stay quiet on a clean one; the pragma /
baseline machinery must catch drift in both directions; and the dynamic
lock-order detector must flag a seeded inversion while the instrumented
tier-1 subset (``-m lockorder`` under ``REPRO_LOCK_ORDER=1``) runs clean.

The fixtures are tiny synthetic modules written into ``tmp_path`` — the
rules are syntactic, so a handful of lines per bug class is enough to pin
the exact idiom each rule keys on.
"""
import json
import textwrap
import threading

import pytest

from repro.analysis.engine import (AnalysisConfig, Engine, Finding,
                                   load_config)
from repro.analysis.rules import default_rules
from repro.analysis.lockorder import (ENV_VAR, LockOrderMonitor,
                                      LockOrderViolation,
                                      monitor_enabled_by_env)


# ---------------------------------------------------------------------------
# harness: run the engine over one synthetic module
# ---------------------------------------------------------------------------
_FAKE_REGISTRY = '''
SITES: dict[str, str] = {
    "proc.*": "per processor trigger",
    "log.append": "per chunk write",
}
'''

_FAKE_STATS = '''
from dataclasses import dataclass

@dataclass
class ComponentStats:
    name: str
    in_records: int = 0
    out_records: int = 0
'''


def _scan(tmp_path, source, filename="mod.py"):
    """Write one module plus the fake registry/stats modules; return the
    rule ids of the (unsuppressed) findings and the full ScanResult."""
    (tmp_path / "faults.py").write_text(_FAKE_REGISTRY)
    (tmp_path / "metrics.py").write_text(_FAKE_STATS)
    target = tmp_path / filename
    target.parent.mkdir(parents=True, exist_ok=True)
    target.write_text(textwrap.dedent(source))
    config = AnalysisConfig(root=tmp_path, paths=[filename],
                            fault_registry="faults.py",
                            stats_module="metrics.py")
    result = Engine(config).scan()
    return [f.rule for f in result.findings], result


# ---------------------------------------------------------------------------
# lock-blocking-call
# ---------------------------------------------------------------------------
def test_lock_blocking_flags_sleep_and_recv(tmp_path):
    rules, result = _scan(tmp_path, """
        import time

        class C:
            def bad(self):
                with self._lock:
                    time.sleep(0.1)
                    self._sock.recv(4096)
                    self._sock.sendall(b"x")
                    self.out.offer_batch(batch)
    """)
    assert rules == ["lock-blocking-call"] * 4
    assert "while holding self._lock" in result.findings[0].message


def test_lock_blocking_flags_untimed_wait_join_and_fsync(tmp_path):
    rules, _ = _scan(tmp_path, """
        import os

        class C:
            def bad(self):
                with self._cv:
                    self._cv.wait()
                with self._wal_lock:
                    os.fsync(fd)
                with node.pool_lock:
                    helper.join()
    """)
    assert rules == ["lock-blocking-call"] * 3


def test_lock_blocking_clean_idioms_pass(tmp_path):
    rules, _ = _scan(tmp_path, """
        import os, time

        class C:
            def good(self):
                with self._lock:
                    x = self._count          # bookkeeping only
                time.sleep(0.1)              # blocking OUTSIDE the lock
                with self._cv:
                    self._cv.wait(0.05)      # bounded wait is a choice
                with self._lock:
                    parts = ", ".join(xs)    # str.join takes args: not Thread.join
                with self._lock:
                    def cb():                # defining is not calling
                        time.sleep(1)
                with self.buffer:            # not a lock-ish name
                    time.sleep(0.01)
    """)
    assert rules == []


# ---------------------------------------------------------------------------
# durability-rename
# ---------------------------------------------------------------------------
def test_durability_rename_flags_bare_replace(tmp_path):
    rules, _ = _scan(tmp_path, """
        import os
        def persist(tmp, final):
            os.replace(tmp, final)
        def persist2(tmp, final):
            os.rename(tmp, final)
        def persist3(tmp, final):
            tmp.rename(final)
    """)
    assert rules == ["durability-rename"] * 3


def test_durability_rename_allows_atomic_write_bytes(tmp_path):
    rules, _ = _scan(tmp_path, """
        import os
        def atomic_write_bytes(path, data):
            os.replace(str(path) + ".tmp", path)
    """, filename="logstore.py")
    assert rules == []


# ---------------------------------------------------------------------------
# fault-site-registry
# ---------------------------------------------------------------------------
def test_fault_site_registry_flags_undeclared(tmp_path):
    rules, result = _scan(tmp_path, """
        from faults import fire
        def f(injector):
            fire("log.apend")               # typo'd: silently never fires
            injector.arm("nope.site")
    """)
    assert rules == ["fault-site-registry"] * 2
    assert "log.apend" in result.findings[0].message


def test_fault_site_registry_accepts_declared_and_wildcards(tmp_path):
    rules, _ = _scan(tmp_path, """
        from faults import fire
        def f(injector, name):
            fire("log.append")
            fire("proc.enrich")             # matches the proc.* family
            injector.arm(site="log.append")
            fire("proc." + name)            # dynamic: runtime check's job
    """)
    assert rules == []


# ---------------------------------------------------------------------------
# naked-clock
# ---------------------------------------------------------------------------
def test_naked_clock_flags_direct_reads_in_injectable_class(tmp_path):
    rules, result = _scan(tmp_path, """
        import time

        class Injectable:
            def __init__(self, clock=None):
                self._clock = clock or time.monotonic
            def deadline(self, timeout):
                return time.monotonic() + timeout     # resurrects real time
            def stamp(self):
                return time.time()
    """)
    assert rules == ["naked-clock"] * 2
    assert "Injectable" in result.findings[0].message


def test_naked_clock_ignores_uninjectable_class_and_now_helper(tmp_path):
    rules, _ = _scan(tmp_path, """
        import time

        class NoClockParam:
            def __init__(self, name):
                self.name = name
            def deadline(self, timeout):
                return time.monotonic() + timeout     # class opted out

        class Injectable:
            def __init__(self, clock=None):
                self._clock = clock
            def _now(self):
                return self._clock() if self._clock else time.monotonic()
            def deadline(self, timeout):
                return self._now() + timeout
    """)
    assert rules == []


# ---------------------------------------------------------------------------
# stats-direct-mutation
# ---------------------------------------------------------------------------
def test_stats_direct_mutation_flags_bare_writes(tmp_path):
    rules, _ = _scan(tmp_path, """
        def bump(proc, stats):
            proc.stats.in_records += 1      # three bytecodes, loses updates
            stats.out_records = 5
    """)
    assert rules == ["stats-direct-mutation"] * 2


def test_stats_direct_mutation_allows_locked_helpers(tmp_path):
    rules, _ = _scan(tmp_path, """
        def bump(proc, other):
            proc.stats.add(in_records=1)
            proc.stats.set(out_records=5)
            other.in_records += 1           # not a .stats. chain
    """)
    assert rules == []


# ---------------------------------------------------------------------------
# pragmas
# ---------------------------------------------------------------------------
def test_pragma_suppresses_with_reason_same_line_and_above(tmp_path):
    rules, result = _scan(tmp_path, """
        import time

        class C:
            def f(self):
                with self._lock:
                    time.sleep(0.01)  # lint: ok(lock-blocking-call) — bounded pause, lock is private
                    # lint: ok(lock-blocking-call) — drain is non-blocking here
                    self.out.offer_batch(batch)
    """)
    assert rules == []
    assert len(result.suppressed) == 2
    assert result.unused_pragmas == []


def test_pragma_without_reason_does_not_suppress(tmp_path):
    rules, _ = _scan(tmp_path, """
        import time

        class C:
            def f(self):
                with self._lock:
                    time.sleep(0.01)  # lint: ok(lock-blocking-call)
    """)
    assert rules == ["lock-blocking-call"]


def test_unused_pragma_is_reported(tmp_path):
    _, result = _scan(tmp_path, """
        x = 1  # lint: ok(lock-blocking-call) — stale suppression
    """)
    assert len(result.unused_pragmas) == 1


# ---------------------------------------------------------------------------
# baseline drift (both directions)
# ---------------------------------------------------------------------------
def test_baseline_partition_new_and_stale(tmp_path):
    _, result = _scan(tmp_path, """
        import os
        def persist(tmp, final):
            os.replace(tmp, final)
    """)
    assert len(result.findings) == 1
    # exact match: nothing new, nothing stale
    new, stale = result.partition_against(list(result.findings))
    assert new == [] and stale == []
    # unknown finding in the scan output -> new
    new, stale = result.partition_against([])
    assert len(new) == 1 and stale == []
    # baseline entry whose finding was fixed -> stale
    ghost = Finding(rule="durability-rename", path=result.findings[0].path,
                    line=99, message="gone")
    new, stale = result.partition_against(list(result.findings) + [ghost])
    assert new == [] and stale == [ghost]


def test_baseline_outside_scanned_paths_is_not_stale(tmp_path):
    _, result = _scan(tmp_path, "x = 1\n")
    ghost = Finding(rule="durability-rename", path="elsewhere/other.py",
                    line=1, message="not rescanned")
    new, stale = result.partition_against([ghost])
    assert new == [] and stale == []


# ---------------------------------------------------------------------------
# baseline freshness: the real repo against its committed baseline
# ---------------------------------------------------------------------------
def test_repo_scan_matches_committed_baseline_exactly():
    """The meta-test the CI gate rests on: scanning the configured paths of
    THIS checkout must reproduce the committed baseline exactly — zero new
    findings, zero stale entries, zero unused pragmas. Any drift (a new
    violation, or a fix that should shrink the baseline) fails here before
    it fails in scripts/ci.sh."""
    config = load_config()
    engine = Engine(config)
    result = engine.scan()
    baseline = engine.load_baseline()
    new, stale = result.partition_against(baseline)
    assert new == [], "unbaselined findings:\n" + "\n".join(
        f.render() for f in new)
    assert stale == [], "stale baseline entries (fixed? regenerate):\n" + \
        "\n".join(f.render() for f in stale)
    assert result.unused_pragmas == []
    # and the committed JSON itself is the canonical serialization
    on_disk = json.loads(config.baseline_path().read_text())
    assert sorted(d["path"] + ":" + str(d["line"]) + ":" + d["rule"]
                  for d in on_disk["findings"]) == \
        sorted(f.path + ":" + str(f.line) + ":" + f.rule for f in baseline)


def test_default_rules_cover_the_documented_bug_classes():
    config = load_config()
    ids = {r.id for r in default_rules(config)}
    assert ids == {"lock-blocking-call", "durability-rename",
                   "fault-site-registry", "naked-clock",
                   "stats-direct-mutation"}
    for r in default_rules(config):
        assert r.doc, f"rule {r.id} has no one-line doc"


# ---------------------------------------------------------------------------
# dynamic lock-order detector
# ---------------------------------------------------------------------------
def _two_tracked_locks(mon):
    """Construct two locks at distinct sites inside this (tracked) file."""
    with mon:
        lock_a = threading.Lock()   # site A
        lock_b = threading.Lock()   # site B
    return lock_a, lock_b


def test_lockorder_detects_seeded_inversion():
    """A -> B then B -> A, recorded from the acquisition ORDER — no actual
    deadlock has to happen for the hazard to be caught."""
    mon = LockOrderMonitor(prefixes=("test_analysis",))
    a, b = _two_tracked_locks(mon)
    with a:
        with b:
            pass
    with b:
        with a:             # inversion: the cycle is now in the graph
            pass
    cycles = mon.cycles()
    assert len(cycles) == 1 and len(cycles[0]) == 2
    with pytest.raises(LockOrderViolation) as ei:
        mon.check()
    assert "CYCLE" in str(ei.value)
    # both edges (and their witness thread) appear in the report
    assert len([e for e in mon.edges() if e[0] != e[1]]) == 2


def test_lockorder_consistent_order_is_clean():
    mon = LockOrderMonitor(prefixes=("test_analysis",))
    a, b = _two_tracked_locks(mon)
    for _ in range(3):
        with a:
            with b:
                pass
    assert mon.cycles() == []
    mon.check()             # does not raise


def test_lockorder_cross_thread_inversion_detected():
    mon = LockOrderMonitor(prefixes=("test_analysis",))
    a, b = _two_tracked_locks(mon)
    with a:
        with b:
            pass
    done = threading.Event()

    def inverted():
        with b:
            with a:
                pass
        done.set()

    t = threading.Thread(target=inverted)
    t.start()
    t.join(5)
    assert done.is_set()
    assert len(mon.cycles()) == 1


def test_lockorder_rlock_reentrancy_is_not_a_self_edge():
    mon = LockOrderMonitor(prefixes=("test_analysis",))
    with mon:
        r = threading.RLock()
    with r:
        with r:             # recursion, not a second instance
            pass
    assert mon.cycles() == []


def test_lockorder_self_edge_between_instances_is_a_cycle():
    """Two instances from the SAME construction site held across each other
    (the A.merge(B) / B.merge(A) shape) — reported as a one-node cycle."""
    mon = LockOrderMonitor(prefixes=("test_analysis",))
    with mon:
        def make():
            return threading.Lock()
        first, second = make(), make()
    with first:
        with second:
            pass
    cycles = mon.cycles()
    assert len(cycles) == 1 and len(cycles[0]) == 1


def test_lockorder_condition_wait_releases_the_lock():
    """cond.wait() parks with the lock RELEASED — a lock taken inside the
    wait window must not record an edge from the condition's lock."""
    mon = LockOrderMonitor(prefixes=("test_analysis",))
    with mon:
        inner = threading.Lock()
        cond = threading.Condition(threading.Lock())
    started = threading.Event()
    release = threading.Event()

    def waiter():
        with cond:
            started.set()
            cond.wait(5)

    t = threading.Thread(target=waiter)
    t.start()
    started.wait(5)
    # while the waiter is parked, take the other lock then notify
    with inner:
        release.set()
    with cond:
        cond.notify_all()
    t.join(5)
    assert all(a != b for a, b in mon.edges()), mon.report()
    assert mon.cycles() == []


def test_lockorder_untracked_construction_returns_stock_locks():
    mon = LockOrderMonitor(prefixes=("no/such/path",))
    with mon:
        lock = threading.Lock()
    assert type(lock).__name__ == "lock"        # raw _thread.lock
    assert mon.tracked_sites == set()


def test_lockorder_env_gating(monkeypatch):
    monkeypatch.delenv(ENV_VAR, raising=False)
    assert monitor_enabled_by_env() is None
    monkeypatch.setenv(ENV_VAR, "0")
    assert monitor_enabled_by_env() is None
    monkeypatch.setenv(ENV_VAR, "1")
    assert isinstance(monitor_enabled_by_env(), LockOrderMonitor)


def test_lockorder_uninstall_restores_factories():
    orig_lock, orig_rlock = threading.Lock, threading.RLock
    mon = LockOrderMonitor()
    mon.install()
    mon.uninstall()
    assert threading.Lock is orig_lock and threading.RLock is orig_rlock


# ---------------------------------------------------------------------------
# fault-site registry: runtime half
# ---------------------------------------------------------------------------
def test_arm_rejects_undeclared_site():
    from repro.core.faults import FaultInjector, UndeclaredFaultSite
    inj = FaultInjector()
    with pytest.raises(UndeclaredFaultSite):
        inj.arm("transport.server.recieve")     # typo'd: would never fire
    inj.arm("transport.server.recv")            # declared: fine
    inj.arm("proc.anything-goes-here")          # declared family
    assert inj.armed() == ["proc.anything-goes-here", "transport.server.recv"]


def test_declared_registry_docs_are_nonempty():
    from repro.core.faults import SITES, declared
    for site, doc in SITES.items():
        assert doc.strip(), f"site {site} has no one-line doc"
    assert declared("proc.x") and not declared("procx")
