"""Tokenizer, packing, streaming loader: determinism + exactly-once."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st

from repro.core import ConsumerGroup, PartitionedLog, make_flowfile
from repro.data import (ByteTokenizer, SequencePacker, StreamingDataLoader,
                        attach_training_loader, build_news_pipeline)


def test_tokenizer_roundtrip():
    tok = ByteTokenizer()
    ids = tok.encode("hello stream")
    assert ids[0] == tok.BOS and ids[-1] == tok.EOS
    assert tok.decode(ids) == "hello stream"


@given(st.text(max_size=400))
@settings(deadline=None, max_examples=50)
def test_tokenizer_roundtrip_property(s):
    tok = ByteTokenizer()
    assert tok.decode(tok.encode(s)) == s


def test_packer_emits_full_rows_only():
    p = SequencePacker(seq_len=7, pad_id=256)
    rows = p.add_document(list(range(20)))
    assert len(rows) == 2 and all(len(r) == 8 for r in rows)
    assert rows[0].tolist() == list(range(8))
    tail = p.flush()
    assert tail is not None and tail[:4].tolist() == [16, 17, 18, 19]
    assert (tail[4:] == 256).all()


@given(st.lists(st.integers(1, 50), min_size=1, max_size=40),
       st.integers(4, 64))
@settings(deadline=None, max_examples=40)
def test_packer_conserves_tokens(doc_lens, seq_len):
    """No token lost, no token duplicated, order preserved."""
    p = SequencePacker(seq_len=seq_len, pad_id=0)
    stream, emitted = [], []
    tok = 1
    for n in doc_lens:
        doc = list(range(tok, tok + n)); tok += n
        stream.extend(doc)
        for row in p.add_document(doc):
            emitted.extend(row.tolist())
    emitted.extend(p.state()["carry"])
    assert emitted == stream


def _fill_log(tmp_path, n_docs=200, partitions=4):
    log = PartitionedLog(tmp_path / "log")
    log.create_topic("docs", partitions=partitions)
    for i in range(n_docs):
        ff = make_flowfile(f"document number {i} " + "tok " * (i % 37))
        k, v = ff.to_record()
        log.append("docs", k, v, partition=i % partitions)
    return log


def _make_loader(log, member="m0", group="g", batch_size=4, seq_len=64):
    grp = ConsumerGroup(log, "docs", group)
    c = grp.add_member(member)
    return StreamingDataLoader(c, batch_size=batch_size, seq_len=seq_len)


def test_loader_produces_batches(tmp_path):
    log = _fill_log(tmp_path)
    loader = _make_loader(log)
    b = loader.next_batch()
    assert b.shape == (4, 65) and b.dtype == np.int32
    assert loader.batches_emitted == 1
    log.close()


def test_loader_exactly_once_restore(tmp_path):
    """The core guarantee: after restoring loader state, the continuation of
    the batch stream is byte-identical to the uninterrupted run."""
    log = _fill_log(tmp_path)
    loader = _make_loader(log)
    for _ in range(3):
        loader.next_batch()
    ckpt = loader.state()
    expected = [loader.next_batch() for _ in range(4)]

    log2 = PartitionedLog(tmp_path / "log")       # fresh process
    loader2 = _make_loader(log2, group="g2")
    loader2.restore(ckpt)
    got = [loader2.next_batch() for _ in range(4)]
    for e, g in zip(expected, got):
        np.testing.assert_array_equal(e, g)
    log.close(); log2.close()


def test_loader_returns_none_when_exhausted(tmp_path):
    log = _fill_log(tmp_path, n_docs=2)
    loader = _make_loader(log, batch_size=512, seq_len=512)
    assert loader.next_batch(timeout_polls=3) is None
    log.close()


def test_loader_prefetch_thread(tmp_path):
    log = _fill_log(tmp_path)
    loader = _make_loader(log)
    loader.start()
    b = loader.get_prefetched(timeout=10)
    assert b is not None and b.shape == (4, 65)
    loader.stop()
    log.close()


def test_news_pipeline_end_to_end(tmp_path):
    flow, log = build_news_pipeline(tmp_path, n_rss=300, n_firehose=300,
                                    n_ws=50, partitions=4)
    flow.run_to_completion(timeout=120)
    assert sum(log.end_offsets("articles")) > 300   # most records survive
    assert sum(log.end_offsets("events")) == 50
    grp, loader = attach_training_loader(log, batch_size=2, seq_len=128)
    b = loader.next_batch()
    assert b.shape == (2, 129)
    # two consumers (training + eval) attach independently — the paper's
    # add-consumers-without-changing-the-pipeline property
    grp2, loader2 = attach_training_loader(log, group="eval", batch_size=2,
                                           seq_len=128)
    b2 = loader2.next_batch()
    np.testing.assert_array_equal(b, b2)            # same stream, same bytes
    log.close()
