"""Synthetic source generators at their rate-parameter edges — the default
rates are exercised everywhere; these pin the boundary behaviours
(``dup_rate=0``, ``junk_rate=1.0``, ``count=0``) the acquisition layer's
determinism story depends on."""
import json

from repro.core import (FirehoseSource, RssAggregatorSource, WebSocketSource)


def test_firehose_dup_rate_zero_yields_all_unique():
    ffs = list(FirehoseSource(300, dup_rate=0.0)())
    texts = [json.loads(ff.content)["text"] for ff in ffs]
    assert len(ffs) == 300
    assert len(set(texts)) == 300           # no retweets at all
    assert all(ff.attributes["kind"] == "tweet" for ff in ffs)


def test_firehose_dup_rate_one_repeats_after_first():
    ffs = list(FirehoseSource(100, dup_rate=1.0)())
    texts = {json.loads(ff.content)["text"] for ff in ffs}
    assert len(ffs) == 100
    assert len(texts) == 1                  # everything retweets record 0


def test_rss_junk_rate_one_yields_only_malformed():
    ffs = list(RssAggregatorSource(200, junk_rate=1.0)())
    assert len(ffs) == 200
    assert all(ff.attributes["kind"] == "junk" for ff in ffs)
    for ff in ffs:                          # malformed by construction
        try:
            json.loads(ff.content)
            raise AssertionError("junk record parsed as JSON")
        except (ValueError, UnicodeDecodeError):
            pass


def test_rss_dup_rate_zero_yields_unique_articles():
    ffs = list(RssAggregatorSource(300, dup_rate=0.0, junk_rate=0.0)())
    ids = [json.loads(ff.content)["id"] for ff in ffs]
    assert len(ids) == 300 and len(set(ids)) == 300
    assert all(ff.attributes["kind"] == "article" for ff in ffs)


def test_count_zero_sources_are_empty_and_replayable():
    for src in (RssAggregatorSource(0), FirehoseSource(0),
                WebSocketSource(0)):
        assert list(src()) == []
        assert list(src()) == []            # replay stays empty, no state


def test_websocket_source_deterministic_replay():
    a = [ff.content for ff in WebSocketSource(50)()]
    b = [ff.content for ff in WebSocketSource(50)()]
    assert a == b and len(a) == 50
