"""Telemetry layer (ISSUE 9): mergeable log-bucketed latency histograms,
the process-local metrics registry, trace sampling through provenance,
the flight recorder, and the HTTP scrape endpoint.

The merge tests are the load-bearing ones: fabric-wide aggregation is
only correct because merging per-worker histograms bucket-wise is *exact*
(fixed power-of-two boundaries), so percentiles over the merged state
equal percentiles over a single histogram fed every sample.
"""
import json
import random
import threading
import urllib.error
import urllib.request

import pytest

from repro.core.telemetry import (FlightRecorder, LatencyHistogram,
                                  MetricsRegistry, bucket_index,
                                  merge_histogram_states, metric_key,
                                  serve_scrape, split_metric_key,
                                  summarize_histogram_state)


# -- LatencyHistogram ---------------------------------------------------------

def test_bucket_index_boundaries():
    assert bucket_index(0.0) == 0
    assert bucket_index(-1.0) == 0
    assert bucket_index(1e-6) == 1          # 1µs -> bucket 1
    assert bucket_index(1.5e-6) == 1
    assert bucket_index(2e-6) == 2
    assert bucket_index(1.0) == 20          # 1s ≈ 2^20 µs
    assert bucket_index(1e9) < 64           # clamped: no IndexError ever


def test_percentile_midpoint_and_count():
    h = LatencyHistogram()
    h.record(0.001, n=5)                    # 1ms x5
    h.record(0.1)                           # 100ms x1
    assert h.count == 6
    assert h.sum_seconds == pytest.approx(0.105)
    # p50 lands in the 1ms bucket, p99 in the 100ms bucket; answers are
    # geometric bucket midpoints, so within the power-of-two width
    assert 0.0007 < h.percentile(0.5) < 0.0015
    assert 0.06 < h.percentile(0.99) < 0.13
    s = h.summary()
    assert s["count"] == 6
    assert s["p50_ms"] < s["p99_ms"]


def test_percentile_empty_and_bad_q():
    h = LatencyHistogram()
    assert h.percentile(0.5) == 0.0
    with pytest.raises(ValueError):
        h.percentile(1.5)


def test_merge_is_exact():
    """Percentiles over merged histograms == percentiles over one
    histogram fed all samples — the fabric-aggregation invariant."""
    rng = random.Random(7)
    samples = [rng.uniform(1e-6, 0.5) for _ in range(4_000)]
    whole = LatencyHistogram()
    parts = [LatencyHistogram() for _ in range(4)]
    for i, s in enumerate(samples):
        whole.record(s)
        parts[i % 4].record(s)
    merged = LatencyHistogram()
    for p in parts:
        merged.merge(p)
    assert merged.count == whole.count
    assert merged.sum_seconds == pytest.approx(whole.sum_seconds)
    for q in (0.1, 0.5, 0.9, 0.99):
        assert merged.percentile(q) == whole.percentile(q)


def test_serialization_round_trip_and_state_merge():
    h = LatencyHistogram()
    h.record(0.004, n=3)
    h.record(2.0)
    state = h.to_dict()
    assert json.loads(json.dumps(state)) == json.loads(json.dumps(state))
    back = LatencyHistogram.from_dict(json.loads(json.dumps(state)))
    assert back.count == h.count
    assert back.summary() == h.summary()
    # merge_histogram_states == instance merge, on the wire format
    into = {"k": h.to_dict()}
    merge_histogram_states(into, {"k": h.to_dict(), "k2": h.to_dict()})
    assert into["k"]["n"] == 2 * h.count
    assert into["k2"]["n"] == h.count
    summ = summarize_histogram_state(into)
    assert summ["k"]["count"] == 2 * h.count


def test_state_merge_does_not_alias_source():
    """First insert must deep-copy: merging more state into the target
    must never mutate the original report (the fabric merges the same
    per-worker dicts every ``status()`` call)."""
    src = {"k": {"b": {"3": 2}, "n": 2, "s": 1.0}}
    into: dict = {}
    merge_histogram_states(into, src)
    merge_histogram_states(into, src)
    assert src["k"]["n"] == 2                # untouched
    assert into["k"]["n"] == 4


def test_timer_uses_injected_clock():
    fake = [10.0]
    h = LatencyHistogram(clock=lambda: fake[0])
    with h.timer(n=4):
        fake[0] += 0.25
    assert h.count == 4
    assert h.sum_seconds == pytest.approx(1.0)      # 0.25s x4


def test_record_many_matches_individual_records():
    a, b = LatencyHistogram(), LatencyHistogram()
    durations = [0.001, 0.002, 0.5, 0.0001]
    a.record_many(durations)
    for d in durations:
        b.record(d)
    assert a.to_dict() == b.to_dict()


def test_concurrent_record_and_collect():
    """Writer threads hammer record() while a reader collects summaries:
    no tearing, and the final count is exact (no lost increments)."""
    h = LatencyHistogram()
    n_threads, per_thread = 8, 2_000
    stop = threading.Event()

    def reader():
        while not stop.is_set():
            s = h.summary()
            assert s["count"] >= 0

    r = threading.Thread(target=reader)
    ws = [threading.Thread(
        target=lambda: [h.record(0.001) for _ in range(per_thread)])
        for _ in range(n_threads)]
    r.start()
    for w in ws:
        w.start()
    for w in ws:
        w.join()
    stop.set()
    r.join()
    assert h.count == n_threads * per_thread


# -- metric keys --------------------------------------------------------------

def test_metric_key_round_trip_and_sorting():
    k = metric_key("rpc_seconds", {"op": "read", "addr": "x"})
    assert k == 'rpc_seconds{addr="x",op="read"}'      # labels sorted
    name, labels = split_metric_key(k)
    assert name == "rpc_seconds"
    assert labels == 'addr="x",op="read"'
    assert split_metric_key("plain") == ("plain", "")


# -- MetricsRegistry ----------------------------------------------------------

def test_registry_get_or_create_and_merged():
    reg = MetricsRegistry()
    h1 = reg.histogram("process_seconds", processor="parse")
    h2 = reg.histogram("process_seconds", processor="parse")
    assert h1 is h2
    reg.histogram("process_seconds", processor="route").record(0.1, n=2)
    h1.record(0.001, n=3)
    assert reg.merged("process_seconds").count == 5
    summ = reg.summaries()
    assert summ['process_seconds{processor="parse"}']["count"] == 3


def test_registry_sources_collect_and_render():
    reg = MetricsRegistry()
    reg.register_source(
        "connector", lambda: {"rss": {"records": 7, "state": "RUNNING",
                                      "lag": None}})
    reg.histogram("poll_seconds", connector="rss").record(0.002)
    out = reg.collect()
    assert out["gauges"]["connector"]["rss"]["records"] == 7
    text = reg.render_text()
    # numeric gauges render; strings/None are skipped; histograms render
    # as summary-style quantile/count/sum lines
    assert 'repro_connector_records{connector="rss"} 7' in text
    assert "state" not in text
    assert 'repro_poll_seconds{connector="rss",quantile="0.5"}' in text
    assert 'repro_poll_seconds_count{connector="rss"} 1' in text
    json.loads(reg.to_json())               # valid JSON dump


def test_registry_source_errors_are_isolated():
    reg = MetricsRegistry()
    reg.register_source("bad", lambda: 1 / 0)
    reg.register_source("good", lambda: {"x": {"v": 1}})
    out = reg.collect()
    assert out["gauges"]["good"]["x"]["v"] == 1
    assert out["gauges"]["bad"] == {}       # isolated, not fatal


# -- FlightRecorder -----------------------------------------------------------

def test_flight_recorder_ring_and_dump(tmp_path):
    fake = [100.0]
    fr = FlightRecorder(capacity=4, clock=lambda: fake[0])
    for i in range(10):
        fake[0] += 1.0
        fr.record({"i": i})
    snaps = fr.snapshots()
    assert len(snaps) == 4                       # ring kept the last N
    assert [s["status"]["i"] for s in snaps] == [6, 7, 8, 9]
    assert snaps[0]["ts"] == pytest.approx(107.0)
    path = tmp_path / "flight.json"
    fr.dump(path)
    assert [e["status"]["i"] for e in json.loads(path.read_text())] \
        == [6, 7, 8, 9]


# -- ScrapeServer -------------------------------------------------------------

def test_scrape_server_serves_metrics_text():
    srv = serve_scrape(lambda: "repro_up 1\n")
    try:
        body = urllib.request.urlopen(srv.url, timeout=5).read().decode()
        assert body == "repro_up 1\n"
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(
                f"http://127.0.0.1:{srv.port}/nope", timeout=5)
    finally:
        srv.close()
        srv.close()                              # idempotent
