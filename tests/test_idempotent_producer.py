"""Idempotent producer ids ``(producer_id, seq)``: store-side dedup of
ambiguous retries, frozen-run resends in the batching Producer, and the
zombie-writer regression — a producer fenced mid-batch whose write landed
must not duplicate it under the new leader."""
from __future__ import annotations

import pytest

from repro.core import faults
from repro.core.delivery import Producer
from repro.core.log import PartitionedLog
from repro.core.logstore import ProducerDedupTable
from repro.core.replicated import ReplicatedLog


# -- dedup table (pure) ------------------------------------------------------

def test_dedup_table_classify_new_retry_and_overlap():
    t = ProducerDedupTable()
    assert t.classify("t", 0, "p", 0, 2)[0] == "new"
    t.record("t", 0, "p", 0, 2, first_offset=10)
    kind, entry = t.classify("t", 0, "p", 0, 2)
    assert kind == "retry" and entry.first_offset == 10
    assert t.classify("t", 0, "p", 2, 3)[0] == "new"       # next batch
    assert t.classify("t", 0, "p", 5, 1)[0] == "new"       # forward gap ok
    with pytest.raises(ValueError):
        t.classify("t", 0, "p", 1, 2)                      # overlap
    with pytest.raises(ValueError):
        t.classify("t", 0, "p", 0, 3)                      # count mismatch


# -- store-level retry dedup -------------------------------------------------

def test_partitioned_log_dedups_exact_retry(tmp_log):
    tmp_log.create_topic("t", partitions=2)
    recs = [(b"k1", b"v1"), (b"k2", b"v2")]
    off1 = tmp_log.append_batch("t", recs, partition=0,
                                producer_id="p1", base_seq=0)
    off2 = tmp_log.append_batch("t", recs, partition=0,
                                producer_id="p1", base_seq=0)   # retry
    assert off1 == off2
    assert tmp_log.end_offset("t", 0) == 2                      # no dupes
    off3 = tmp_log.append_batch("t", recs, partition=0,
                                producer_id="p1", base_seq=2)   # next batch
    assert off3[0][1] == 2
    with pytest.raises(ValueError):                             # rewind/overlap
        tmp_log.append_batch("t", [(b"x", b"y")], partition=0,
                             producer_id="p1", base_seq=3)


def test_pid_append_requires_explicit_partition_and_seq(tmp_log):
    tmp_log.create_topic("t", partitions=2)
    with pytest.raises(ValueError):
        tmp_log.append_batch("t", [(b"k", b"v")],
                             producer_id="p1", base_seq=0)
    with pytest.raises(ValueError):
        tmp_log.append_batch("t", [(b"k", b"v")], partition=0,
                             producer_id="p1")


# -- Producer: ambiguous failure + frozen-run resend -------------------------

class _Flaky:
    """Delegate store whose append applies server-side, then raises — the
    ambiguous failure (did it land?) that forces an idempotent retry."""

    def __init__(self, inner):
        self.inner = inner
        self.fail_next = False

    def __getattr__(self, name):
        return getattr(self.inner, name)

    def append_batch(self, *a, **kw):
        out = self.inner.append_batch(*a, **kw)
        if self.fail_next:
            self.fail_next = False
            raise ConnectionError("socket dropped after server applied")
        return out


def test_producer_resends_frozen_run_exactly_once(tmp_path):
    inner = PartitionedLog(tmp_path / "log")
    inner.create_topic("t", partitions=4)
    flaky = _Flaky(inner)
    prod = Producer(flaky, "t", max_batch_records=8, linger_sec=0.0,
                    producer_id="P")
    for i in range(8):
        prod.send(b"k%d" % i, b"v%d" % i)
    flaky.fail_next = True
    with pytest.raises(ConnectionError):
        prod.send(b"k8", b"v8")          # 9th send trips the batch drain
    # keep sending to the same partitions, then flush: the frozen run must
    # resend byte-identically (same seq range) and dedup server-side
    for i in range(9, 14):
        prod.send(b"k%d" % i, b"v%d" % i)
    prod.flush()
    assert sum(inner.end_offsets("t")) == 14        # exactly once
    assert prod.pending() == 0
    inner.close()


# -- regression: fence a zombie writer mid-batch -----------------------------

def test_fenced_zombie_mid_batch_lands_record_exactly_once(tmp_path):
    """The PR 3 duplicate window: a leader's store append lands, the leader
    is fenced before epoch re-validation, and the retry against the new
    leader re-appends the already-shipped batch. Producer ids close it."""
    rl = ReplicatedLog(tmp_path / "rl", replicas=2, acks="leader",
                      ship_batch_records=4)
    rl.create_topic("t", partitions=1)
    leader0 = rl.leader("t", 0)

    def zombie(ctx):
        # the instant after the leader-store write: a racing catch-up ships
        # the leader's log to the follower, then the failure detector
        # demotes the leader — its in-flight append is now a zombie write
        faults.INJECTOR.disarm("replica.fence")
        rset = rl._rset("t", 0)
        follower = next(r for r in rset.preference if r != ctx["replica"])
        with rset.ship_lock:
            rl._ship_range_locked("t", 0, ctx["replica"], follower)
        rl._demote(rset, ctx["replica"], ctx["epoch"])

    rl.append_batch("t", [(b"a", b"1")], partition=0,
                    producer_id="P", base_seq=0)
    faults.INJECTOR.arm("replica.fence", zombie)
    rl.append_batch("t", [(b"b", b"2")], partition=0,
                    producer_id="P", base_seq=1)
    assert rl.leader("t", 0) != leader0              # takeover happened
    assert rl.end_offset("t", 0) == 2                # NOT 3: no duplicate
    assert [r.value for r in rl.iter_records("t", 0)] == [b"1", b"2"]
    rl.close()


def test_fenced_zombie_without_pid_still_duplicates(tmp_path):
    """Control: the same fault without a producer id keeps the documented
    at-least-once behavior (a duplicate lands) — proving the test above
    exercises the dedup path, not an accidental absence of the window."""
    rl = ReplicatedLog(tmp_path / "rl", replicas=2, acks="leader",
                      ship_batch_records=4)
    rl.create_topic("t", partitions=1)

    def zombie(ctx):
        faults.INJECTOR.disarm("replica.fence")
        rset = rl._rset("t", 0)
        follower = next(r for r in rset.preference if r != ctx["replica"])
        with rset.ship_lock:
            rl._ship_range_locked("t", 0, ctx["replica"], follower)
        rl._demote(rset, ctx["replica"], ctx["epoch"])

    rl.append_batch("t", [(b"a", b"1")], partition=0)
    faults.INJECTOR.arm("replica.fence", zombie)
    rl.append_batch("t", [(b"b", b"2")], partition=0)
    assert rl.end_offset("t", 0) == 3                # the duplicate window
    rl.close()
