"""Watermark-driven event-time windows: WindowedAggregate closes tumbling
windows only when the LowWatermarkClock passes them, routes stragglers to
``late``, flushes the remainder at end of stream, and — via the flow
engine's idle triggers — fires closes while its own input is quiet."""
import time

import pytest

from repro.core import (CollectSink, FlowError, FlowGraph,
                        LowWatermarkClock, Processor, Source,
                        WindowedAggregate, make_flowfile)
from repro.core.windows import (ATTR_WINDOW_CLOSE_WM, ATTR_WINDOW_COUNT,
                                ATTR_WINDOW_END, ATTR_WINDOW_START)


def ff_at(ts: float, text: str = "x"):
    return make_flowfile(text, **{"event.ts": f"{ts:.6f}"})


def run_trigger(proc, batch):
    return list(proc.on_trigger(batch))


def test_windows_close_only_at_or_behind_watermark():
    clock = LowWatermarkClock()
    t = clock.register("src", lateness=0.0)
    w = WindowedAggregate("w", clock, 10.0)
    # records span three windows [0,10) [10,20) [20,30); watermark at 5
    t.observe(5.0)
    out = run_trigger(w, [ff_at(1.0, "a"), ff_at(12.0, "b"),
                          ff_at(22.0, "c")])
    assert out == []                     # wm=5: no window end <= 5
    # watermark passes the first two windows
    t.observe(21.0)
    out = run_trigger(w, [])
    assert [o[0] for o in out] == ["success", "success"]
    closes = [o[1] for o in out]
    assert [c.attributes[ATTR_WINDOW_START] for c in closes] \
        == ["0.000000", "10.000000"]
    for c in closes:
        # the invariant the acceptance scenario checks fleet-wide
        assert (float(c.attributes[ATTR_WINDOW_END])
                <= float(c.attributes[ATTR_WINDOW_CLOSE_WM]))
        assert c.attributes[ATTR_WINDOW_COUNT] == "1"
    assert w.snapshot_windows()["open_windows"] == 1


def test_window_contents_merge_in_event_time_order():
    clock = LowWatermarkClock()
    t = clock.register("src", lateness=0.0)
    w = WindowedAggregate("w", clock, 10.0)
    run_trigger(w, [ff_at(7.0, "late-in-window"), ff_at(2.0, "first"),
                    ff_at(5.0, "mid")])
    t.observe(12.0)
    # the close gate needs BOTH the clock and the stage's own frontier past
    # the window end — the stage seeing ts=11 supplies the second half
    ((rel, merged), *rest) = run_trigger(w, [ff_at(11.0, "next-window")])
    assert rel == "success" and not rest
    assert merged.content == b"first\nmid\nlate-in-window"
    assert merged.attributes[ATTR_WINDOW_COUNT] == "3"


def test_close_gated_on_stage_frontier_not_raw_clock():
    """The clock is read live and can outrun records still in flight to
    this stage; a window must NOT close before the stage itself has seen
    past its end, or the in-flight suffix would all land late."""
    clock = LowWatermarkClock()
    t = clock.register("src", lateness=0.0)
    w = WindowedAggregate("w", clock, 10.0)
    t.observe(50.0)                      # clock far ahead of the stage
    assert run_trigger(w, [ff_at(2.0, "in-flight")]) == []   # no close
    assert run_trigger(w, [ff_at(4.0, "also-on-time")]) == []  # not late!
    out = run_trigger(w, [ff_at(12.0, "past-the-window")])
    assert [(rel, ff.attributes[ATTR_WINDOW_COUNT]) for rel, ff in out] \
        == [("success", "2")]
    assert w.late_records == 0


def test_straggler_behind_closed_window_routes_late():
    clock = LowWatermarkClock()
    t = clock.register("src", lateness=0.0)
    w = WindowedAggregate("w", clock, 10.0)
    t.observe(15.0)
    run_trigger(w, [ff_at(12.0)])        # closes [0,10) (empty) at wm=15
    out = run_trigger(w, [ff_at(3.0, "straggler")])
    assert [(rel, ff.content) for rel, ff in out] \
        == [("late", b"straggler")]
    assert w.late_records == 1
    # the open [10,20) window is untouched by the straggler
    assert w.snapshot_windows()["buffered_records"] == 1


def test_declared_unseen_source_gates_closes():
    """Fail-open regression: a declared source that finished (the clock
    excludes it) before ANY of its records reached the stage must hold
    every close — otherwise its whole in-flight stream lands late. The
    gate releases once its tail drains through the stage."""
    clock = LowWatermarkClock()
    a = clock.register("a", lateness=0.0)
    b = clock.register("b", lateness=0.0)
    w = WindowedAggregate("w", clock, 10.0, sources=("a", "b"))
    a.observe(50.0)
    b.observe(5.0)
    clock.mark_finished("b")             # b's records are all still in flight
    # stage has seen plenty of "a" but nothing of "b": closes held
    out = run_trigger(w, [make_flowfile("a45", **{
        "event.ts": "45.0", "source": "a"})])
    assert out == []
    # b's tail drains through the stage: bucketed on time, gate released
    out = run_trigger(w, [make_flowfile("b5", **{
        "event.ts": "5.0", "source": "b"})])
    rels = [rel for rel, _ in out]
    assert "late" not in rels
    assert w.late_records == 0
    assert rels == ["success"]           # [0,10) closes, b5 inside it
    assert out[0][1].attributes[ATTR_WINDOW_COUNT] == "1"


def test_declared_unregistered_source_raises_instead_of_wedging():
    """A declared source name the clock has never registered (a typo, or
    a renamed connector) could never be released — instead of silently
    holding every close forever, the first close attempt raises."""
    clock = LowWatermarkClock()
    a = clock.register("a", lateness=0.0)
    w = WindowedAggregate("w", clock, 10.0, sources=("a", "typo"))
    a.observe(50.0)
    with pytest.raises(ValueError, match="typo"):
        run_trigger(w, [make_flowfile("x", **{"event.ts": "5.0",
                                              "source": "a"})])


def test_declared_source_finishing_empty_releases_gate():
    """A declared source that finishes having produced NOTHING (no
    watermark at all — e.g. an empty feed) has no in-flight tail to wait
    for: its gate must release, not hold every close at -inf forever."""
    clock = LowWatermarkClock()
    a = clock.register("a", lateness=0.0)
    clock.register("b", lateness=0.0)
    w = WindowedAggregate("w", clock, 10.0, sources=("a", "b"))
    clock.mark_finished("b")             # finished empty: never observed
    a.observe(50.0)
    out = run_trigger(w, [
        make_flowfile("old", **{"event.ts": "5.0", "source": "a"}),
        make_flowfile("new", **{"event.ts": "45.0", "source": "a"})])
    rels = [rel for rel, _ in out]
    assert rels == ["success"]           # [0,10) closes; [40,50) stays open
    assert w.snapshot_windows()["open_windows"] == 1


def test_final_flush_emits_remaining_windows_marked_final():
    clock = LowWatermarkClock()
    clock.register("src", lateness=0.0)
    w = WindowedAggregate("w", clock, 10.0)
    run_trigger(w, [ff_at(1.0, "a"), ff_at(11.0, "b")])
    out = list(w.final_flush())
    assert [ff.attributes[ATTR_WINDOW_CLOSE_WM] for _, ff in out] \
        == ["final", "final"]
    assert w.snapshot_windows()["open_windows"] == 0


def test_rejects_nonpositive_window():
    with pytest.raises(ValueError):
        WindowedAggregate("w", LowWatermarkClock(), 0.0)


def test_idle_trigger_failure_escalates_with_retry_armed():
    """Regression: with record retry armed (max_retries>0) a failing EMPTY
    trigger used to be reprocessed 'record-at-a-time' — zero iterations —
    silently swallowing the exception. It must escalate to the supervisor
    like any other processor failure."""
    class BoomOnIdle(Processor):
        idle_trigger_sec = 0.01

        def on_trigger(self, batch):
            if not batch:
                raise RuntimeError("boom on idle")
            return ()

    def gen():
        yield make_flowfile("x")
        time.sleep(0.5)                  # hold the stream open: idle fires

    g = FlowGraph("idle-fail")
    src = g.add(Source("src", gen))
    boom = g.add(BoomOnIdle("boom"))
    g.connect(src, "success", boom, max_retries=2)
    g.start()
    with pytest.raises(FlowError, match="boom"):
        g.join(timeout=10)


def test_idle_trigger_closes_windows_without_new_input():
    """The flow engine re-triggers an idle WindowedAggregate, so a window
    closes when ANOTHER stream's progress advances the clock — no new
    record through the window stage is needed (the upstream is held open
    to prove it's the idle trigger, not the final flush)."""
    import threading
    clock = LowWatermarkClock()
    t = clock.register("src", lateness=0.0)
    release = threading.Event()

    def gen():
        yield ff_at(1.0, "a")
        time.sleep(0.06)                 # > source linger: deliver each now
        yield ff_at(12.0, "next-window")
        release.wait(20)                 # hold the stream open

    g = FlowGraph("windows-idle")
    src = g.add(Source("src", gen))
    w = g.add(WindowedAggregate("w", clock, 10.0, idle_trigger_sec=0.01))
    sink = g.add(CollectSink("sink"))
    g.connect(src, "success", w)
    g.connect(w, "success", sink)
    t.observe(3.0)                       # wm=3: window [0,10) stays open
    g.start()
    deadline = time.monotonic() + 5
    while (w.snapshot_windows()["buffered_records"] < 2
           and time.monotonic() < deadline):
        time.sleep(0.005)
    assert w.snapshot_windows()["buffered_records"] == 2
    assert sink.items == []              # buffered, not closed (wm=3)
    t.observe(25.0)                      # clock jumps past the window...
    deadline = time.monotonic() + 5
    while not sink.items and time.monotonic() < deadline:
        time.sleep(0.005)                # ...and an IDLE trigger closes it
    assert len(sink.items) == 1
    closed = sink.items[0]
    assert closed.content == b"a"
    assert closed.attributes[ATTR_WINDOW_CLOSE_WM] != "final"
    release.set()
    g.join(timeout=10)
