"""End-to-end FlowGraph tests: the paper's case-study topology in miniature,
provenance lineage, backpressure propagation through the graph, failure
routing, and crash-replay recovery through the durable log."""
import json
import threading
import time

import pytest

from repro.core import (CollectSink, ConsumerGroup, ContentFilter,
                        DetectDuplicate, ExecuteScript, FileSink, FlowError,
                        FlowFile, FlowGraph, MergeContent, PartitionedLog,
                        PublishToLog, RouteOnAttribute, RssAggregatorSource,
                        Source, Throttle, make_flowfile)


def _mini_news_flow(tmp_path, n=300, log=None):
    """source → parse/filter junk → dedup → publish(unique) to log."""
    g = FlowGraph("news")
    src = g.add(Source("rss", RssAggregatorSource(count=n, seed=3)))

    def parse(ff):
        try:
            art = ff.json()
        except (ValueError, UnicodeDecodeError):
            return None                       # junk → DROP
        return ff.with_attributes(article_id=art["id"])
    parser = g.add(ExecuteScript("parse", parse))
    dedup = g.add(DetectDuplicate(mode="exact",
                                  key_fn=lambda ff: ff.attributes["article_id"].encode()))
    log = log or PartitionedLog(tmp_path / "log")
    log.create_topic("news", partitions=4)
    pub = g.add(PublishToLog("kafka", log, "news"))
    dups = g.add(CollectSink("dups"))
    g.connect(src, "success", parser)
    g.connect(parser, "success", dedup)
    g.connect(dedup, "unique", pub)
    g.connect(dedup, "duplicate", dups)
    return g, log, pub, dups


def test_end_to_end_news_flow(tmp_path):
    g, log, pub, dups = _mini_news_flow(tmp_path)
    g.run_to_completion(timeout=60)
    st = g.status()
    created = st["processors"]["rss"]["in_records"]
    assert created == 300
    # no record is lost: published + duplicates + junk == created
    junk = st["processors"]["parse"]["dropped"]
    assert pub.published + len(dups.items) + junk == created
    assert pub.published > 0 and len(dups.items) > 0 and junk > 0
    # published records are readable from the log
    total = sum(log.end_offset("news", p) for p in range(4))
    assert total == pub.published
    log.close()


def test_provenance_lineage_walk(tmp_path):
    # n chosen so the seeded stream contains junk (DROP events) as well
    g, log, pub, _ = _mini_news_flow(tmp_path, n=150)
    g.run_to_completion(timeout=60)
    counts = g.provenance.counts()
    assert counts["CREATE"] == 150
    assert counts["ROUTE"] > 0 and counts["DROP"] > 0
    # walk one lineage end-to-end (paper Fig. 4)
    ev = g.provenance.events(event_type="CREATE")[0]
    chain = g.provenance.lineage_chain(ev.lineage_id)
    assert chain[0] == "rss"
    log.close()


def test_backpressure_propagates_upstream(tmp_path):
    """A stalled stage with tiny queues throttles the source transitively —
    NiFi's 'source no longer scheduled' behaviour across two hops.
    Deterministic: the stage blocks on an Event, not a timer."""
    g = FlowGraph("bp")
    emitted = []
    gate = threading.Event()
    reached_gate = threading.Event()

    def gen():
        for i in range(200):
            emitted.append(i)
            yield make_flowfile(f"{i}", i=str(i))

    def gated(ff):
        reached_gate.set()
        assert gate.wait(60)
        return ff

    src = g.add(Source("fast-src", gen))
    ident = g.add(ExecuteScript("ident", lambda ff: ff))
    slow = g.add(ExecuteScript("slow", gated))
    sink = g.add(CollectSink("sink"))
    c1 = g.connect(src, "success", ident, object_threshold=8)
    c2 = g.connect(ident, "success", slow, object_threshold=8)
    g.connect(slow, "success", sink)
    g.start()
    reached_gate.wait(30)
    # let the upstream stages fill their bounded queues and stall
    deadline = time.monotonic() + 10
    while time.monotonic() < deadline:
        if (c1.snapshot()["backpressure_engagements"] >= 1
                and len(c1) >= 8 and len(c2) >= 8):
            break
        time.sleep(0.02)
    # source cannot run ahead of the two 8-deep queues + in-flight batches
    assert len(emitted) <= 8 + 8 + slow.batch_size + ident.batch_size
    assert c1.snapshot()["backpressure_engagements"] >= 1
    gate.set()                                  # stage recovers
    g.join(timeout=120)
    assert len(sink.items) == 200               # nothing lost


def test_flow_error_surfaces(tmp_path):
    g = FlowGraph("err")
    src = g.add(Source("s", lambda: iter([make_flowfile(b"x")])))
    class Bad(ExecuteScript):
        def on_trigger(self, batch):
            raise RuntimeError("boom")
    bad = g.add(Bad("bad", lambda ff: ff))
    g.connect(src, "success", bad)
    with pytest.raises(FlowError, match="bad"):
        g.run_to_completion(timeout=30)


def test_unwired_relationship_is_auto_terminated(tmp_path):
    g = FlowGraph("auto")
    src = g.add(Source("s", lambda: (make_flowfile(f"{i}") for i in range(5))))
    d = g.add(DetectDuplicate(mode="exact"))
    sink = g.add(CollectSink("sink"))
    g.connect(src, "success", d)
    g.connect(d, "unique", sink)
    # 'duplicate' left unwired on purpose
    g.run_to_completion(timeout=30)
    assert len(sink.items) == 5


def test_crash_replay_from_log(tmp_path):
    """The distribution property (paper §III.C): consumers replay from the
    durable log after a crash without touching the ingestion pipeline."""
    g, log, pub, _ = _mini_news_flow(tmp_path, n=120)
    g.run_to_completion(timeout=60)
    grp = ConsumerGroup(log, "news", "analytics")
    c = grp.add_member("m0")
    seen = []
    while True:
        recs = c.poll(max_records=17)
        if not recs:
            break
        seen.extend(recs)
        c.commit()
    assert len(seen) == pub.published
    # replay: a NEW consumer group re-reads everything from offset 0
    grp2 = ConsumerGroup(log, "news", "replay-group")
    c2 = grp2.add_member("m0")
    replay = []
    while True:
        recs = c2.poll(max_records=64)
        if not recs:
            break
        replay.extend(recs)
    assert len(replay) == pub.published
    # FlowFile metadata survives the log roundtrip
    ff = FlowFile.from_record(replay[0].key, replay[0].value)
    assert "article_id" in ff.attributes
    log.close()


def test_fan_in_merges_sources(tmp_path):
    """Integration requirement (paper §II.A): merge streams from several
    sources into a single flow."""
    g = FlowGraph("fanin")
    s1 = g.add(Source("s1", lambda: (make_flowfile(f"a{i}", src="1") for i in range(10))))
    s2 = g.add(Source("s2", lambda: (make_flowfile(f"b{i}", src="2") for i in range(10))))
    sink = g.add(CollectSink("sink"))
    g.connect(s1, "success", sink)
    g.connect(s2, "success", sink)
    g.run_to_completion(timeout=30)
    assert len(sink.items) == 20
    assert {f.attributes["src"] for f in sink.items} == {"1", "2"}


def test_lineage_index_survives_ring_eviction(tmp_path):
    """With a spill configured, lineage() is an indexed file lookup: it
    returns the FULL history of a record even after the bounded in-memory
    ring evicted its events (ROADMAP: provenance at scale)."""
    from repro.core import ProvenanceRepository, make_flowfile
    repo = ProvenanceRepository(capacity=8, spill_path=tmp_path / "prov.jsonl")
    ffs = [make_flowfile(f"rec-{i}") for i in range(20)]
    for ff in ffs:
        repo.record("CREATE", ff, "src")
    repo.record_batch("ROUTE", ffs, "src", details="success")
    for ff in ffs:
        repo.record("SEND", ff, "sink")
    target = ffs[0].lineage_id               # its events left the ring long ago
    assert all(e.lineage_id != target for e in repo.events())
    evs = repo.lineage(target)
    assert [e.event_type for e in evs] == ["CREATE", "ROUTE", "SEND"]
    assert [e.component for e in evs] == ["src", "src", "sink"]
    assert repo.lineage_chain(target) == ["src", "sink"]
    repo.close()


def test_lineage_index_reopens_existing_spill(tmp_path):
    from repro.core import ProvenanceRepository, make_flowfile
    path = tmp_path / "prov.jsonl"
    repo = ProvenanceRepository(capacity=4, spill_path=path)
    ff = make_flowfile("persistent record")
    repo.record("CREATE", ff, "src")
    repo.close()
    # torn tail from a crash mid-write must be truncated away at reopen
    with open(path, "ab") as f:
        f.write(b'{"event_type": "SEND", "torn')

    repo2 = ProvenanceRepository(capacity=4, spill_path=path)
    repo2.record("SEND", ff, "sink")
    evs = repo2.lineage(ff.lineage_id)
    assert [e.event_type for e in evs] == ["CREATE", "SEND"]
    repo2.close()


def test_lineage_without_spill_still_scans_ring(tmp_path):
    from repro.core import ProvenanceRepository, make_flowfile
    repo = ProvenanceRepository(capacity=100)
    ff = make_flowfile("in-memory only")
    repo.record("CREATE", ff, "src")
    assert [e.event_type for e in repo.lineage(ff.lineage_id)] == ["CREATE"]
    repo.close()
