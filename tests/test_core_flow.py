"""End-to-end FlowGraph tests: the paper's case-study topology in miniature,
provenance lineage, backpressure propagation through the graph, failure
routing, and crash-replay recovery through the durable log."""
import json
import threading
import time

import pytest

from repro.core import (CollectSink, ConsumerGroup, ContentFilter,
                        DetectDuplicate, ExecuteScript, FileSink, FlowError,
                        FlowFile, FlowGraph, MergeContent, PartitionedLog,
                        PublishToLog, RouteOnAttribute, RssAggregatorSource,
                        Source, Throttle, make_flowfile)

#: fast concurrency-layer module: CI re-runs it under the
#: REPRO_LOCK_ORDER=1 lock-order detector (scripts/ci.sh)
pytestmark = pytest.mark.lockorder


def _mini_news_flow(tmp_path, n=300, log=None):
    """source → parse/filter junk → dedup → publish(unique) to log."""
    g = FlowGraph("news")
    src = g.add(Source("rss", RssAggregatorSource(count=n, seed=3)))

    def parse(ff):
        try:
            art = ff.json()
        except (ValueError, UnicodeDecodeError):
            return None                       # junk → DROP
        return ff.with_attributes(article_id=art["id"])
    parser = g.add(ExecuteScript("parse", parse))
    dedup = g.add(DetectDuplicate(mode="exact",
                                  key_fn=lambda ff: ff.attributes["article_id"].encode()))
    log = log or PartitionedLog(tmp_path / "log")
    log.create_topic("news", partitions=4)
    pub = g.add(PublishToLog("kafka", log, "news"))
    dups = g.add(CollectSink("dups"))
    g.connect(src, "success", parser)
    g.connect(parser, "success", dedup)
    g.connect(dedup, "unique", pub)
    g.connect(dedup, "duplicate", dups)
    return g, log, pub, dups


def test_end_to_end_news_flow(tmp_path):
    g, log, pub, dups = _mini_news_flow(tmp_path)
    g.run_to_completion(timeout=60)
    st = g.status()
    created = st["processors"]["rss"]["in_records"]
    assert created == 300
    # no record is lost: published + duplicates + junk == created
    junk = st["processors"]["parse"]["dropped"]
    assert pub.published + len(dups.items) + junk == created
    assert pub.published > 0 and len(dups.items) > 0 and junk > 0
    # published records are readable from the log
    total = sum(log.end_offset("news", p) for p in range(4))
    assert total == pub.published
    log.close()


def test_provenance_lineage_walk(tmp_path):
    # n chosen so the seeded stream contains junk (DROP events) as well
    g, log, pub, _ = _mini_news_flow(tmp_path, n=150)
    g.run_to_completion(timeout=60)
    counts = g.provenance.counts()
    assert counts["CREATE"] == 150
    assert counts["ROUTE"] > 0 and counts["DROP"] > 0
    # walk one lineage end-to-end (paper Fig. 4)
    ev = g.provenance.events(event_type="CREATE")[0]
    chain = g.provenance.lineage_chain(ev.lineage_id)
    assert chain[0] == "rss"
    log.close()


def test_backpressure_propagates_upstream(tmp_path):
    """A stalled stage with tiny queues throttles the source transitively —
    NiFi's 'source no longer scheduled' behaviour across two hops.
    Deterministic: the stage blocks on an Event, not a timer."""
    g = FlowGraph("bp")
    emitted = []
    gate = threading.Event()
    reached_gate = threading.Event()

    def gen():
        for i in range(200):
            emitted.append(i)
            yield make_flowfile(f"{i}", i=str(i))

    def gated(ff):
        reached_gate.set()
        assert gate.wait(60)
        return ff

    src = g.add(Source("fast-src", gen))
    ident = g.add(ExecuteScript("ident", lambda ff: ff))
    slow = g.add(ExecuteScript("slow", gated))
    sink = g.add(CollectSink("sink"))
    c1 = g.connect(src, "success", ident, object_threshold=8)
    c2 = g.connect(ident, "success", slow, object_threshold=8)
    g.connect(slow, "success", sink)
    g.start()
    reached_gate.wait(30)
    # let the upstream stages fill their bounded queues and stall
    deadline = time.monotonic() + 10
    while time.monotonic() < deadline:
        if (c1.snapshot()["backpressure_engagements"] >= 1
                and len(c1) >= 8 and len(c2) >= 8):
            break
        time.sleep(0.02)
    # source cannot run ahead of the two 8-deep queues + in-flight batches
    assert len(emitted) <= 8 + 8 + slow.batch_size + ident.batch_size
    assert c1.snapshot()["backpressure_engagements"] >= 1
    gate.set()                                  # stage recovers
    g.join(timeout=120)
    assert len(sink.items) == 200               # nothing lost


def test_flow_error_surfaces(tmp_path):
    g = FlowGraph("err")
    src = g.add(Source("s", lambda: iter([make_flowfile(b"x")])))
    class Bad(ExecuteScript):
        def on_trigger(self, batch):
            raise RuntimeError("boom")
    bad = g.add(Bad("bad", lambda ff: ff))
    g.connect(src, "success", bad)
    with pytest.raises(FlowError, match="bad"):
        g.run_to_completion(timeout=30)


def test_unwired_relationship_is_auto_terminated(tmp_path):
    g = FlowGraph("auto")
    src = g.add(Source("s", lambda: (make_flowfile(f"{i}") for i in range(5))))
    d = g.add(DetectDuplicate(mode="exact"))
    sink = g.add(CollectSink("sink"))
    g.connect(src, "success", d)
    g.connect(d, "unique", sink)
    # 'duplicate' left unwired on purpose
    g.run_to_completion(timeout=30)
    assert len(sink.items) == 5


def test_crash_replay_from_log(tmp_path):
    """The distribution property (paper §III.C): consumers replay from the
    durable log after a crash without touching the ingestion pipeline."""
    g, log, pub, _ = _mini_news_flow(tmp_path, n=120)
    g.run_to_completion(timeout=60)
    grp = ConsumerGroup(log, "news", "analytics")
    c = grp.add_member("m0")
    seen = []
    while True:
        recs = c.poll(max_records=17)
        if not recs:
            break
        seen.extend(recs)
        c.commit()
    assert len(seen) == pub.published
    # replay: a NEW consumer group re-reads everything from offset 0
    grp2 = ConsumerGroup(log, "news", "replay-group")
    c2 = grp2.add_member("m0")
    replay = []
    while True:
        recs = c2.poll(max_records=64)
        if not recs:
            break
        replay.extend(recs)
    assert len(replay) == pub.published
    # FlowFile metadata survives the log roundtrip
    ff = FlowFile.from_record(replay[0].key, replay[0].value)
    assert "article_id" in ff.attributes
    log.close()


def test_fan_in_merges_sources(tmp_path):
    """Integration requirement (paper §II.A): merge streams from several
    sources into a single flow."""
    g = FlowGraph("fanin")
    s1 = g.add(Source("s1", lambda: (make_flowfile(f"a{i}", src="1") for i in range(10))))
    s2 = g.add(Source("s2", lambda: (make_flowfile(f"b{i}", src="2") for i in range(10))))
    sink = g.add(CollectSink("sink"))
    g.connect(s1, "success", sink)
    g.connect(s2, "success", sink)
    g.run_to_completion(timeout=30)
    assert len(sink.items) == 20
    assert {f.attributes["src"] for f in sink.items} == {"1", "2"}


def test_lineage_index_survives_ring_eviction(tmp_path):
    """With a spill configured, lineage() is an indexed file lookup: it
    returns the FULL history of a record even after the bounded in-memory
    ring evicted its events (ROADMAP: provenance at scale)."""
    from repro.core import ProvenanceRepository, make_flowfile
    repo = ProvenanceRepository(capacity=8, spill_path=tmp_path / "prov.jsonl")
    ffs = [make_flowfile(f"rec-{i}") for i in range(20)]
    for ff in ffs:
        repo.record("CREATE", ff, "src")
    repo.record_batch("ROUTE", ffs, "src", details="success")
    for ff in ffs:
        repo.record("SEND", ff, "sink")
    target = ffs[0].lineage_id               # its events left the ring long ago
    assert all(e.lineage_id != target for e in repo.events())
    evs = repo.lineage(target)
    assert [e.event_type for e in evs] == ["CREATE", "ROUTE", "SEND"]
    assert [e.component for e in evs] == ["src", "src", "sink"]
    assert repo.lineage_chain(target) == ["src", "sink"]
    repo.close()


def test_lineage_index_reopens_existing_spill(tmp_path):
    from repro.core import ProvenanceRepository, make_flowfile
    path = tmp_path / "prov.jsonl"
    repo = ProvenanceRepository(capacity=4, spill_path=path)
    ff = make_flowfile("persistent record")
    repo.record("CREATE", ff, "src")
    repo.close()
    # torn tail from a crash mid-write must be truncated away at reopen
    with open(path, "ab") as f:
        f.write(b'{"event_type": "SEND", "torn')

    repo2 = ProvenanceRepository(capacity=4, spill_path=path)
    repo2.record("SEND", ff, "sink")
    evs = repo2.lineage(ff.lineage_id)
    assert [e.event_type for e in evs] == ["CREATE", "SEND"]
    repo2.close()


def test_lineage_without_spill_still_scans_ring(tmp_path):
    from repro.core import ProvenanceRepository, make_flowfile
    repo = ProvenanceRepository(capacity=100)
    ff = make_flowfile("in-memory only")
    repo.record("CREATE", ff, "src")
    assert [e.event_type for e in repo.lineage(ff.lineage_id)] == ["CREATE"]
    repo.close()


# ---------------------------------------------------------------------------
# elastic worker pools (ISSUE 7)
# ---------------------------------------------------------------------------
def _gen(n):
    def it():
        for i in range(n):
            yield make_flowfile(b"x" * 32, i=str(i))
    return it


def test_elastic_pool_scales_up_under_sustained_depth():
    g = FlowGraph("pool")
    src = g.add(Source("src", _gen(400)))

    def slow_fn(ff):
        time.sleep(0.001)
        return ff

    slow = g.add(ExecuteScript("slow", slow_fn), min_workers=1, max_workers=3)
    # fast-reacting governor so the test stays quick
    slow.scale_up_utilization = 0.25
    slow.scale_up_polls = 1
    sink = g.add(CollectSink("sink"))
    g.connect(src, "success", slow, object_threshold=16)
    g.connect(slow, "success", sink)
    g.run_to_completion(timeout=60)
    st = g.status()["processors"]["slow"]
    assert st["scale_ups"] >= 1                  # the burst grew the pool
    assert st["workers"] == 1                    # helpers departed at drain
    ids = [f.attributes["i"] for f in sink.items]
    assert len(ids) == 400 and len(set(ids)) == 400   # no loss, no dup


def test_min_workers_fill_is_not_a_scale_event():
    g = FlowGraph("pool-min")
    src = g.add(Source("src", _gen(60)))
    work = g.add(ExecuteScript("work", lambda ff: ff),
                 min_workers=2, max_workers=2)
    sink = g.add(CollectSink("sink"))
    g.connect(src, "success", work)
    g.connect(work, "success", sink)
    g.run_to_completion(timeout=60)
    st = g.status()["processors"]["work"]
    assert st["scale_ups"] == 0 and st["scale_downs"] == 0
    assert len(sink.items) == 60


def test_helper_failure_replays_on_supervised_path():
    """A record failing in a pool helper must not be lost: the escalation
    path hands the in-flight batch back to the queue, the helper exits, and
    the replay lands on the primary's supervised (restartable) worker."""
    from repro.core import RestartPolicy
    g = FlowGraph("pool-fail")
    src = g.add(Source("src", _gen(100)))
    tripped = threading.Event()

    # the raise must escape on_trigger (ExecuteScript's own fn-level catch
    # would route to `failure` instead of exercising the escalation path)
    class Flaky(ExecuteScript):
        def process(self, ff):
            if ff.attributes["i"] == "37" and not tripped.is_set():
                tripped.set()
                raise RuntimeError("boom")
            time.sleep(0.0005)
            yield "success", ff

    slow = g.add(Flaky("flaky", lambda ff: ff),
                 restart_policy=RestartPolicy(max_restarts=5,
                                              backoff_base_sec=0.001),
                 min_workers=2, max_workers=2)
    sink = g.add(CollectSink("sink"))
    g.connect(src, "success", slow, object_threshold=8)
    g.connect(slow, "success", sink)
    g.run_to_completion(timeout=60)
    assert tripped.is_set()
    ids = {f.attributes["i"] for f in sink.items}
    assert ids == {str(i) for i in range(100)}   # at-least-once, zero loss


def test_pool_eligibility_refusals(tmp_path):
    # sources: one replayable generator, one cursor — no pool
    g = FlowGraph("v1")
    src = g.add(Source("src", _gen(5)), max_workers=2)
    sink = g.add(CollectSink("sink"))
    g.connect(src, "success", sink)
    with pytest.raises(FlowError, match="sources cannot"):
        g.start()

    # durable inputs: the acked frontier is a count prefix — no pool
    log = PartitionedLog(tmp_path / "log")
    g2 = FlowGraph("v2")
    src2 = g2.add(Source("src", _gen(5)))
    es2 = g2.add(ExecuteScript("es", lambda ff: ff), max_workers=2)
    sink2 = g2.add(CollectSink("sink"))
    g2.connect(src2, "success", es2, durable=log)
    g2.connect(es2, "success", sink2)
    with pytest.raises(FlowError, match="durable"):
        g2.start()
    log.close()

    # cross-trigger buffering state — no pool
    g3 = FlowGraph("v3")
    src3 = g3.add(Source("src", _gen(5)))
    merge = g3.add(MergeContent("merge", max_records=4), max_workers=2)
    sink3 = g3.add(CollectSink("sink"))
    g3.connect(src3, "success", merge)
    g3.connect(merge, "success", sink3)
    with pytest.raises(FlowError, match="buffers_across_triggers"):
        g3.start()

    # idle-triggered state machines — no pool
    g4 = FlowGraph("v4")
    src4 = g4.add(Source("src", _gen(5)))
    es4 = g4.add(ExecuteScript("es", lambda ff: ff), max_workers=2)
    es4.idle_trigger_sec = 0.1
    sink4 = g4.add(CollectSink("sink"))
    g4.connect(src4, "success", es4)
    g4.connect(es4, "success", sink4)
    with pytest.raises(FlowError, match="idle-triggered"):
        g4.start()

    # bounds must be sane
    g5 = FlowGraph("v5")
    with pytest.raises(ValueError, match="min_workers"):
        g5.add(ExecuteScript("es", lambda ff: ff),
               min_workers=3, max_workers=2)
