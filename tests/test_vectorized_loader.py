"""The batched/vectorized loader hot path must be bit-identical to the
per-document reference path: same tokens, same packed rows, same
checkpointable state (the exactly-once story depends on it)."""
import numpy as np

from repro.core import ConsumerGroup, PartitionedLog, make_flowfile
from repro.core.sources import corpus_documents
from repro.data import StreamingDataLoader
from repro.data.packing import SequencePacker
from repro.data.tokenizer import ByteTokenizer


def test_encode_batch_matches_encode():
    tok = ByteTokenizer()
    texts = ["hello world", "", "héllo wörld — unicode", "abc" * 100]
    flat = np.concatenate([tok.encode_np(t) for t in texts])
    assert np.array_equal(tok.encode_batch(texts), flat)
    # bos/eos toggles behave like the scalar path
    flat_plain = np.concatenate(
        [tok.encode_np(t, add_bos=False, add_eos=False) for t in texts])
    assert np.array_equal(
        tok.encode_batch(texts, add_bos=False, add_eos=False), flat_plain)


def test_add_tokens_matches_add_document():
    rng = np.random.default_rng(0)
    docs = [rng.integers(0, 259, size=int(n)).tolist()
            for n in rng.integers(1, 90, size=40)]
    for seq_len in (8, 31):
        ref = SequencePacker(seq_len, 256)
        vec = SequencePacker(seq_len, 256)
        ref_rows = [row for d in docs for row in ref.add_document(d)]
        vec_rows = vec.add_tokens(np.concatenate(
            [np.asarray(d, dtype=np.int32) for d in docs]))
        assert np.array_equal(np.stack(ref_rows), vec_rows)
        assert ref.state() == vec.state()


class _ScalarOnlyTokenizer(ByteTokenizer):
    """A pluggable tokenizer without encode_batch (protocol minimum)."""
    encode_batch = None


def _fill_log(tmp_path, n_docs=60, partitions=4):
    log = PartitionedLog(tmp_path / "log")
    log.create_topic("corpus", partitions=partitions)
    records = [make_flowfile(doc).to_record()
               for doc in corpus_documents(n_docs)]
    log.append_batch("corpus", records)
    log.flush(fsync=False)
    return log


def test_loader_batches_identical_with_and_without_encode_batch(tmp_path):
    log = _fill_log(tmp_path)
    grp = ConsumerGroup(log, "corpus", "g")
    fast = StreamingDataLoader(grp.add_member("fast"), batch_size=4,
                               seq_len=64, poll_records=32)
    slow = StreamingDataLoader(grp.add_member("slow"), batch_size=4,
                               seq_len=64, poll_records=32,
                               tokenizer=_ScalarOnlyTokenizer())
    # both members see a disjoint half of the partitions; re-point the slow
    # one at the fast one's assignment for an apples-to-apples replay
    slow.consumer.assignment = list(fast.consumer.assignment)
    slow.consumer._positions = dict(fast.consumer.positions())
    slow.consumer._cached_end = {}
    slow.consumer.generation = fast.consumer.generation
    while True:
        a = fast.next_batch(timeout_polls=2)
        b = slow.next_batch(timeout_polls=2)
        assert (a is None) == (b is None)
        if a is None:
            break
        assert np.array_equal(a, b)
    assert fast.state()["packer"] == slow.state()["packer"]


def test_loader_state_roundtrip_with_vectorized_path(tmp_path):
    log = _fill_log(tmp_path)
    grp = ConsumerGroup(log, "corpus", "g")
    loader = StreamingDataLoader(grp.add_member("m0"), batch_size=4,
                                 seq_len=64, poll_records=16)
    first = loader.next_batch(timeout_polls=2)
    assert first is not None
    ckpt = loader.state()
    second = loader.next_batch(timeout_polls=2)
    loader.restore(ckpt)
    replay = loader.next_batch(timeout_polls=2)
    assert np.array_equal(second, replay)
    log.close()
