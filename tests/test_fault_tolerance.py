"""Fault-tolerant flow runtime: supervised restarts with backoff, record
retry + penalization, dead-letter quarantine, WAL-backed connections, and
the acceptance scenario — the news topology surviving a mid-graph processor
fault-injected to crash every ~N records with zero record loss."""
import json
import time

import pytest

from repro.core import (CollectSink, DeadLetterQueue, DurableConnection,
                        ExecuteScript, FlowError, FlowGraph, PartitionedLog,
                        RestartPolicy, RssAggregatorSource, Source,
                        make_flowfile)
from repro.core.faults import INJECTOR, InjectedFault, raise_on
from repro.data.pipeline import (arm_news_chaos, build_news_pipeline,
                                 expected_clean_doc_ids)


def _linear_flow(n=100, policy=None, max_retries=0, dlq_log=None,
                 topic="dead"):
    g = FlowGraph("ft")
    src = g.add(Source("src", lambda: (
        make_flowfile(f"rec-{i}", i=str(i), poison="1" if i % 10 == 3 else "0")
        for i in range(n))))
    work = g.add(ExecuteScript("work", lambda ff: ff), restart_policy=policy)
    sink = g.add(CollectSink("sink"))
    g.connect(src, "success", work, max_retries=max_retries,
              retry_penalty_sec=0.001)
    g.connect(work, "success", sink)
    dlq = None
    if dlq_log is not None:
        dlq = g.add(DeadLetterQueue("dlq", dlq_log, topic=topic))
        g.route_dead_letters_to(dlq)
    return g, sink, dlq


# ---------------------------------------------------------------------------
# supervisor
# ---------------------------------------------------------------------------
def test_transient_fault_restarts_without_record_loss():
    g, sink, _ = _linear_flow(
        n=200, policy=RestartPolicy(max_restarts=3, backoff_base_sec=0.01))
    INJECTOR.arm("proc.work", "raise", nth=1)          # fails exactly once
    g.run_to_completion(timeout=60)
    st = g.status()
    assert len(sink.items) == 200                      # in-flight batch kept
    assert st["processors"]["work"]["restarts"] == 1
    assert st["processors"]["work"]["state"] == "COMPLETED"
    assert st["failed"] == []


def test_restart_backoff_schedule_observed():
    policy = RestartPolicy(max_restarts=3, backoff_base_sec=0.01,
                           backoff_factor=2.0, backoff_cap_sec=10.0)
    g, sink, _ = _linear_flow(n=50, policy=policy)
    fires = {"n": 0}

    def three_times(ctx):
        if fires["n"] < 3:
            fires["n"] += 1
            raise InjectedFault("transient")
    INJECTOR.arm("proc.work", three_times, every=1)
    g.run_to_completion(timeout=60)
    node = g.nodes["work"]
    assert node.restarts == 3
    assert node.backoff_history == [0.01, 0.02, 0.04]  # exponential
    assert len(sink.items) == 50


def test_failed_terminal_only_after_budget_exhausted():
    policy = RestartPolicy(max_restarts=2, backoff_base_sec=0.005)
    g, sink, _ = _linear_flow(n=10, policy=policy)
    INJECTOR.arm("proc.work", "raise", nth=1, every=1)  # always fails
    with pytest.raises(FlowError, match="work"):
        g.run_to_completion(timeout=60)
    node = g.nodes["work"]
    assert node.state == "FAILED"
    assert node.restarts == 2                     # full budget consumed first
    assert g.status()["failed"] == ["work"]


def test_default_policy_preserves_fail_fast():
    g, sink, _ = _linear_flow(n=10)               # no policy, no retries
    INJECTOR.arm("proc.work", "raise", nth=1)
    with pytest.raises(FlowError, match="work"):
        g.run_to_completion(timeout=60)
    assert g.nodes["work"].restarts == 0
    assert g.nodes["work"].state == "FAILED"


def test_source_restart_fast_forwards_replayable_generator():
    g = FlowGraph("src-restart")
    src = g.add(Source("src", lambda: (make_flowfile(f"{i}", i=str(i))
                                       for i in range(100))),
                restart_policy=RestartPolicy(max_restarts=2,
                                             backoff_base_sec=0.005))
    sink = g.add(CollectSink("sink"))
    g.connect(src, "success", sink)
    INJECTOR.arm("proc.src", "raise", nth=2)      # fail on the 2nd trigger
    g.run_to_completion(timeout=60)
    # the fault fired before any emit of that batch: replay is exact
    assert sorted(int(f.attributes["i"]) for f in sink.items) == list(range(100))
    assert g.nodes["src"].restarts == 1


# ---------------------------------------------------------------------------
# retry + dead-letter routing
# ---------------------------------------------------------------------------
def test_poison_routed_to_dlq_after_max_retries(tmp_path):
    log = PartitionedLog(tmp_path / "log")
    g, sink, dlq = _linear_flow(n=100, max_retries=2, dlq_log=log)
    INJECTOR.arm("proc.work",
                 raise_on(lambda ff: ff.attributes.get("poison") == "1"),
                 every=1)
    g.run_to_completion(timeout=60)
    st = g.status()
    assert len(sink.items) == 90                  # innocents all pass
    assert dlq.quarantined == 10
    assert st["processors"]["work"]["retries"] == 20        # 10 poison * 2
    assert st["processors"]["work"]["dead_lettered"] == 10
    # quarantined records carry the retry/dead-letter audit trail and are
    # keyed by provenance lineage id in the log
    quarantined = list(DeadLetterQueue.replay(log, "dead"))
    assert len(quarantined) == 10
    assert all(ff.attributes["retry.count"] == "2" for ff in quarantined)
    assert all(ff.attributes["dead.letter.source"] == "work"
               for ff in quarantined)
    recs = log.read("dead", 0, 0, max_records=100)
    assert {r.key.decode() for r in recs} == \
           {ff.lineage_id for ff in quarantined}
    log.close()


def test_record_recovers_within_retry_budget():
    """A record that fails twice and then succeeds must land downstream,
    not in the DLQ (penalization + retry.count attribute observable)."""
    g, sink, _ = _linear_flow(n=40, max_retries=3)
    INJECTOR.arm("proc.work", raise_on(
        lambda ff: (ff.attributes.get("poison") == "1"
                    and int(ff.attributes.get("retry.count", "0")) < 2)),
        every=1)
    g.run_to_completion(timeout=60)
    assert len(sink.items) == 40                  # nothing lost, nothing DLQd
    st = g.status()
    assert st["processors"]["work"]["dead_lettered"] == 0
    retried = [f for f in sink.items if f.attributes.get("retry.count")]
    assert retried and all(f.attributes["retry.count"] == "2"
                           for f in retried)
    assert st["processors"]["work"]["retries"] == 2 * len(retried)


def test_exhausted_records_without_dlq_are_dropped_with_provenance():
    g, sink, _ = _linear_flow(n=50, max_retries=1)
    INJECTOR.arm("proc.work",
                 raise_on(lambda ff: ff.attributes.get("poison") == "1"),
                 every=1)
    g.run_to_completion(timeout=60)               # must NOT raise
    st = g.status()
    assert len(sink.items) == 45
    assert st["processors"]["work"]["dead_lettered"] == 5
    drops = g.provenance.events(event_type="DROP", component="work")
    assert sum(1 for e in drops if e.details == "dead-letter:unrouted") == 5


def test_failing_dlq_escalates_instead_of_self_looping(tmp_path):
    """If the quarantine itself breaks, records must NOT be dead-lettered
    back into its own input (infinite self-loop); the supervisor escalates
    and the graph fails fast."""
    log = PartitionedLog(tmp_path / "log")
    g, sink, dlq = _linear_flow(n=30, max_retries=1, dlq_log=log)
    INJECTOR.arm("proc.work",
                 raise_on(lambda ff: ff.attributes.get("poison") == "1"),
                 every=1)
    log.close()                                   # breaks every DLQ append
    with pytest.raises(FlowError, match="dlq"):
        g.run_to_completion(timeout=30)
    assert g.nodes["dlq"].state == "FAILED"


def test_escalation_requeue_with_full_input_queue_fails_fast():
    """Default (no-FT) config with the input queue at its backpressure
    threshold: the pre-restart requeue must not deadlock against the queue
    this worker itself drains — the error still surfaces promptly."""
    g = FlowGraph("full-queue")
    src = g.add(Source("src", lambda: (make_flowfile(f"{i}", i=str(i))
                                       for i in range(500))))
    work = g.add(ExecuteScript("work", lambda ff: ff))
    sink = g.add(CollectSink("sink"))
    g.connect(src, "success", work, object_threshold=8)   # tiny queue
    g.connect(work, "success", sink)
    INJECTOR.arm("proc.work", "raise", nth=2)
    t0 = time.monotonic()
    with pytest.raises(FlowError, match="work"):
        g.run_to_completion(timeout=60)
    assert time.monotonic() - t0 < 30             # failed fast, no hang


# ---------------------------------------------------------------------------
# WAL-backed connections
# ---------------------------------------------------------------------------
def test_durable_wal_gc_drops_acked_segments(tmp_path):
    """The WAL must stay O(in-flight): segments wholly below the acked
    frontier are garbage-collected as acks accumulate."""
    log = PartitionedLog(tmp_path / "log", segment_bytes=2048)
    c = DurableConnection("a:success->b", log)
    for i in range(400):                          # ~ many small segments
        c.offer(make_flowfile(f"record-{i:04d}" * 4, i=str(i)))
        got = c.poll_batch(4)
        c.ack(len(got))
    wal_dir = tmp_path / "log" / c.topic / "0"
    segs = sorted(int(p.stem) for p in wal_dir.glob("*.seg"))
    assert segs and segs[0] > 0                   # leading segments dropped
    assert len(segs) < 10
    # recovery still works against the GC'd journal
    log2 = PartitionedLog(tmp_path / "log", segment_bytes=2048)
    c2 = DurableConnection("a:success->b", log2)
    remaining = [ff.attributes["i"] for ff in c2.poll_batch(500)]
    assert remaining == [str(i) for i in range(c.acked, 400)]
    log.close()
    log2.close()
def test_durable_connection_offer_poll_ack_replay(tmp_path):
    log = PartitionedLog(tmp_path / "log")
    c = DurableConnection("a:success->b", log)
    for i in range(30):
        c.offer(make_flowfile(f"r{i}", i=str(i)))
    first = c.poll_batch(10)
    c.ack(len(first))
    c.poll_batch(5)                               # polled but never acked
    # crash: rebuild the connection over a fresh log handle
    log2 = PartitionedLog(tmp_path / "log")
    c2 = DurableConnection("a:success->b", log2)
    assert c2.replayed == 20                      # 30 offered - 10 acked
    replay = [ff.attributes["i"] for ff in c2.poll_batch(50)]
    assert replay == [str(i) for i in range(10, 30)]   # frontier order kept
    snap = c2.snapshot()
    assert snap["durable"] and snap["acked"] == 10
    log.close()
    log2.close()


def test_durable_connection_in_graph_acks_to_frontier(tmp_path):
    log = PartitionedLog(tmp_path / "log")
    g = FlowGraph("durable")
    src = g.add(Source("s", lambda: (make_flowfile(f"{i}") for i in range(64))))
    sink = g.add(CollectSink("sink"))
    conn = g.connect(src, "success", sink, durable=log)
    assert isinstance(conn, DurableConnection)
    g.run_to_completion(timeout=60)
    assert len(sink.items) == 64
    # every consumed batch was acked: a rebuild has nothing to replay
    assert conn.acked == 64
    c2 = DurableConnection("s:success->sink", PartitionedLog(tmp_path / "log"))
    assert c2.replayed == 0
    log.close()


def test_durable_connection_rejects_prioritizer(tmp_path):
    log = PartitionedLog(tmp_path / "log")
    g = FlowGraph("bad")
    src = g.add(Source("s", lambda: iter(())))
    sink = g.add(CollectSink("k"))
    with pytest.raises(FlowError, match="FIFO"):
        g.connect(src, "success", sink, durable=log,
                  prioritizer=lambda ff: 0.0)
    log.close()


def test_durable_buffering_processor_defers_acks(tmp_path):
    """A buffering processor (MergeContent) on a durable input must not ack
    records it absorbed into internal state at trigger time — acks land only
    at the final flush, so a crash replays the whole buffered window."""
    from repro.core import MergeContent
    assert MergeContent.buffers_across_triggers
    log = PartitionedLog(tmp_path / "log")
    g = FlowGraph("merge")
    src = g.add(Source("s", lambda: (make_flowfile(f"rec-{i}")
                                     for i in range(100))))
    merge = g.add(MergeContent("merge", max_records=1000,
                               max_latency_sec=1e9))
    sink = g.add(CollectSink("sink"))
    conn = g.connect(src, "success", merge, durable=log)
    g.connect(merge, "success", sink)
    g.run_to_completion(timeout=60)
    assert len(sink.items) == 1                   # one final bundle
    assert conn.acked == 100                      # acked only at the end
    log.close()


def test_durable_buffering_escalation_does_not_ack_over_buffered(tmp_path):
    """Supervisor escalation on a later trigger must not ack the durable
    frontier past records an ack-deferring processor still holds in its
    internal buffer — after the crash they must be replayable."""
    from repro.core import MergeContent
    log = PartitionedLog(tmp_path / "log")
    g = FlowGraph("merge-crash")
    src = g.add(Source("s", lambda: (make_flowfile(f"rec-{i}", i=str(i))
                                     for i in range(10))))
    merge = g.add(MergeContent("merge", max_records=1000,
                               max_latency_sec=1e9))
    merge.batch_size = 5                          # >= two triggers
    sink = g.add(CollectSink("sink"))
    g.connect(src, "success", merge, durable=log)
    g.connect(merge, "success", sink)
    INJECTOR.arm("proc.merge", "raise", nth=2)    # escalates: no retry wired
    with pytest.raises(FlowError, match="merge"):
        g.run_to_completion(timeout=30)
    # rebuild: every source record is still in the un-acked WAL suffix,
    # including the ones trigger 1 had buffered inside the merger
    c2 = DurableConnection("s:success->merge", PartitionedLog(tmp_path / "log"))
    replayed = {ff.attributes["i"] for ff in c2.poll_batch(100)}
    assert {str(i) for i in range(10)} <= replayed
    log.close()


def test_durable_retry_penalty_is_honored(tmp_path):
    """On a durable connection the penalized copy is re-journaled at once
    (frontier must stay a prefix) but delivery waits out retry.not.before —
    a transient blip must not burn the whole retry budget in microseconds."""
    log = PartitionedLog(tmp_path / "log")
    g = FlowGraph("penalty")
    src = g.add(Source("s", lambda: iter([make_flowfile("x", poison="1")])))
    work = g.add(ExecuteScript("work", lambda ff: ff))
    sink = g.add(CollectSink("sink"))
    g.connect(src, "success", work, durable=log, max_retries=3,
              retry_penalty_sec=0.05)
    g.connect(work, "success", sink)
    INJECTOR.arm("proc.work", raise_on(
        lambda ff: (ff.attributes.get("poison") == "1"
                    and int(ff.attributes.get("retry.count", "0")) < 2)),
        every=1)
    t0 = time.monotonic()
    g.run_to_completion(timeout=60)
    elapsed = time.monotonic() - t0
    assert len(sink.items) == 1                   # recovered, not quarantined
    assert sink.items[0].attributes["retry.count"] == "2"
    assert elapsed >= 0.05 + 0.10                 # 0.05 * 2**0 + 0.05 * 2**1
    log.close()


def test_log_append_batch_raise_site_leaves_index_consistent(tmp_path):
    """A 'raise' armed at log.segment.append_batch must not corrupt the
    in-memory offset index: the failed batch contributes nothing, and a
    retried append lands cleanly."""
    log = PartitionedLog(tmp_path / "log")
    log.create_topic("t", partitions=1)
    recs = [(b"k", f"v{i}".encode()) for i in range(10)]
    INJECTOR.arm("log.segment.append_batch", "raise", nth=1)
    with pytest.raises(InjectedFault):
        log.append_batch("t", recs, partition=0)
    assert log.end_offset("t", 0) == 0            # no phantom records
    log.append_batch("t", recs, partition=0)      # injector spent: succeeds
    assert log.end_offset("t", 0) == 10
    assert [r.value for r in log.iter_records("t", 0)] == \
           [v for _, v in recs]
    log.close()


# ---------------------------------------------------------------------------
# acceptance: news topology + injected crashes every ~N records
# ---------------------------------------------------------------------------
def test_news_topology_zero_record_loss_under_periodic_faults(tmp_path):
    n, seed, poison_rate = 2_000, 11, 0.005
    flow, log = build_news_pipeline(
        tmp_path, n_rss=n, n_firehose=0, n_ws=0, partitions=4, seed=seed,
        restart_policy=RestartPolicy(max_restarts=40, backoff_base_sec=0.002,
                                     backoff_cap_sec=0.05),
        max_retries=3, dead_letter_topic="dead-letters",
        poison_rate=poison_rate)
    arm_news_chaos(crash_every=300, source_nth=3, source_every=5)
    flow.run_to_completion(timeout=120)

    # at-least-once: every clean article id lands (duplicates allowed)
    expected = expected_clean_doc_ids(n, seed, poison_rate)
    n_poison = sum(
        1 for ff in RssAggregatorSource(n, seed=seed,
                                        poison_rate=poison_rate)()
        if ff.attributes.get("kind") == "poison")
    landed = {json.loads(r.key)["attributes"].get("doc_id", "")
              for r in log.iter_records("articles")}
    assert expected <= landed, f"lost {len(expected - landed)} records"
    # poison records ended up quarantined, not lost and not published
    dlq = flow.nodes["dead-letter"].processor
    assert n_poison > 0 and dlq.quarantined == n_poison
    st = flow.status()
    assert st["failed"] == []
    # both halves of the fault-tolerance story actually fired
    assert st["processors"]["big-rss"]["restarts"] > 0
    assert st["processors"]["enrich"]["retries"] > 0
    log.close()


# ---------------------------------------------------------------------------
# automatic dead-letter re-drive (poison fingerprinting)
# ---------------------------------------------------------------------------
def test_redrive_reingests_quarantined_records_once_fixed(tmp_path):
    log = PartitionedLog(tmp_path / "log")
    g, sink, dlq = _linear_flow(n=100, max_retries=1, dlq_log=log)
    INJECTOR.arm("proc.work",
                 raise_on(lambda ff: ff.attributes.get("poison") == "1"),
                 every=1)
    g.run_to_completion(timeout=60)
    assert len(sink.items) == 90 and dlq.quarantined == 10
    INJECTOR.reset()                              # "the bug is fixed"

    # a fresh graph over the same log: redrive routes each record back to
    # the processor that dead-lettered it (dead.letter.source == "work")
    g2, sink2, dlq2 = _linear_flow(n=0, max_retries=1, dlq_log=log)
    report = dlq2.redrive(g2)
    assert report == {"redriven": 10, "skipped_poison": 0, "unroutable": 0}
    g2.run_to_completion(timeout=60)
    assert sorted(int(f.attributes["i"]) for f in sink2.items) == \
           [i for i in range(100) if i % 10 == 3]
    # redriven records re-enter with a fresh retry budget / audit trail
    assert all("retry.count" not in f.attributes for f in sink2.items)
    assert all("dead.letter.source" not in f.attributes
               for f in sink2.items)
    log.close()


def test_redrive_skips_confirmed_poison_on_second_pass(tmp_path):
    """A record that comes BACK to quarantine after a redrive is poison by
    fingerprint: later redrives skip it instead of re-poisoning the flow."""
    log = PartitionedLog(tmp_path / "log")
    g, sink, dlq = _linear_flow(n=50, max_retries=1, dlq_log=log)
    poison_pred = raise_on(lambda ff: ff.attributes.get("poison") == "1")
    INJECTOR.arm("proc.work", poison_pred, every=1)
    g.run_to_completion(timeout=60)
    assert dlq.quarantined == 5

    # bug NOT fixed: redrive 1 re-ingests, records get re-quarantined
    g2, sink2, dlq2 = _linear_flow(n=0, max_retries=1, dlq_log=log)
    INJECTOR.arm("proc.work", poison_pred, every=1)
    assert dlq2.redrive(g2)["redriven"] == 5
    g2.run_to_completion(timeout=60)
    assert len(sink2.items) == 0 and dlq2.quarantined == 5
    INJECTOR.reset()

    # redrive 2 recognizes the returned fingerprints and leaves them alone
    g3, sink3, dlq3 = _linear_flow(n=0, max_retries=1, dlq_log=log)
    report = dlq3.redrive(g3)
    assert report == {"redriven": 0, "skipped_poison": 5, "unroutable": 0}
    g3.run_to_completion(timeout=60)
    assert len(sink3.items) == 0
    log.close()


def test_redrive_stall_timeout_is_tunable(tmp_path):
    """A redrive into a full connection nobody drains must bail out after
    ``stall_timeout`` (previously a hard-coded 30 s) — and bail WITHOUT
    saving state, so the records stay redrivable."""
    log = PartitionedLog(tmp_path / "log")
    g, sink, dlq = _linear_flow(n=20, max_retries=1, dlq_log=log)
    INJECTOR.arm("proc.work",
                 raise_on(lambda ff: ff.attributes.get("poison") == "1"),
                 every=1)
    g.run_to_completion(timeout=60)
    assert dlq.quarantined == 2
    INJECTOR.reset()

    # the destination's queue holds 1 record and the flow is NOT running
    g2, _, dlq2 = _linear_flow(n=0, max_retries=1, dlq_log=log)
    g2.nodes["work"].input.object_threshold = 1
    t0 = time.monotonic()
    with pytest.raises(RuntimeError, match="stalled"):
        dlq2.redrive(g2, stall_timeout=0.2)
    assert time.monotonic() - t0 < 5.0      # nowhere near the old 30 s
    # frontier untouched: a later redrive (with room) re-offers everything
    g3, sink3, dlq3 = _linear_flow(n=0, max_retries=1, dlq_log=log)
    assert dlq3.redrive(g3)["redriven"] == 2
    g3.run_to_completion(timeout=60)
    assert len(sink3.items) == 2
    log.close()


def test_redrive_explicit_dest_and_unroutable(tmp_path):
    log = PartitionedLog(tmp_path / "log")
    g, sink, dlq = _linear_flow(n=30, max_retries=1, dlq_log=log)
    INJECTOR.arm("proc.work",
                 raise_on(lambda ff: ff.attributes.get("poison") == "1"),
                 every=1)
    g.run_to_completion(timeout=60)
    assert dlq.quarantined == 3
    INJECTOR.reset()

    # a graph that lacks the original "work" processor: explicit dest
    # overrides the per-record dead.letter.source routing
    g2 = FlowGraph("other")
    other = g2.add(ExecuteScript("other", lambda ff: ff))
    osink = g2.add(CollectSink("osink"))
    g2.connect(g2.add(Source("noop", lambda: iter(()))), "success", "other")
    g2.connect(other, "success", osink)
    dlq2 = DeadLetterQueue("dlq", log, topic="dead")
    # a typo'd explicit dest raises up front, leaving the frontier (and
    # therefore redrivability) untouched
    with pytest.raises(ValueError):
        dlq2.redrive(g2, dest="othre")
    assert dlq2.redrive(g2, dest=other)["redriven"] == 3
    g2.run_to_completion(timeout=60)
    assert sorted(int(f.attributes["i"]) for f in osink.items) == [3, 13, 23]

    # a quarantined record whose dead.letter.source is absent from the
    # graph (and no dest given) is unroutable: left in place, not lost
    orphan = make_flowfile("orphan record")
    log.append("dead", *DeadLetterQueue.encode(orphan), partition=0)
    assert dlq2.redrive(g2)["unroutable"] == 1
    assert len(list(DeadLetterQueue.replay(log, "dead"))) == 4
    log.close()
