"""Ingestion fabric: lease-table election logic (pure, injected clock) and
end-to-end multi-process runs — clean completion and kill -9 takeover."""
from __future__ import annotations

import time

import pytest

from repro.core.fabric import FabricError, LeaseTable, resolve_factory
from repro.data.pipeline import (build_news_fabric, expected_fabric_doc_ids,
                                 landed_doc_ids_by_shard)


# -- LeaseTable (no processes, no sleeps) ------------------------------------

def test_lease_initial_assignment_round_robins():
    lt = LeaseTable(lease_timeout_sec=1.0)
    for w in ("w0", "w1"):
        lt.register_worker(w, now=0.0)
    out = lt.assign_initial(["g0", "g1", "g2"])
    assert out == {"g0": "w0", "g1": "w1", "g2": "w0"}
    assert lt.holder("g1") == ("w1", 1)


def test_lease_expiry_uses_injected_clock():
    lt = LeaseTable(lease_timeout_sec=1.0)
    lt.register_worker("w0", now=0.0)
    lt.register_worker("w1", now=0.0)
    lt.heartbeat("w0", now=5.0)
    assert lt.expired_workers(now=5.5) == ["w1"]
    assert lt.expired_workers(now=0.5) == []


def test_lease_takeover_bumps_epoch_and_picks_least_loaded():
    lt = LeaseTable(lease_timeout_sec=1.0)
    for w in ("w0", "w1", "w2"):
        lt.register_worker(w, now=0.0)
    lt.assign_initial(["g0", "g1", "g2", "g3"])   # w0:{g0,g3} w1:{g1} w2:{g2}
    moved = lt.declare_dead("w0")
    assert [(g, e) for g, _w, e in moved] == [("g0", 2), ("g3", 2)]
    # least-loaded first: w1 and w2 hold one group each, so the two orphans
    # split across them instead of piling onto one survivor
    assert sorted(w for _g, w, _e in moved) == ["w1", "w2"]
    assert lt.declare_dead("w0") == []             # idempotent


def test_lease_dead_worker_cannot_heartbeat_or_complete():
    lt = LeaseTable(lease_timeout_sec=1.0)
    lt.register_worker("w0", now=0.0)
    lt.register_worker("w1", now=0.0)
    lt.assign_initial(["g0"])
    lt.declare_dead("w0")
    assert lt.heartbeat("w0", now=9.0) is False    # zombies stay dead
    # a completion report under the stale lease must be rejected
    assert lt.mark_done("g0", "w0", epoch=1) is False
    assert lt.mark_done("g0", "w1", epoch=2) is True
    assert lt.all_done()


def test_lease_last_worker_death_raises():
    lt = LeaseTable(lease_timeout_sec=1.0)
    lt.register_worker("w0", now=0.0)
    lt.assign_initial(["g0"])
    with pytest.raises(FabricError):
        lt.declare_dead("w0")


def test_resolve_factory_validates_path():
    fn = resolve_factory("repro.data.pipeline:build_fabric_news_worker")
    assert callable(fn)
    with pytest.raises(ValueError):
        resolve_factory("repro.data.pipeline")      # no ':function'
    with pytest.raises(ValueError):
        resolve_factory("repro.data.pipeline:nope")


# -- end-to-end (spawned workers + socket log) -------------------------------

@pytest.mark.cluster
@pytest.mark.slow
def test_fabric_clean_run_lands_every_shard_exactly(tmp_path):
    fab = build_news_fabric(tmp_path, workers=2, n_rss=400, n_firehose=400,
                            n_ws=100, partitions=4, group_timeout_sec=120.0)
    fab.start()
    st = fab.wait(timeout=120.0)
    assert not st["reassignments"]
    exp = expected_fabric_doc_ids(list(fab.shards.values()))
    ids, counts = landed_doc_ids_by_shard(fab.store)
    for gid in exp:
        assert exp[gid] - ids.get(gid, set()) == set()
        assert counts[gid] == len(ids[gid])        # clean run: zero dupes
    # events landed on each group's own partition
    ev = fab.store.end_offsets("events")
    assert sum(ev) == 100 and all(n > 0 for n in ev)


@pytest.mark.cluster
@pytest.mark.slow
@pytest.mark.timeout(300)
def test_fabric_kill9_takeover_no_acked_loss(tmp_path):
    fab = build_news_fabric(tmp_path, workers=2, n_rss=8_000,
                            n_firehose=8_000, n_ws=1_000, partitions=4,
                            durable=True, heartbeat_sec=0.1,
                            lease_timeout_sec=1.0, group_timeout_sec=240.0)
    fab.start()
    # kill once real progress exists but well before completion
    deadline = time.monotonic() + 60.0
    while (sum(fab.store.end_offsets("articles")) < 1_000
           and time.monotonic() < deadline):
        time.sleep(0.05)
    assert not fab.leases.all_done()
    fab.kill_worker("w0")
    st = fab.wait(timeout=240.0)
    # lease takeover: the dead worker's group moved, under a higher epoch
    assert st["reassignments"]
    gid, old, new, epoch = st["reassignments"][0]
    assert old == "w0" and new == "w1" and epoch == 2
    # zero acked-record loss: every clean article of every shard landed
    exp = expected_fabric_doc_ids(list(fab.shards.values()))
    ids, counts = landed_doc_ids_by_shard(fab.store)
    for g in exp:
        assert exp[g] - ids.get(g, set()) == set(), f"lost records in {g}"
    # bounded duplicates: in-flight replay, not O(run length)
    dupes = sum(counts[g] - len(ids[g]) for g in exp)
    assert dupes <= 4096 + 64
    # fabric-wide low watermark never went backwards through the takeover
    hist = st["watermark_history"]
    assert all(a <= b for a, b in zip(hist, hist[1:]))
