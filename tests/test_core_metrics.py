"""Metrics correctness (ISSUE 7 bugfix sweep): the windowed rate gauge must
decay after a burst, and ``ComponentStats`` must tolerate concurrent writers
without losing increments or tearing paired gauges.

The time-dependent tests inject ``clock=`` (ISSUE 9) instead of
monkeypatching ``time.monotonic`` module-wide — the old approach broke as
soon as anything else in the module read the real clock."""
import dataclasses
import threading

from repro.core.metrics import ComponentStats, WindowedCounter


# -- WindowedCounter.rate_per_sec decay regression ---------------------------

def test_rate_decays_with_idle_time():
    """Regression: rate_per_sec divided by the occupied-bucket span only, so
    a 1-second burst reported its peak rate for the full 5-minute window.
    The divisor must be elapsed-time-to-now, clamped to the window."""
    fake_now = [1000.0]
    wc = WindowedCounter(window_sec=300.0, bucket_sec=1.0,
                         clock=lambda: fake_now[0])
    wc.add(600)                       # burst: 600 records in one bucket
    fake_now[0] += 0.5
    assert wc.rate_per_sec() == 600.0 / 1.0   # sub-bucket elapse clamps up

    fake_now[0] = 1000.0 + 60.0       # one idle minute later
    rate = wc.rate_per_sec()
    assert rate < 11.0                # ~600/60, NOT the frozen 600/s peak
    assert rate > 0.0

    fake_now[0] = 1000.0 + 299.0      # still inside the window
    assert 0.0 < wc.rate_per_sec() < 2.1      # ~600/299

    fake_now[0] = 1000.0 + 302.0      # evicted: window fully rolled past
    assert wc.rate_per_sec() == 0.0


def test_rate_clamps_to_window():
    """A steady stream's divisor never exceeds window_sec, so the steady
    rate is reported correctly rather than diluted by forgotten history."""
    fake_now = [0.0]
    wc = WindowedCounter(window_sec=10.0, bucket_sec=1.0,
                         clock=lambda: fake_now[0])
    for i in range(40):               # 40s of 5 rec/s; window keeps last 10s
        fake_now[0] = float(i)
        wc.add(5)
    fake_now[0] = 39.5
    assert abs(wc.rate_per_sec() - 5.0) < 1.0


def test_total_evicts_expired_buckets():
    fake_now = [0.0]
    wc = WindowedCounter(window_sec=5.0, bucket_sec=1.0,
                         clock=lambda: fake_now[0])
    wc.add(10)
    fake_now[0] = 3.0
    wc.add(7)
    assert wc.total() == 17
    fake_now[0] = 6.5                 # first bucket now outside the window
    assert wc.total() == 7


# -- ComponentStats thread-safety --------------------------------------------

def test_add_is_atomic_under_contention():
    """`stats.in_records += n` from N threads loses updates (read-modify-
    write is three bytecodes); the locked ``add`` helper must not."""
    stats = ComponentStats("hammer")
    threads = [
        threading.Thread(
            target=lambda: [stats.add(in_records=1, in_bytes=10)
                            for _ in range(2_000)])
        for _ in range(8)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert stats.in_records == 16_000
    assert stats.in_bytes == 160_000


def test_snapshot_is_consistent_with_paired_updates():
    """A paired set (e.g. in_records+in_bytes moved together) must never be
    observed torn: every snapshot sees in_bytes == 10 * in_records."""
    stats = ComponentStats("pairs")
    stop = threading.Event()
    torn = []

    def writer():
        while not stop.is_set():
            stats.add(in_records=1, in_bytes=10)

    def reader():
        while not stop.is_set():
            s = stats.snapshot()
            if s["in_bytes"] != 10 * s["in_records"]:
                torn.append(s)

    ts = [threading.Thread(target=writer) for _ in range(4)]
    ts += [threading.Thread(target=reader) for _ in range(2)]
    for t in ts:
        t.start()
    import time
    time.sleep(0.3)
    stop.set()
    for t in ts:
        t.join()
    assert not torn


def test_snapshot_carries_congestion_and_pool_fields():
    s = ComponentStats("c")
    s.add(shed=3, spilled=5, spill_replayed=5, throttle_engagements=2,
          scale_ups=1, scale_downs=1)
    s.set(workers=4, lag=7, watermark=123.0)
    snap = s.snapshot()
    assert snap["shed"] == 3 and snap["spilled"] == 5
    assert snap["spill_replayed"] == 5 and snap["throttle_engagements"] == 2
    assert snap["workers"] == 4
    assert snap["scale_ups"] == 1 and snap["scale_downs"] == 1
    assert snap["lag"] == 7 and snap["watermark"] == 123.0


def test_snapshot_tracks_dataclass_fields():
    """Regression (ISSUE 9 bugfix): ``snapshot()`` was a hand-maintained
    dict that silently dropped fields added to the dataclass (it missed
    ``shed``/``spilled``/... when they were added). It must now mirror
    ``dataclasses.fields`` exactly, minus the lock."""
    stats = ComponentStats("schema")
    expected = {f.name for f in dataclasses.fields(ComponentStats)
                if f.name != "_lock"}
    assert set(stats.snapshot()) == expected
    assert "_lock" not in stats.snapshot()
