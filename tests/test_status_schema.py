"""Golden-schema tests (ISSUE 9): pin the keys of ``FlowGraph.status()``
and ``IngestionFabric.status()`` so a refactor that silently drops an
observability surface fails loudly, plus the end-to-end telemetry
acceptance — merged per-stage histograms visible mid-run via heartbeats,
the HTTP scrape endpoint, and sampled record traces through provenance.
"""
import time
import urllib.request

import pytest

from repro.core import (ExecuteScript, FlowGraph, PartitionedLog,
                        PublishToLog, Source)


def _tiny_flow(tmp_path, **graph_kw):
    log = PartitionedLog(tmp_path / "log")
    log.create_topic("out", partitions=1)
    g = FlowGraph("schema", **graph_kw)

    def gen():
        from repro.core.flowfile import make_flowfile
        for i in range(40):
            yield make_flowfile(f'{{"i": {i}}}', seq=str(i))

    src = g.add(Source("src", gen))
    echo = g.add(ExecuteScript("echo", lambda ff: ff))
    sink = g.add(PublishToLog("sink", log, "out"))
    g.connect(src, "success", echo)
    g.connect(echo, "success", sink)
    return g, log


# -- FlowGraph.status() golden schema ----------------------------------------

FLOW_STATUS_KEYS = {"processors", "connections", "provenance_counts",
                    "failed", "telemetry"}

PROCESSOR_KEYS = {"name", "in_records", "in_bytes", "out_records",
                  "out_bytes", "dropped", "retries", "dead_lettered",
                  "restarts", "state", "pending_retries"}

TELEMETRY_SUMMARY_KEYS = {"count", "mean_ms", "p50_ms", "p90_ms", "p99_ms"}


def test_flow_status_schema(tmp_path):
    g, log = _tiny_flow(tmp_path)
    g.run_to_completion(timeout=60)
    st = g.status()
    assert set(st) == FLOW_STATUS_KEYS
    for snap in st["processors"].values():
        assert PROCESSOR_KEYS <= set(snap)
    # per-stage histograms: process time per processor, queue dwell per
    # (processor, relationship) edge, ingest→land at the terminal sink
    tel = st["telemetry"]
    assert tel['process_seconds{processor="echo"}']["count"] == 40
    assert tel['queue_dwell_seconds{processor="echo",'
               'relationship="success"}']["count"] == 40
    e2e = tel['ingest_to_land_seconds{processor="sink"}']
    assert set(e2e) == TELEMETRY_SUMMARY_KEYS
    assert e2e["count"] == 40
    assert e2e["p50_ms"] <= e2e["p99_ms"]
    log.close()


def test_flow_status_telemetry_off(tmp_path):
    g, log = _tiny_flow(tmp_path, telemetry=False)
    g.run_to_completion(timeout=60)
    st = g.status()
    assert set(st) == FLOW_STATUS_KEYS      # same schema, empty body
    assert st["telemetry"] == {}
    log.close()


# -- sampled traces through provenance ---------------------------------------

def test_trace_sampling_spans(tmp_path):
    g, log = _tiny_flow(tmp_path, trace_sample_rate=1.0)
    # sources are admission points: every record gets a trace.id at rate 1
    g.run_to_completion(timeout=60)
    span_events = [e for e in g.provenance.events()
                   if e.details.startswith("span ")]
    assert span_events, "no span events recorded at rate 1.0"
    trace_id = span_events[0].lineage_id
    spans = g.trace_spans(trace_id)
    assert spans, "trace_spans found nothing for a traced record"
    for s in spans:
        assert s["elapsed_us"] >= 0
        assert s["batch"] >= 1
        assert s["component"]
    assert [s["ts"] for s in spans] == sorted(s["ts"] for s in spans)
    log.close()


def test_trace_sampling_off_by_default(tmp_path):
    g, log = _tiny_flow(tmp_path)
    g.run_to_completion(timeout=60)
    assert g._trace_every == 0
    log.close()


def test_bad_trace_rate_rejected():
    with pytest.raises(ValueError):
        FlowGraph("bad", trace_sample_rate=1.5)


# -- IngestionFabric.status() golden schema + live telemetry ------------------

FABRIC_STATUS_KEYS = {"leases", "reassignments", "low_watermark",
                      "watermark_history", "group_errors", "transport",
                      "telemetry"}


def test_fabric_status_schema_and_live_telemetry(tmp_path):
    from repro.data.pipeline import build_news_fabric
    fab = build_news_fabric(tmp_path, workers=2, n_rss=1_500,
                            n_firehose=1_500, n_ws=300)
    fab.start()
    srv = fab.serve_metrics()
    try:
        assert set(fab.status()) == FABRIC_STATUS_KEYS
        # heartbeat-shipped per-stage histograms must become visible
        # MID-RUN (before wait() returns)
        deadline = time.monotonic() + 60.0
        live = {}
        while time.monotonic() < deadline and not fab.leases.all_done():
            tel = fab.status()["telemetry"]
            live = {k: v for k, v in tel.items()
                    if k.startswith("process_seconds") and v["count"] > 0}
            if live:
                break
            time.sleep(0.05)
        assert live, "no mid-run telemetry arrived over heartbeats"
        body = urllib.request.urlopen(srv.url, timeout=10).read().decode()
        assert "repro_" in body
        st = fab.wait(timeout=120.0)
    finally:
        fab.shutdown(force=True)
        fab.store.close()
    tel = st["telemetry"]
    # final state is exact: shipped with each group_done, not a lagging beat
    e2e = [v for k, v in tel.items()
           if k.startswith("ingest_to_land_seconds")]
    assert sum(v["count"] for v in e2e) > 0
    rpc = [k for k in tel if k.startswith("rpc_seconds")]
    assert rpc, "worker RemoteLogStore RPC histograms missing"
