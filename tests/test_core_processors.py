"""Processor semantics: dedup, route, enrich, merge, filter, sinks."""
import json

from repro.core import (BloomFilter, CollectSink, ContentFilter,
                        DetectDuplicate, ExecuteScript, FileSink,
                        LookupEnrich, MergeContent, PartitionRecords,
                        RouteOnAttribute, make_flowfile)
from repro.core.processor import REL_DROP, REL_FAILURE, REL_SUCCESS


def run(proc, items):
    out = list(proc.on_trigger(list(items)))
    out.extend(proc.final_flush())
    return out


def test_detect_duplicate_exact():
    d = DetectDuplicate(mode="exact")
    items = [make_flowfile(b"a"), make_flowfile(b"b"), make_flowfile(b"a")]
    rels = [rel for rel, _ in run(d, items)]
    assert rels == ["unique", "unique", "duplicate"]


def test_detect_duplicate_bloom_no_false_negatives():
    d = DetectDuplicate(mode="bloom", expected_items=10_000)
    first = [make_flowfile(f"m{i}".encode()) for i in range(1000)]
    repeat = [make_flowfile(f"m{i}".encode()) for i in range(1000)]
    out1 = run(d, first)
    out2 = run(d, repeat)
    # every true duplicate is caught (no false negatives by construction)
    assert all(rel == "duplicate" for rel, _ in out2)
    # false-positive rate on uniques is small
    fp = sum(1 for rel, _ in out1 if rel == "duplicate")
    assert fp < 20


def test_bloom_filter_properties():
    b = BloomFilter(expected_items=1000, fp_rate=1e-3)
    keys = [f"k{i}".encode() for i in range(500)]
    for k in keys:
        b.add(k)
    assert all(k in b for k in keys)


def test_route_on_attribute():
    r = RouteOnAttribute("route", {
        "finance": lambda ff: ff.attributes.get("keyword") == "finance",
        "sports": lambda ff: ff.attributes.get("keyword") == "sports",
    })
    outs = run(r, [make_flowfile(b"1", keyword="finance"),
                   make_flowfile(b"2", keyword="sports"),
                   make_flowfile(b"3", keyword="other")])
    assert [rel for rel, _ in outs] == ["finance", "sports", "unmatched"]


def test_execute_script_drop_and_failure():
    def fn(ff):
        if ff.content == b"bad":
            raise ValueError("malformed")
        if ff.content == b"noise":
            return None
        return ff.with_attributes(clean="1")
    p = ExecuteScript("script", fn)
    outs = run(p, [make_flowfile(b"ok"), make_flowfile(b"noise"),
                   make_flowfile(b"bad")])
    assert [rel for rel, _ in outs] == [REL_SUCCESS, REL_DROP, REL_FAILURE]
    assert outs[2][1].attributes["error"] == "ValueError"


def test_content_filter_language():
    p = ContentFilter("lang", lambda ff: ff.attributes.get("lang") == "en")
    outs = run(p, [make_flowfile(b"x", lang="en"), make_flowfile(b"y", lang="de")])
    assert [rel for rel, _ in outs] == [REL_SUCCESS, REL_DROP]


def test_lookup_enrich():
    table = {"reuters": {"region": "uk", "tier": "1"}}
    p = LookupEnrich("enrich", table,
                     key_fn=lambda ff: ff.attributes.get("origin", ""))
    outs = run(p, [make_flowfile(b"a", origin="reuters"),
                   make_flowfile(b"b", origin="unknown")])
    assert outs[0][1].attributes["region"] == "uk"
    assert "region" not in outs[1][1].attributes      # pass-through on miss


def test_merge_content_bundles():
    m = MergeContent(max_records=3, max_latency_sec=10)
    outs = run(m, [make_flowfile(f"r{i}".encode()) for i in range(7)])
    assert [rel for rel, _ in outs] == [REL_SUCCESS] * 3
    assert outs[0][1].content == b"r0\nr1\nr2"
    assert outs[2][1].content == b"r6"                # final flush remainder
    assert outs[0][1].attributes["merge.count"] == "3"


def test_partition_records_stamps_key():
    p = PartitionRecords("pr", key_fn=lambda ff: ff.attributes["origin"])
    outs = run(p, [make_flowfile(b"x", origin="ap")])
    assert outs[0][1].attributes["partition.key"] == "ap"


def test_file_sink_writes_uuid_files(tmp_path):
    s = FileSink("hdfs", tmp_path / "landing")
    items = [make_flowfile(f"doc{i}".encode()) for i in range(4)]
    run(s, items)
    files = list((tmp_path / "landing").iterdir())
    assert len(files) == 4
    assert sorted(f.read_bytes() for f in files) == [b"doc0", b"doc1", b"doc2", b"doc3"]


def test_collect_sink():
    s = CollectSink()
    run(s, [make_flowfile(b"z")])
    assert s.items[0].content == b"z"
