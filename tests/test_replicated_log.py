"""ReplicatedLog: LogStore contract, leader failover with epoch fencing,
durability levels, replica loss/restore, and the delivery layers running
unchanged over the replicated store."""
import shutil
import threading

import pytest

from repro.core import (ConsumerGroup, LogStore, PartitionedLog, Producer,
                        ReplicatedLog, ReplicationError)
from repro.core.connection import DurableConnection
from repro.core.faults import INJECTOR, InjectedFault
from repro.core.flowfile import make_flowfile


def _fill(log, topic="t", n=100, partition=0):
    log.create_topic(topic, partitions=max(1, partition + 1))
    log.append_batch(topic, [(f"k{i}".encode(), f"v{i}".encode())
                             for i in range(n)], partition=partition)


def _values(log, topic="t", partition=0):
    return [r.value for r in log.iter_records(topic, partition)]


# ---------------------------------------------------------------------------
# contract / degeneration
# ---------------------------------------------------------------------------
def test_both_stores_implement_logstore(tmp_path):
    assert issubclass(PartitionedLog, LogStore)
    assert issubclass(ReplicatedLog, LogStore)


def test_single_replica_matches_partitioned_log_bytes(tmp_path):
    """replicas=1 must degenerate to the exact PartitionedLog hot path —
    byte-identical segment files for the same appends."""
    plain = PartitionedLog(tmp_path / "plain")
    repl = ReplicatedLog(tmp_path / "repl", replicas=1)
    recs = [(f"key-{i}".encode(), f"val-{i}".encode() * (i % 3 + 1))
            for i in range(200)]
    for log in (plain, repl):
        log.create_topic("t", partitions=4)
        assert log.append_batch("t", recs) is not None
        log.flush()
    for p in range(4):
        a = b"".join(f.read_bytes() for f in sorted(
            (tmp_path / "plain" / "t" / str(p)).glob("*.seg")))
        b = b"".join(f.read_bytes() for f in sorted(
            (tmp_path / "repl" / "replica-0" / "t" / str(p)).glob("*.seg")))
        assert a == b
    plain.close()
    repl.close()


def test_acks_all_ships_every_append_to_every_replica(tmp_path):
    log = ReplicatedLog(tmp_path, replicas=3, acks="all")
    _fill(log, n=50)
    for d in log.describe("t"):
        assert d["ends"] == [50] * 3 if d["partition"] == 0 else True
    log.close()
    # each replica directory is independently a complete PartitionedLog
    for i in range(3):
        store = PartitionedLog(tmp_path / f"replica-{i}")
        assert [r.value for r in store.iter_records("t", 0)] == \
            [f"v{i}".encode() for i in range(50)]
        store.close()


def test_key_routing_matches_partitioned_log(tmp_path):
    plain = PartitionedLog(tmp_path / "plain")
    repl = ReplicatedLog(tmp_path / "repl", replicas=2)
    recs = [(f"key-{i}".encode(), f"val-{i}".encode()) for i in range(80)]
    for log in (plain, repl):
        log.create_topic("t", partitions=4)
    assert plain.append_batch("t", recs) == repl.append_batch("t", recs)
    plain.close()
    repl.close()


def test_acks_leader_lazy_shipping_catches_up_on_flush(tmp_path):
    log = ReplicatedLog(tmp_path, replicas=2, acks="leader",
                        ship_batch_records=64)
    log.create_topic("t", partitions=1)
    log.append_batch("t", [(b"", f"v{i}".encode()) for i in range(10)],
                     partition=0)
    d = log.describe("t")[0]
    assert d["ends"][d["leader"]] == 10
    follower_end = d["ends"][1 - d["leader"]]
    assert follower_end < 10            # lazily trailing
    log.flush_topic("t")
    assert {e for e in log.describe("t")[0]["ends"]} == {10}
    log.close()


def test_invalid_config_rejected(tmp_path):
    with pytest.raises(ValueError):
        ReplicatedLog(tmp_path, replicas=0)
    with pytest.raises(ValueError):
        ReplicatedLog(tmp_path, replicas=2, acks="quorum")
    with pytest.raises(ValueError):
        ReplicatedLog(tmp_path, replicas=2, fsync_every=[1])


# ---------------------------------------------------------------------------
# failover
# ---------------------------------------------------------------------------
def test_leader_failover_mid_ingest_zero_record_loss(tmp_path):
    """Acceptance: kill the leader mid-ingest via the FaultInjector; a
    follower is promoted with an epoch bump and a consumer group replays
    every record (duplicates allowed, loss is not)."""
    log = ReplicatedLog(tmp_path, replicas=3, acks="all")
    log.create_topic("t", partitions=1)
    leader0 = log.leader("t", 0)
    assert log.epoch("t", 0) == 0
    # the 4th leader-store append dies (simulated disk death of the leader)
    INJECTOR.arm("replica.leader", "raise", nth=4)
    with Producer(log, "t", max_batch_records=16) as prod:
        for i in range(200):
            prod.send(b"", f"v{i}".encode(), partition=0)
    assert INJECTOR.fired("replica.leader") == 1
    assert log.leader("t", 0) != leader0
    assert log.epoch("t", 0) >= 1
    assert leader0 not in log.describe("t")[0]["in_sync"]
    # consumer-side replay: zero loss (exact count — the failed append never
    # assigned offsets, so the retry produces no duplicates here)
    group = ConsumerGroup(log, "t", "g")
    consumer = group.add_member("m0")
    seen = []
    while True:
        recs = consumer.poll(64)
        if not recs:
            break
        seen.extend(r.value for r in recs)
    assert set(seen) >= {f"v{i}".encode() for i in range(200)}
    log.close()


def test_concurrent_kill_during_ingest_loses_nothing(tmp_path):
    """A racing failure detector (kill_replica from another thread) fences
    in-flight writers; every acked record survives on the promoted side."""
    log = ReplicatedLog(tmp_path, replicas=2, acks="all")
    log.create_topic("t", partitions=1)
    leader0 = log.leader("t", 0)
    acked = []
    stop = threading.Event()

    def ingest():
        i = 0
        while not stop.is_set() and i < 3000:
            log.append("t", b"", f"v{i}".encode(), partition=0)
            acked.append(i)
            i += 1

    t = threading.Thread(target=ingest)
    t.start()
    while len(acked) < 50:      # let the writer get going
        pass
    log.kill_replica(leader0)
    stop.set()
    t.join()
    values = set(_values(log))
    assert values >= {f"v{i}".encode() for i in acked}
    assert log.leader("t", 0) != leader0
    log.close()


def test_follower_ship_failure_shrinks_isr_but_append_succeeds(tmp_path):
    log = ReplicatedLog(tmp_path, replicas=3, acks="all")
    log.create_topic("t", partitions=1)
    epoch0 = log.epoch("t", 0)
    INJECTOR.arm("replica.ship", "raise", nth=1)
    log.append_batch("t", [(b"", b"v0")], partition=0)
    d = log.describe("t")[0]
    assert len(d["in_sync"]) == 2                 # one follower ejected
    assert d["leader"] == log.leader("t", 0)
    assert log.epoch("t", 0) == epoch0            # leadership unchanged
    assert _values(log) == [b"v0"]
    log.close()


def test_all_replicas_dead_raises(tmp_path):
    log = ReplicatedLog(tmp_path, replicas=2)
    log.create_topic("t", partitions=1)
    log.kill_replica(0)
    with pytest.raises(ReplicationError):
        log.kill_replica(1)                       # cannot kill the last one
    # killing the only live replica via injected leader faults exhausts the set
    INJECTOR.arm("replica.leader", "raise", every=1)
    with pytest.raises(ReplicationError):
        log.append("t", b"", b"v", partition=0)
    INJECTOR.reset()
    log.close()


def test_restore_replica_full_resync_and_rejoin(tmp_path):
    log = ReplicatedLog(tmp_path, replicas=2, acks="all")
    _fill(log, n=30)
    log.kill_replica(0)
    log.append_batch("t", [(b"", f"post{i}".encode()) for i in range(10)],
                     partition=0)
    log.restore_replica(0)
    d = log.describe("t")[0]
    assert d["in_sync"] == [0, 1] and d["ends"] == [40, 40]
    # restored replica follows; it does not reclaim leadership (no fail-back)
    assert d["leader"] == 1
    log.append("t", b"", b"after-restore", partition=0)
    assert log.describe("t")[0]["ends"] == [41, 41]
    log.close()


# ---------------------------------------------------------------------------
# durability / reopen
# ---------------------------------------------------------------------------
def test_acks_all_survives_leader_dir_deletion(tmp_path):
    """Acceptance: with acks=all, rm -rf of the leader's data directory
    loses nothing — reopen reconciles from the surviving replicas and a
    consumer group replays every record."""
    log = ReplicatedLog(tmp_path, replicas=3, acks="all")
    log.create_topic("t", partitions=2)
    expect = {f"v{i}".encode() for i in range(300)}
    with Producer(log, "t") as prod:
        for i in range(300):
            prod.send(f"k{i}".encode(), f"v{i}".encode())
    leader0 = log.leader("t", 0)
    log.close()

    shutil.rmtree(tmp_path / f"replica-{leader0}")
    log2 = ReplicatedLog(tmp_path, replicas=3, acks="all")
    group = ConsumerGroup(log2, "t", "g")
    consumer = group.add_member("m0")
    seen = set()
    while True:
        recs = consumer.poll(128)
        if not recs:
            break
        seen.update(r.value for r in recs)
    assert seen == expect
    # the wiped replica was resynced back to a full copy
    for d in log2.describe("t"):
        ends = d["ends"]
        assert len(set(ends)) == 1 and ends[0] > 0
    log2.close()


def test_reopen_after_clean_close_is_reconciled_noop(tmp_path):
    log = ReplicatedLog(tmp_path, replicas=2, acks="leader")
    _fill(log, n=40)
    log.close()                                   # ships the lazy lag fully
    log2 = ReplicatedLog(tmp_path, replicas=2, acks="leader")
    assert _values(log2) == [f"v{i}".encode() for i in range(40)]
    assert log2.describe("t")[0]["ends"] == [40, 40]
    log2.close()


def test_retention_applies_across_replicas_and_ship_respects_begin(tmp_path):
    log = ReplicatedLog(tmp_path, replicas=2, acks="all", segment_bytes=256)
    log.create_topic("t", partitions=1)
    log.append_batch("t", [(b"", b"x" * 40) for _ in range(100)], partition=0)
    dropped = log.enforce_retention("t", retention_bytes=1024)
    assert dropped > 0
    begin = log.begin_offset("t", 0)
    assert begin > 0
    recs = log.read("t", 0, begin, max_records=10)
    assert recs and recs[0].offset == begin
    # appends after retention keep both replicas aligned
    log.append("t", b"", b"tail", partition=0)
    assert log.describe("t")[0]["ends"][0] == log.describe("t")[0]["ends"][1]
    log.close()


# ---------------------------------------------------------------------------
# the layers above run unchanged over the replicated store
# ---------------------------------------------------------------------------
def test_durable_connection_wal_over_replicated_log(tmp_path):
    log = ReplicatedLog(tmp_path, replicas=2, acks="all")
    conn = DurableConnection("c", log)
    ffs = [make_flowfile(f"rec-{i}", idx=str(i)) for i in range(20)]
    assert conn.offer_batch(ffs) == 20
    for _ in range(5):
        conn.poll(block=False)
    conn.ack(5)
    leader0 = log.leader(conn.topic, 0)
    log.close()
    # the WAL survives losing the journal leader's directory
    shutil.rmtree(tmp_path / f"replica-{leader0}")
    log2 = ReplicatedLog(tmp_path, replicas=2, acks="all")
    conn2 = DurableConnection("c", log2)
    assert conn2.acked == 5
    assert conn2.replayed == 15
    replayed = [conn2.poll(block=False).attributes["idx"] for _ in range(15)]
    assert replayed == [str(i) for i in range(5, 20)]
    log2.close()


def test_consumer_group_failover_mid_consumption(tmp_path):
    log = ReplicatedLog(tmp_path, replicas=2, acks="all")
    log.create_topic("t", partitions=2)
    log.append_batch("t", [(f"k{i}".encode(), f"v{i}".encode())
                           for i in range(100)])
    group = ConsumerGroup(log, "t", "g")
    consumer = group.add_member("m0")
    seen = {r.value for r in consumer.poll(30)}
    log.kill_replica(log.leader("t", 0))          # reads fail over too
    while True:
        recs = consumer.poll(64)
        if not recs:
            break
        seen.update(r.value for r in recs)
    assert seen == {f"v{i}".encode() for i in range(100)}
    log.close()


# ---------------------------------------------------------------------------
# review-hardening regressions
# ---------------------------------------------------------------------------
def test_reopen_prefers_last_recorded_leader_over_zombie(tmp_path):
    """Equal-length divergence after a fenced failover: at reopen the
    persisted (leader, epoch) metadata — not log length or preference
    order — decides authority, so an acked record on the promoted leader
    beats a zombie's divergent record at the same offset."""
    log = ReplicatedLog(tmp_path, replicas=2, acks="all")
    log.create_topic("t", partitions=1)
    log.append_batch("t", [(b"", f"v{i}".encode()) for i in range(10)],
                     partition=0)
    log.kill_replica(0)                    # replica 0 led partition 0
    log.append("t", b"", b"acked-on-1", partition=0)   # only replica 1 has it
    log.flush(fsync=False)
    # hard crash: no close() — the clean marker stays False. The dead
    # zombie's disk then gains a divergent record at the SAME offset 10.
    zombie = PartitionedLog(tmp_path / "replica-0")
    zombie.append("t", b"", b"zombie-write", partition=0)
    zombie.flush(fsync=False)
    zombie.close()

    log2 = ReplicatedLog(tmp_path, replicas=2, acks="all")
    assert log2.leader("t", 0) == 1        # metadata, not preference order
    recs = log2.read("t", 0, 10, max_records=1)
    assert recs[0].value == b"acked-on-1"
    # the zombie was rebuilt as a verbatim copy of the authority
    assert log2.describe("t")[0]["ends"] == [11, 11]
    log2.close()
    z2 = PartitionedLog(tmp_path / "replica-0")
    assert [r.value for r in z2.iter_records("t", 0)][-1] == b"acked-on-1"
    z2.close()


def test_caller_type_error_does_not_demote_replicas(tmp_path):
    """A producer bug (non-bytes key/value) must surface to the caller,
    not eat the in-sync set one healthy replica at a time."""
    log = ReplicatedLog(tmp_path, replicas=2, acks="all")
    log.create_topic("t", partitions=1)
    with pytest.raises(TypeError):
        log.append("t", "not-bytes", b"v", partition=0)
    with pytest.raises(KeyError):
        log.append("no-such-topic", b"k", b"v")
    with pytest.raises(TypeError):
        log.read("t", 0, None)                        # read path too
    with pytest.raises(KeyError):
        log.end_offset("no-such-topic", 0)
    assert log.describe("t")[0]["in_sync"] == [0, 1]
    log.append("t", b"k", b"v", partition=0)          # still fully healthy
    assert log.describe("t")[0]["ends"] == [1, 1]
    log.close()


def test_leader_killed_between_append_and_ship_fails_over(tmp_path):
    """A racing kill_replica landing after the leader write but before
    replication must fail over (and re-append), not leak the store's
    KeyError/ValueError to the producer."""
    log = ReplicatedLog(tmp_path, replicas=2, acks="all")
    log.create_topic("t", partitions=1)
    leader0 = log.leader("t", 0)

    INJECTOR.arm("replica.ship",
                 lambda ctx: log.kill_replica(leader0), nth=1)
    log.append_batch("t", [(b"", f"v{i}".encode()) for i in range(5)],
                     partition=0)
    assert log.leader("t", 0) != leader0
    values = [r.value for r in log.iter_records("t", 0)]
    assert set(values) >= {f"v{i}".encode() for i in range(5)}  # zero loss
    log.close()
