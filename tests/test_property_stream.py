"""Hypothesis property tests on the ingestion fabric's invariants."""
import json

import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import (Connection, DetectDuplicate, OffsetStore,
                        PartitionedLog, make_flowfile, range_assign)

_SETTINGS = dict(deadline=None, max_examples=40,
                 suppress_health_check=[HealthCheck.function_scoped_fixture])


@given(records=st.lists(st.binary(min_size=0, max_size=200), max_size=200),
       partitions=st.integers(min_value=1, max_value=8))
@settings(**_SETTINGS)
def test_log_read_after_write_exact(tmp_path_factory, records, partitions):
    """Everything appended is read back, in order, byte-identical."""
    root = tmp_path_factory.mktemp("log")
    log = PartitionedLog(root, segment_bytes=512)
    log.create_topic("t", partitions=partitions)
    placed: dict[int, list[bytes]] = {p: [] for p in range(partitions)}
    for i, v in enumerate(records):
        p = i % partitions
        log.append("t", f"{i}".encode(), v, partition=p)
        placed[p].append(v)
    for p in range(partitions):
        got = [r.value for r in log.read("t", p, 0, max_records=len(records) + 1)]
        assert got == placed[p]
    log.close()


@given(keys=st.lists(st.text(max_size=20), min_size=1, max_size=300))
@settings(**_SETTINGS)
def test_dedup_exact_set_semantics(keys):
    """Exact dedup: 'unique' outputs == set of inputs; every repeat flagged."""
    d = DetectDuplicate(mode="exact", key_fn=lambda ff: ff.content)
    uniques, dups = [], []
    for k in keys:
        for rel, ff in d.process(make_flowfile(k)):
            (uniques if rel == "unique" else dups).append(ff.content)
    assert sorted(set(uniques)) == sorted(set(k.encode() for k in keys))
    assert len(uniques) + len(dups) == len(keys)
    assert len(uniques) == len(set(k.encode() for k in keys))


@given(ops=st.lists(st.tuples(st.booleans(), st.integers(1, 50)), max_size=200),
       threshold=st.integers(min_value=1, max_value=20))
@settings(**_SETTINGS)
def test_backpressure_invariant_never_exceeds_threshold(ops, threshold):
    """Queue depth never exceeds the object threshold; accepted == drained +
    still queued (no loss, no duplication)."""
    c = Connection("c", object_threshold=threshold)
    accepted = drained = 0
    for is_offer, size in ops:
        if is_offer:
            if c.offer(make_flowfile(b"x" * size), block=False):
                accepted += 1
        else:
            if c.poll(block=False) is not None:
                drained += 1
        assert len(c) <= threshold
    assert accepted == drained + len(c)


@given(partitions=st.integers(0, 64),
       members=st.lists(st.text(min_size=1, max_size=5), min_size=1,
                        max_size=10, unique=True))
@settings(**_SETTINGS)
def test_range_assign_partition_exactly_once(partitions, members):
    a = range_assign(partitions, members)
    got = sorted(p for ps in a.values() for p in ps)
    assert got == list(range(partitions))
    sizes = [len(v) for v in a.values()]
    assert max(sizes) - min(sizes) <= 1          # balanced


@given(commits=st.lists(st.tuples(st.integers(0, 3), st.integers(0, 10_000)),
                        max_size=50))
@settings(**_SETTINGS)
def test_offset_store_last_write_wins(tmp_path_factory, commits):
    root = tmp_path_factory.mktemp("off")
    s = OffsetStore(root / "o.json")
    last: dict[int, int] = {}
    for p, off in commits:
        s.commit("g", "t", {p: off})
        last[p] = off
    s2 = OffsetStore(root / "o.json")            # reload from disk
    for p, off in last.items():
        assert s2.get("g", "t", p) == off
