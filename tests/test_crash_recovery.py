"""Crash-recovery property tests: a writer process is hard-killed (via
``FaultInjector``) mid-``append_batch`` — including across a segment roll —
and the reopened log must recover to a clean prefix with no torn records.
A flow over a WAL-backed ``DurableConnection`` killed mid-run must resume
from its last acked frontier with at-least-once delivery.

Subprocess-based (a real ``os._exit``, no interpreter cleanup) — marked
``slow``; deselect with ``-m 'not slow'``.
"""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.core import DurableConnection, PartitionedLog
from repro.core.log import _HEADER

pytestmark = pytest.mark.slow

ROOT = Path(__file__).resolve().parent.parent

N_RECORDS = 400
BATCH = 50


def run_sub(code: str, timeout=120) -> subprocess.CompletedProcess:
    return subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=timeout,
        env={"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/tmp"})


def _writer_code(root: Path, *, nth: int, segment_bytes: int) -> str:
    """A child that appends batches, armed to die with a half-written
    record on the ``nth`` contiguous chunk write."""
    return textwrap.dedent(f"""
    import os
    from repro.core import PartitionedLog
    from repro.core.faults import INJECTOR

    def torn_write_then_die(ctx):
        seg, buf = ctx["segment"], ctx["buf"]
        seg._fh.write(buf[: max(1, len(buf) // 2)])   # half a chunk
        seg._fh.flush()                               # make the tear visible
        os._exit(23)

    INJECTOR.arm("log.segment.append_batch", torn_write_then_die, nth={nth})
    log = PartitionedLog(r"{root}", segment_bytes={segment_bytes})
    log.create_topic("t", partitions=1)
    recs = [(str(i).encode(), ("value-%05d" % i).encode() * 3)
            for i in range({N_RECORDS})]
    for start in range(0, {N_RECORDS}, {BATCH}):
        log.append_batch("t", recs[start:start + {BATCH}], partition=0)
        log.flush_topic("t", fsync=False)
    os._exit(0)                                       # fault did not fire
    """)


def _assert_clean_prefix(root: Path, segment_bytes: int) -> int:
    """Reopen and require: offsets form a contiguous prefix whose contents
    byte-match the writer's deterministic records; appends continue."""
    log = PartitionedLog(root, segment_bytes=segment_bytes)
    end = log.end_offset("t", 0)
    assert 0 < end < N_RECORDS                 # crashed mid-stream
    recs = list(log.iter_records("t", 0, batch_records=64))
    assert [r.offset for r in recs] == list(range(end))
    for r in recs:                             # prefix, bit-exact
        i = int(r.key.decode())
        assert i == r.offset
        assert r.value == ("value-%05d" % i).encode() * 3
    _, cont = log.append("t", b"resumed", b"after-crash", partition=0)
    assert cont == end
    log.close()
    return end


def test_writer_killed_mid_append_batch_recovers_to_prefix(tmp_path):
    res = run_sub(_writer_code(tmp_path, nth=3, segment_bytes=1 << 20))
    assert res.returncode == 23, res.stderr
    end = _assert_clean_prefix(tmp_path, 1 << 20)
    # two whole batches landed; the half-written third chunk recovers its
    # leading whole records and truncates the one torn mid-record
    assert 2 * BATCH <= end < 3 * BATCH


def test_writer_killed_on_chunk_after_segment_roll(tmp_path):
    """Small segments force one append_batch to span a roll; the kill lands
    on a chunk write in a freshly rolled segment, so the torn bytes sit at
    the very start of the tail segment."""
    segment_bytes = 1024                       # ~25 records per segment
    res = run_sub(_writer_code(tmp_path, nth=2, segment_bytes=segment_bytes))
    assert res.returncode == 23, res.stderr
    segs = sorted((tmp_path / "t" / "0").glob("*.seg"))
    assert len(segs) > 1                       # the batch really rolled
    end = _assert_clean_prefix(tmp_path, segment_bytes)
    # the tear landed in the freshly rolled tail segment: everything in the
    # sealed segments survived, and the tail recovered to a record boundary
    assert end >= int(segs[-1].stem)


def test_durable_flow_killed_mid_run_resumes_from_acked_frontier(tmp_path):
    """A graph publishing through a DurableConnection is hard-killed by the
    injector mid-run; rebuilding the same topology over the same log replays
    the un-acked suffix and every source record lands (duplicates allowed)."""
    n = 300
    code = textwrap.dedent(f"""
    from repro.core import (FlowGraph, PartitionedLog, PublishToLog, Source,
                            make_flowfile)
    from repro.core.faults import INJECTOR

    log = PartitionedLog(r"{tmp_path}" + "/log")
    log.create_topic("articles", partitions=2)
    g = FlowGraph("durable")
    src = g.add(Source("s", lambda: (
        make_flowfile("payload-%d" % i, i=str(i)) for i in range({n}))))
    pub = g.add(PublishToLog("pub", log, "articles", flush_every=1))
    src.batch_size = 16        # many small triggers -> kill lands mid-stream
    pub.batch_size = 16
    INJECTOR.arm("proc.pub", "crash", nth=6, exit_code=29)
    g.connect(src, "success", pub, durable=log)
    g.run_to_completion(timeout=60)
    """)
    res = run_sub(code)
    assert res.returncode == 29, res.stderr

    log = PartitionedLog(tmp_path / "log")
    before = sum(log.end_offsets("articles"))
    assert 0 < before < n                      # died with records in flight

    # rebuild the same topology (same names => same WAL topic). The WAL's
    # end offset is the durable count of records the source got accepted
    # before the kill: the replayable source resumes from there, and the
    # un-acked suffix below it is replayed from the journal.
    from repro.core import FlowGraph, PublishToLog, Source, make_flowfile
    wal_end = log.end_offset("__wal__.s:success->pub", 0)
    assert 0 < wal_end <= n
    g = FlowGraph("durable")
    src = g.add(Source("s", lambda: (
        make_flowfile("payload-%d" % i, i=str(i)) for i in range(wal_end, n))))
    pub = g.add(PublishToLog("pub", log, "articles", flush_every=1))
    conn = g.connect(src, "success", pub, durable=log)
    assert conn.replayed > 0                   # polled-but-unacked came back
    g.run_to_completion(timeout=60)

    landed = {json.loads(r.key)["attributes"]["i"]
              for r in log.iter_records("articles")}
    assert landed == {str(i) for i in range(n)}   # zero loss, dups allowed
    log.close()
