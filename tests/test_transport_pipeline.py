"""Pipelined-transport tests (ISSUE 8 tentpole): in-flight windows over one
socket, ordered replay of unacked frames across connection drops, the
idempotent-producer contract under a partially-acked pipeline, client-side
append coalescing, and the read-ahead / advertised-end caches."""
from __future__ import annotations

import threading
import time

import pytest

from repro.core import PartitionedLog
from repro.core.delivery import Producer
from repro.core.faults import INJECTOR
from repro.core.logstore import LogStore
from repro.core.transport import LogServer, RemoteLogStore

#: fast concurrency-layer module: CI re-runs it under the
#: REPRO_LOCK_ORDER=1 lock-order detector (scripts/ci.sh)
pytestmark = pytest.mark.lockorder


@pytest.fixture()
def remote(tmp_path):
    store = PartitionedLog(tmp_path / "server")
    server = LogServer(store).start()
    client = RemoteLogStore(server.address, tmp_path / "client",
                            retry_backoff_sec=0.01)
    yield client, store, server
    client.close()
    server.stop()
    store.close()


# -- pipelining --------------------------------------------------------------

def test_pipelined_concurrent_calls_share_one_socket(remote, tmp_path):
    client, _, _ = remote
    threads_n, per = 6, 25
    client.create_topic("t", partitions=threads_n)
    errs: list[Exception] = []

    def work(p: int) -> None:
        try:
            for i in range(per):
                off = client.append("t", b"k", f"{p}:{i}".encode(),
                                    partition=p)[1]
                assert off == i          # per-partition offsets stay dense
        except Exception as e:   # noqa: BLE001
            errs.append(e)

    ts = [threading.Thread(target=work, args=(p,)) for p in range(threads_n)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=60)
    assert not errs
    assert client.end_offsets("t") == [per] * threads_n
    st = client.transport_stats()
    assert st["reconnects"] == 0
    # every thread's appends went down ONE socket as distinct rpcs
    assert st["append_rpcs"] == threads_n * per


def test_server_drop_in_ack_window_replays_only_unacked(remote):
    """The connection dies after an op applied but before its ack: the
    client must replay that frame — and ONLY that frame. Earlier acked
    appends stay un-duplicated; the torn one lands at-least-once (twice,
    without a producer id)."""
    client, store, _ = remote
    client.create_topic("t", partitions=1)
    client.append("t", b"", b"v0", partition=0)
    client.append("t", b"", b"v1", partition=0)
    # next server op applies, then the connection drops before the ack
    INJECTOR.arm("transport.server.respond", "raise", nth=1)
    client.append("t", b"", b"v2", partition=0)
    vals = [r.value for r in client.iter_records("t", 0)]
    # acked prefix exactly once; the ambiguous op at-least-once
    assert vals[:2] == [b"v0", b"v1"]
    assert vals.count(b"v0") == 1 and vals.count(b"v1") == 1
    assert vals.count(b"v2") == 2               # applied + replayed
    st = client.transport_stats()
    assert st["reconnects"] >= 1
    assert st["replayed_frames"] >= 1


def test_lost_request_before_apply_is_exactly_once(remote):
    """The connection dies after the request is read but before dispatch:
    nothing was applied, so the replay lands the op exactly once even
    without a producer id."""
    client, _, _ = remote
    client.create_topic("t", partitions=1)
    INJECTOR.arm("transport.server.recv", "raise", nth=1)
    client.append("t", b"", b"only", partition=0)
    assert [r.value for r in client.iter_records("t", 0)] == [b"only"]
    assert client.transport_stats()["reconnects"] >= 1


def test_full_window_survives_mid_pipeline_drop(remote):
    """Concurrent callers keep the in-flight window full while the server
    tears the connection mid-pipeline: every caller's op completes, and
    duplicates stay bounded by the frames that were in flight at the tear
    (never the acked history)."""
    client, _, _ = remote
    threads_n, per = 6, 20
    sent = threads_n * per
    client.create_topic("t", partitions=threads_n)
    INJECTOR.arm("transport.server.respond", "raise", nth=20)
    errs: list[Exception] = []

    def work(p: int) -> None:
        try:
            for i in range(per):
                client.append("t", b"k", f"{p}:{i}".encode(), partition=p)
        except Exception as e:   # noqa: BLE001
            errs.append(e)

    ts = [threading.Thread(target=work, args=(p,)) for p in range(threads_n)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=60)
    assert not errs
    st = client.transport_stats()
    assert st["reconnects"] >= 1
    landed = sum(client.end_offsets("t"))
    # at-least-once: everything acked landed; dupes only from the replayed
    # in-flight window, not from run length
    assert landed >= sent
    assert landed - sent <= st["replayed_frames"]
    # per-partition sequences survived the replay in order
    for p in range(threads_n):
        vals = [r.value for r in client.iter_records("t", p)]
        deduped = [v for i, v in enumerate(vals) if v not in vals[:i]]
        assert deduped == [f"{p}:{i}".encode() for i in range(per)]


def test_idempotent_producer_exactly_once_across_partial_ack(remote):
    """The regression the dedup contract exists for: a producer-stamped
    batch applied-but-unacked is replayed byte-identical and recognized —
    zero duplicates from a partially-acked pipeline."""
    client, _, _ = remote
    client.create_topic("t", partitions=2)
    INJECTOR.arm("transport.server.respond", "raise", nth=2, every=3)
    with Producer(client, "t", producer_id="p8", linger_sec=0.0,
                  max_batch_records=8) as prod:
        for i in range(64):
            prod.send(f"k{i}".encode(), f"v{i}".encode(), partition=i % 2)
    vals = [r.value for r in client.iter_records("t")]
    assert sorted(vals) == sorted(f"v{i}".encode() for i in range(64))
    assert len(vals) == 64                       # exactly once, no dupes
    assert client.transport_stats()["reconnects"] >= 1


def test_raw_idempotent_append_batch_dedups_replay(remote):
    client, _, _ = remote
    client.create_topic("t", partitions=1)
    INJECTOR.arm("transport.server.respond", "raise", nth=1)
    placed = client.append_batch(
        "t", [(b"a", b"1"), (b"b", b"2")], partition=0,
        producer_id="pid-x", base_seq=0)
    assert [off for _, off in placed] == [0, 1]
    # the batch was applied once despite the replay
    assert client.end_offset("t", 0) == 2
    assert client.transport_stats()["reconnects"] == 1


def test_window_admission_bounds_inflight(remote, tmp_path):
    """max_inflight callers can be on the wire; one more waits for a slot
    instead of growing the window without bound."""
    client, _, server = remote
    small = RemoteLogStore(server.address, tmp_path / "small",
                           max_inflight=2, op_timeout=5.0)
    try:
        small.create_topic("t", partitions=1)
        errs: list[Exception] = []

        def work(i: int) -> None:
            try:
                small.append("t", b"k", f"{i}".encode(), partition=0)
            except Exception as e:   # noqa: BLE001
                errs.append(e)

        ts = [threading.Thread(target=work, args=(i,)) for i in range(8)]
        for t in ts:
            t.start()
        for t in ts:
            t.join(timeout=30)
        assert not errs
        assert small.end_offset("t", 0) == 8
    finally:
        small.close()


# -- coalescer ---------------------------------------------------------------

def test_coalescer_merges_concurrent_appends_with_exact_offsets(remote):
    client, _, _ = remote
    client.create_topic("t", partitions=1)
    threads_n, per = 8, 30
    results: dict[int, list[tuple[int, bytes]]] = {}
    errs: list[Exception] = []

    def work(tid: int) -> None:
        mine = []
        try:
            for i in range(per):
                val = f"{tid}:{i}".encode()
                (_, off), = client.append_batch("t", [(b"k", val)],
                                                partition=0)
                mine.append((off, val))
        except Exception as e:   # noqa: BLE001
            errs.append(e)
        results[tid] = mine

    ts = [threading.Thread(target=work, args=(i,)) for i in range(threads_n)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=60)
    assert not errs
    total = threads_n * per
    assert client.end_offset("t", 0) == total    # dense, no gaps, no dupes
    # every caller got back the offset its record actually landed at
    by_offset = {r.offset: r.value
                 for r in client.iter_records("t", 0)}
    for mine in results.values():
        for off, val in mine:
            assert by_offset[off] == val
    st = client.transport_stats()
    assert st["coalesced_appends"] > 0           # merging actually happened
    assert st["append_rpcs"] < total


def test_coalescer_failure_fans_out_to_all_carried_callers(tmp_path):
    store = PartitionedLog(tmp_path / "srv")
    server = LogServer(store).start()
    client = RemoteLogStore(server.address, tmp_path / "cli",
                            retries=0, retry_backoff_sec=0.01,
                            coalesce_linger_sec=0.02)
    try:
        client.create_topic("t", partitions=1)
        # out-of-range-partition appends fail server-side; every coalesced
        # caller must see the error, not hang
        errs: list[Exception] = []

        def work() -> None:
            try:
                client.append("t", b"k", b"v", partition=7)
            except Exception as e:   # noqa: BLE001 — ST_ERR -> RuntimeError
                errs.append(e)

        ts = [threading.Thread(target=work) for _ in range(4)]
        for t in ts:
            t.start()
        for t in ts:
            t.join(timeout=30)
        assert len(errs) == 4
    finally:
        client.close()
        server.stop()
        store.close()


def test_producer_stamped_appends_bypass_coalescer(remote):
    """Idempotent batches must stay byte-identical across retries: the
    coalescer never merges them."""
    client, _, _ = remote
    client.create_topic("t", partitions=1)
    client.append_batch("t", [(b"a", b"1")], partition=0,
                        producer_id="pid", base_seq=0)
    client.append_batch("t", [(b"b", b"2")], partition=0,
                        producer_id="pid", base_seq=1)
    st = client.transport_stats()
    assert st["coalesced_appends"] == 0
    assert st["append_rpcs"] == 2


# -- end-offset cache and read-ahead ----------------------------------------

def test_end_offset_cache_is_read_your_writes_exact(remote):
    client, _, _ = remote
    client.create_topic("t", partitions=1)
    client.append_batch("t", [(b"k", b"v")] * 10, partition=0)
    # the append response advertised the end: no RPC needed
    assert client.end_offset("t", 0) == 10
    st = client.transport_stats()
    assert st["end_offset_rpcs"] == 0
    assert st["end_cache_hits"] >= 1
    # appends refresh the cache: immediately exact, not TTL-stale
    client.append_batch("t", [(b"k", b"w")] * 5, partition=0)
    assert client.end_offset("t", 0) == 15


def test_end_offset_cache_ttl_expires_for_foreign_writers(remote):
    client, store, _ = remote
    client.create_topic("t", partitions=1)
    client.append("t", b"k", b"v", partition=0)
    assert client.end_offset("t", 0) == 1
    # another writer appends behind this client's back
    store.append("t", b"k", b"w", partition=0)
    time.sleep(client.end_cache_ttl_sec + 0.02)
    assert client.end_offset("t", 0) == 2        # TTL forced a re-fetch


def test_readahead_collapses_sequential_reads(remote):
    client, _, _ = remote
    client.create_topic("t", partitions=1)
    vals = [f"v{i}".encode() for i in range(1000)]
    client.append_batch("t", [(b"k", v) for v in vals], partition=0)
    got = []
    pos = 0
    while pos < 1000:
        recs = client.read("t", 0, pos, 50)
        assert recs
        got.extend(r.value for r in recs)
        pos = recs[-1].offset + 1
    assert got == vals                           # sequence unchanged
    st = client.transport_stats()
    assert st["read_rpcs"] <= 2                  # 1000/1024-record fetches
    assert st["readahead_hits"] >= 15


def test_readahead_sees_records_appended_past_cached_run(remote):
    client, _, _ = remote
    client.create_topic("t", partitions=1)
    client.append_batch("t", [(b"k", b"old")] * 10, partition=0)
    assert len(client.read("t", 0, 0, 10)) == 10     # run cached
    client.append_batch("t", [(b"k", b"new")] * 10, partition=0)
    # the cached run covers offset 5 but can't fill the request, and this
    # client KNOWS (from its own append ack) more exists: must re-fetch
    recs = client.read("t", 0, 5, 15)
    assert len(recs) == 15
    assert [r.value for r in recs] == [b"old"] * 5 + [b"new"] * 10


# -- Producer drain grouping -------------------------------------------------

class _CountingLog:
    """LogStore proxy counting append_batch wire calls."""

    def __init__(self, inner: LogStore) -> None:
        self._inner = inner
        self.append_calls: list[tuple[int | None, int]] = []

    def append_batch(self, topic, records, partition=None, **kw):
        self.append_calls.append((partition, len(records)))
        return self._inner.append_batch(topic, records, partition=partition,
                                        **kw)

    def __getattr__(self, name):
        return getattr(self._inner, name)


def test_producer_drain_groups_interleaved_partitions(tmp_path):
    """A key-routed workload interleaves partitions record-by-record; the
    drain must still issue ONE append per distinct partition, preserving
    per-partition order."""
    store = PartitionedLog(tmp_path / "log")
    store.create_topic("t", partitions=4)
    log = _CountingLog(store)
    prod = Producer(log, "t", max_batch_records=1024, linger_sec=10.0)
    for i in range(64):
        prod.send(b"k", f"v{i}".encode(), partition=i % 4)
    prod.flush()
    assert len(log.append_calls) == 4            # not 64 one-record runs
    assert sorted(log.append_calls) == [(p, 16) for p in range(4)]
    for p in range(4):
        vals = [r.value for r in store.iter_records("t", p)]
        assert vals == [f"v{i}".encode() for i in range(p, 64, 4)]
    store.close()


def test_producer_idempotent_drain_groups_and_survives_retry(tmp_path):
    store = PartitionedLog(tmp_path / "log")
    store.create_topic("t", partitions=2)
    log = _CountingLog(store)
    boom = {"armed": True}
    real = log._inner.append_batch

    def flaky(topic, records, partition=None, **kw):
        out = real(topic, records, partition=partition, **kw)
        if boom["armed"] and partition == 1:
            boom["armed"] = False
            raise ConnectionError("ack lost after apply")
        return out

    log._inner = type("S", (), {})()             # shim: route through flaky
    log._inner.append_batch = flaky
    log._inner.num_partitions = store.num_partitions
    log._inner.flush_topic = store.flush_topic
    prod = Producer(log, "t", producer_id="pp", max_batch_records=1024,
                    linger_sec=10.0)
    for i in range(20):
        prod.send(f"k{i}".encode(), f"v{i}".encode(), partition=i % 2)
    with pytest.raises(ConnectionError):
        prod.flush()
    prod.flush()                                 # retry: frozen run replays
    vals = [r.value for r in store.iter_records("t")]
    assert sorted(vals) == sorted(f"v{i}".encode() for i in range(20))
    assert len(vals) == 20                       # dedup ate the replay
    store.close()
