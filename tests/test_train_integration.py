"""End-to-end training integration on CPU (reduced config):
stream → loader → train steps → checkpoint → crash → resume, asserting the
resumed loss trajectory is IDENTICAL to an uninterrupted run (exactly-once
ingestion + bit-stable optimizer), plus loss-goes-down and failure injection.
"""
import numpy as np
import pytest

from repro import configs
from repro.core import ConsumerGroup, PartitionedLog, make_flowfile
from repro.core.sources import corpus_documents
from repro.data import StreamingDataLoader
from repro.models import Model
from repro.optim import OptConfig
from repro.runtime import SimulatedFailure, Trainer, TrainerConfig


def _fill_corpus(tmp_path, n_docs=4000, partitions=4):
    log = PartitionedLog(tmp_path / "log")
    log.create_topic("corpus", partitions=partitions)
    for i, doc in enumerate(corpus_documents(n_docs)):
        ff = make_flowfile(doc, doc_id=str(i))
        k, v = ff.to_record()
        log.append("corpus", k, v, partition=i % partitions)
    return log


def _loader(log, group="trainer", batch=4, seq=64):
    grp = ConsumerGroup(log, "corpus", group)
    c = grp.add_member("host0")
    return StreamingDataLoader(c, batch_size=batch, seq_len=seq)


def _trainer(tmp_path, log, *, group="trainer", steps=8, ckpt_every=4,
             fail_at=-1, subdir="ck"):
    cfg = configs.get_reduced("tinyllama-1.1b")
    model = Model(cfg)
    opt = OptConfig(peak_lr=1e-3, warmup_steps=2, total_steps=50)
    tcfg = TrainerConfig(steps=steps, ckpt_every=ckpt_every,
                         ckpt_dir=str(tmp_path / subdir), log_every=1,
                         fail_at_step=fail_at)
    return Trainer(model, _loader(log, group), opt, tcfg)


def test_loss_decreases(tmp_path):
    log = _fill_corpus(tmp_path)
    tr = _trainer(tmp_path, log, steps=30, ckpt_every=0)
    out = tr.run()
    assert out["steps"] == 30
    first = tr.history[0]["loss"]
    last = tr.history[-1]["loss"]
    assert last < first, f"loss did not decrease: {first} -> {last}"
    log.close()


def test_crash_resume_bit_identical(tmp_path):
    """Run A: 12 uninterrupted steps. Run B: crash at step 8 (after ckpt at
    8), new trainer resumes and continues to 12. Loss histories match."""
    log = _fill_corpus(tmp_path)
    a = _trainer(tmp_path, log, group="a", steps=12, ckpt_every=4, subdir="a")
    a.run()
    ref = {h["step"]: h["loss"] for h in a.history}

    b1 = _trainer(tmp_path, log, group="b", steps=12, ckpt_every=4,
                  fail_at=8, subdir="b")
    with pytest.raises(SimulatedFailure):
        b1.run()
    b1.ckpt.wait()

    b2 = _trainer(tmp_path, log, group="b", steps=4, ckpt_every=4, subdir="b")
    assert b2.resume()
    assert b2.step_idx == 8
    b2.run(4)
    got = {h["step"]: h["loss"] for h in b2.history}
    for step, loss in got.items():
        assert step in ref
        np.testing.assert_allclose(loss, ref[step], rtol=0, atol=0,
                                   err_msg=f"divergence at step {step}")
    log.close()


def test_checkpoint_contains_loader_state(tmp_path):
    log = _fill_corpus(tmp_path)
    tr = _trainer(tmp_path, log, steps=4, ckpt_every=4)
    tr.run()
    step, trees, meta = tr.ckpt.restore()
    assert step == 4
    assert "positions" in meta["loader"]
    assert meta["loader"]["batches_emitted"] == 4
    log.close()


def test_two_consumers_same_stream(tmp_path):
    """Train + eval consumer groups read the same topic independently —
    the paper's add-a-consumer-without-changing-the-pipeline property."""
    log = _fill_corpus(tmp_path)
    l1 = _loader(log, group="g1")
    l2 = _loader(log, group="g2")
    b1, b2 = l1.next_batch(), l2.next_batch()
    np.testing.assert_array_equal(b1, b2)
    log.close()
