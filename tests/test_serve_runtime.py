"""Serving runtime: request topic → batched prefill/decode → completions
topic, elasticity across server members."""
import json

import jax

from repro import configs
from repro.core import ConsumerGroup, PartitionedLog
from repro.models import Model
from repro.runtime import ServeConfig, Server


def _setup(tmp_path, n_requests=6):
    log = PartitionedLog(tmp_path / "log")
    log.create_topic("requests", partitions=4)
    log.create_topic("completions", partitions=2)
    for i in range(n_requests):
        log.append("requests", str(i).encode(),
                   json.dumps({"id": i, "prompt": f"request number {i}"}).encode())
    cfg = configs.get_reduced("tinyllama-1.1b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return log, model, params


def test_server_serves_all_requests(tmp_path):
    log, model, params = _setup(tmp_path)
    grp = ConsumerGroup(log, "requests", "servers")
    srv = Server(model, params, grp.add_member("s0"), log,
                 ServeConfig(batch_size=4, prompt_len=16, max_new_tokens=4))
    while srv.serve_once():
        pass
    done = sum(log.end_offsets("completions"))
    assert done == 6
    rec = log.read("completions", 0, 0, 10) + log.read("completions", 1, 0, 10)
    ids = {json.loads(r.value)["id"] for r in rec}
    assert len(ids) == 6
    for r in rec:
        doc = json.loads(r.value)
        assert len(doc["completion_ids"]) == 4
    log.close()


def test_two_servers_split_partitions(tmp_path):
    """Elastic serving: a second member takes half the request partitions."""
    log, model, params = _setup(tmp_path, n_requests=8)
    grp = ConsumerGroup(log, "requests", "servers")
    c0 = grp.add_member("s0")
    c1 = grp.add_member("s1")
    assert sorted(c0.assignment + c1.assignment) == [0, 1, 2, 3]
    s0 = Server(model, params, c0, log,
                ServeConfig(batch_size=4, prompt_len=16, max_new_tokens=2))
    s1 = Server(model, params, c1, log,
                ServeConfig(batch_size=4, prompt_len=16, max_new_tokens=2))
    total = 0
    for srv in (s0, s1):
        while True:
            n = srv.serve_once()
            if not n:
                break
            total += n
    assert total == 8
    log.close()
