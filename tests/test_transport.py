"""Wire protocol + RemoteLogStore tests: framed codec round-trips, torn and
oversized frames, fencing, and cross-process replay determinism."""
from __future__ import annotations

import multiprocessing as mp
import socket
import struct
import threading

import pytest

from repro.core import PartitionedLog
from repro.core.delivery import ConsumerGroup
from repro.core.transport import (FencedError, FenceTable, FrameTooLarge,
                                  LogServer, MAX_FRAME, OP_PING,
                                  RemoteLogStore, TransportError, _Reader,
                                  decode_records, encode_records, recv_ctrl,
                                  recv_exact, send_ctrl, send_frame,
                                  serve_store)

#: fast concurrency-layer module: CI re-runs it under the
#: REPRO_LOCK_ORDER=1 lock-order detector (scripts/ci.sh)
pytestmark = pytest.mark.lockorder


@pytest.fixture()
def remote(tmp_path):
    """A LogServer over a PartitionedLog plus a connected RemoteLogStore."""
    store = PartitionedLog(tmp_path / "server")
    server = LogServer(store).start()
    client = RemoteLogStore(server.address, tmp_path / "client")
    yield client, store, server
    client.close()
    server.stop()
    store.close()


# -- codec -------------------------------------------------------------------

def test_records_codec_roundtrip_deterministic():
    records = [(b"", b""), (b"k", b"v" * 100), (b"\x00\xff", bytes(range(256))),
               (b"key-3", b"")]
    buf = encode_records(records)
    assert decode_records(_Reader(buf)) == records
    assert encode_records(records) == buf          # canonical encoding


def test_records_codec_roundtrip_hypothesis():
    st = pytest.importorskip("hypothesis.strategies")
    from hypothesis import given, settings

    @settings(max_examples=200, deadline=None)
    @given(st.lists(st.tuples(st.binary(max_size=64),
                              st.binary(max_size=256)), max_size=32))
    def check(records):
        r = _Reader(encode_records(records))
        assert decode_records(r) == records
        r.done()

    check()


def test_reader_rejects_truncated_body():
    records = [(b"key", b"value")]
    buf = encode_records(records)
    with pytest.raises(TransportError):
        decode_records(_Reader(buf[:-1]))          # torn inside last field


def test_recv_exact_distinguishes_eof_from_torn_frame():
    a, b = socket.socketpair()
    try:
        b.sendall(b"abc")
        b.close()
        assert recv_exact(a, 3) == b"abc"
        with pytest.raises(TransportError, match="connection closed"):
            recv_exact(a, 1)                       # clean EOF at boundary
    finally:
        a.close()
    a2, b2 = socket.socketpair()
    try:
        b2.sendall(b"ab")
        b2.close()
        with pytest.raises(TransportError, match="torn frame"):
            recv_exact(a2, 5)                      # EOF mid-frame
    finally:
        a2.close()


def test_oversized_frame_rejected_on_send_and_recv():
    with pytest.raises(FrameTooLarge):
        send_frame(socket.socket(), OP_PING, b"x" * (MAX_FRAME + 1))
    a, b = socket.socketpair()
    try:
        # hand-craft a header claiming a body larger than the cap: the
        # reader must refuse before allocating/reading the body
        b.sendall(struct.pack("<I", MAX_FRAME + 1))
        with pytest.raises(FrameTooLarge):
            from repro.core.transport import recv_frame
            recv_frame(a)
    finally:
        a.close()
        b.close()


def test_ctrl_frames_roundtrip_json():
    a, b = socket.socketpair()
    try:
        msg = {"t": "assign", "spec": {"group": "g0", "epoch": 3,
                                       "partitions": {"articles": [0, 2]}}}
        send_ctrl(a, msg)
        assert recv_ctrl(b) == msg
    finally:
        a.close()
        b.close()


# -- client/server surface ---------------------------------------------------

def test_remote_store_matches_local_logstore_surface(remote, tmp_path):
    client, store, _ = remote
    local = PartitionedLog(tmp_path / "local")
    for log in (client, local):
        log.create_topic("t", partitions=2)
        log.append_batch("t", [(b"a", b"1"), (b"b", b"2"), (b"c", b"3")],
                         partition=0)
        log.append("t", b"k", b"solo", partition=1)
    assert client.topics() == local.topics()
    assert client.num_partitions("t") == local.num_partitions("t")
    assert client.end_offsets("t") == local.end_offsets("t")
    got_c = [(r.offset, r.key, r.value) for r in client.iter_records("t", 0)]
    got_l = [(r.offset, r.key, r.value) for r in local.iter_records("t", 0)]
    assert got_c == got_l
    assert client.begin_offset("t", 0) == local.begin_offset("t", 0)
    local.close()


def test_remote_store_propagates_key_errors(remote):
    client, _, _ = remote
    with pytest.raises(KeyError):
        client.num_partitions("nope")
    with pytest.raises(KeyError):
        client.read("nope", 0, 0, 10)


def test_remote_append_fenced_by_server_epoch(tmp_path):
    store = PartitionedLog(tmp_path / "srv")
    fences = FenceTable()
    server = LogServer(store, fences=fences).start()
    stale = RemoteLogStore(server.address, tmp_path / "stale")
    fresh = RemoteLogStore(server.address, tmp_path / "fresh")
    try:
        stale.create_topic("t", partitions=1)
        stale.set_fence_epoch(1)
        fresh.set_fence_epoch(2)
        stale.append("t", b"k", b"before", partition=0)
        fences.advance("t", 0, 2)                  # takeover: epoch 2
        with pytest.raises(FencedError):
            stale.append("t", b"k", b"zombie", partition=0)
        fresh.append("t", b"k", b"after", partition=0)
        vals = [r.value for r in fresh.iter_records("t", 0)]
        assert vals == [b"before", b"after"]       # zombie write rejected
    finally:
        stale.close()
        fresh.close()
        server.stop()
        store.close()


def test_remote_store_reconnects_after_connection_drop(remote):
    client, _, _ = remote
    client.create_topic("t", partitions=1)
    client.append("t", b"", b"one", partition=0)
    # drop the transport under the client without telling it: the next call
    # fails mid-flight and must transparently reconnect and retry (the demux
    # reader may notice first and null out the session — keep our own ref)
    sock = client._sock
    sock.shutdown(socket.SHUT_RDWR)
    sock.close()
    client.append("t", b"", b"two", partition=0)
    assert [r.value for r in client.iter_records("t", 0)] == [b"one", b"two"]
    assert client.reconnects >= 1


@pytest.mark.slow
def test_consumer_poll_replay_deterministic_across_processes(tmp_path):
    """The same committed topic read through two RemoteLogStore clients —
    one in this process, one in a spawned child — yields byte-identical
    Consumer.poll sequences (replay determinism over the wire)."""
    ctx = mp.get_context("spawn")
    parent_conn, child_conn = ctx.Pipe()
    proc = ctx.Process(target=serve_store,
                       args=(str(tmp_path / "daemon"), child_conn),
                       daemon=True)
    proc.start()
    address = parent_conn.recv()
    client = RemoteLogStore(address, tmp_path / "c1")
    try:
        client.create_topic("t", partitions=2)
        records = [(f"k{i}".encode(), f"v{i}".encode()) for i in range(64)]
        client.append_batch("t", records[:32], partition=0)
        client.append_batch("t", records[32:], partition=1)
        client.flush_topic("t", fsync=False)

        def drain(log, gid: str) -> list:
            grp = ConsumerGroup(log, "t", gid)
            c = grp.add_member("m0")
            out = []
            while True:
                batch = c.poll(max_records=7)
                if not batch:
                    break
                out.extend((r.offset, r.key, r.value) for r in batch)
            return out

        here = drain(client, "replay-a")
        other = RemoteLogStore(address, tmp_path / "c2")
        try:
            assert drain(other, "replay-b") == here
        finally:
            other.close()
        assert len(here) == 64
    finally:
        client.close()
        parent_conn.send("stop")
        proc.join(timeout=10)
        if proc.is_alive():
            proc.kill()


def test_server_serves_concurrent_clients(remote, tmp_path):
    client, _, server = remote
    client.create_topic("t", partitions=4)
    errs: list[Exception] = []

    def work(i: int) -> None:
        c = RemoteLogStore(server.address, tmp_path / f"w{i}")
        try:
            for j in range(20):
                c.append("t", f"{i}".encode(), f"{i}:{j}".encode(),
                         partition=i)
        except Exception as e:   # noqa: BLE001
            errs.append(e)
        finally:
            c.close()

    threads = [threading.Thread(target=work, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30)
    assert not errs
    assert client.end_offsets("t") == [20, 20, 20, 20]
