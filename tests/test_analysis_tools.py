"""Unit tests for the roofline-analysis machinery: the trip-count-aware
jaxpr cost walker and the HLO collective parser (these produce the §Roofline
numbers, so they get first-class tests)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import (_computation_depths, _group_size,
                                       _multiplier, _shape_bytes,
                                       collective_bytes)
from repro.launch.jaxpr_cost import Cost, loop_trip_table, traced_cost


# ---------------------------------------------------------------------------
# jaxpr cost walker
# ---------------------------------------------------------------------------
def test_single_matmul_exact():
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    c = traced_cost(lambda x, y: x @ y, a, b)
    assert c.dot_flops == 2 * 64 * 128 * 32
    assert c.bytes == 4 * (64 * 128 + 128 * 32 + 64 * 32)


def test_scan_multiplies_by_length():
    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)

    def f(w):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, w, None, length=7)
        return out
    c = traced_cost(f, w)
    assert c.dot_flops == 7 * 2 * 32 ** 3


def test_nested_scan_and_jit():
    w = jax.ShapeDtypeStruct((16, 16), jnp.float32)

    @jax.jit
    def f(w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None
        out, _ = jax.lax.scan(outer, w, None, length=5)
        return out
    c = traced_cost(f, w)
    assert c.dot_flops == 5 * 3 * 2 * 16 ** 3


def test_grad_and_remat_counted():
    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 32), jnp.float32)

    def loss(w, x):
        @jax.checkpoint
        def layer(h):
            return jnp.tanh(h @ w)
        return jnp.sum(layer(layer(x)))

    fwd = traced_cost(loss, w, x)
    both = traced_cost(jax.grad(loss), w, x)
    # backward adds dgrad+wgrad (2x fwd) plus remat recompute (1x) => ~4x
    assert both.dot_flops >= 3.5 * fwd.dot_flops


def test_int8_dequant_taint_halves_operand_bytes():
    q = jax.ShapeDtypeStruct((256, 128), jnp.int8)
    s = jax.ShapeDtypeStruct((256, 1), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 256), jnp.bfloat16)

    def f(x, q, s):
        deq = (q.astype(jnp.float32) * s).astype(jnp.bfloat16)
        return x @ deq
    c = traced_cost(f, x, q, s)
    # dequantized operand counted at 1 B/elt, not 2 (bf16)
    expected = (8 * 256) * 2 + (256 * 128) * 1 + (8 * 128) * 2
    assert c.bytes == expected


def test_trip_table_shapes():
    t = loop_trip_table("train", num_layers=22, num_microbatches=16)
    assert t == {1: 16.0, 2: 22.0, 3: 1.0}
    t = loop_trip_table("prefill", num_layers=32, kv_blocks=64)
    assert t == {1: 32.0, 2: 64.0}
    assert loop_trip_table("decode", num_layers=40) == {1: 40.0}


# ---------------------------------------------------------------------------
# HLO collective parser
# ---------------------------------------------------------------------------
HLO = """\
HloModule test

%region_0.10 (arg.1: (f32[4], s32[])) -> (f32[4], s32[]) {
  %ag = f32[8,16]{1,0} all-gather(%p), channel_id=1, replica_groups=[4,4]<=[16], dimensions={0}
  ROOT %t = tuple()
}

%region_1.20 (arg.2: (f32[4], s32[])) -> pred[] {
  ROOT %c = pred[] constant(true)
}

ENTRY %main (p0: f32[4]) -> f32[4] {
  %w = (f32[4], s32[]) while(%init), condition=%region_1.20, body=%region_0.10
  %ar = f32[32,32]{1,0} all-reduce(%x), channel_id=2, replica_groups=[2,8]<=[16], to_apply=%add
  ROOT %r = f32[4] get-tuple-element(%w), index=0
}
"""


def test_shape_bytes_and_multipliers():
    assert _shape_bytes("f32[8,16]{1,0}") == 8 * 16 * 4
    assert _shape_bytes("(bf16[4,4], f32[2])") == 32 + 8
    assert _multiplier("all-reduce", 4) == pytest.approx(2 * 3 / 4)
    assert _multiplier("all-gather", 8) == pytest.approx(7 / 8)
    assert _multiplier("reduce-scatter", 8) == 7.0
    assert _multiplier("all-reduce", 1) == 0.0


def test_computation_depths_from_while():
    depths = _computation_depths(HLO)
    assert depths["%main"] == 0
    assert depths["%region_0.10"] == 1      # while body


def test_collective_attribution_with_trips():
    out = collective_bytes(HLO, 16, trip_table={1: 10.0})
    ag = out["ops"]["all-gather"]
    # inside the loop body: x10 trips, group 4 → (4-1)/4 ring
    assert ag["weighted"] == pytest.approx(8 * 16 * 4 * (3 / 4) * 10)
    ar = out["ops"]["all-reduce"]            # entry: 1 trip, group 8
    assert ar["weighted"] == pytest.approx(32 * 32 * 4 * 2 * (7 / 8))
    assert _group_size("replica_groups=[4,4]<=[16]", 99) == 4
